"""repro.noc.telemetry: conservation invariants, engine parity of the
per-link planes, calibration fixed point, plan-cache stats, and mid-run
fault timelines (DESIGN.md §10)."""
import json

import numpy as np
import pytest

from repro.core import grid, plan
from repro.core.algo import unregister_cost_model
from repro.core.topology import make_topology
from repro.noc import (
    LatencyHistogram,
    MeasuredContentionCost,
    NoCConfig,
    Telemetry,
    WormholeSim,
    calibrate_cost_model,
    fit_energy_cost,
    link_coords,
    link_index,
    synthetic_workload,
    xsimulate,
)
from repro.noc.trace import (
    Trace,
    TraceEvent,
    TracePhase,
    cross_validate,
    export_timeline,
    replay_host,
    replay_xsim,
)

GRACE = 800


def _host_run(cfg, wl, algo="DPM"):
    g = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_plan(plan(algo, g, r.src, r.dests), r.time)
    return sim, sim.run(wl.horizon + cfg.drain_grace)


# ------------------------------------------------------------ link indexing
def test_link_index_round_trips_mesh_and_torus_wrap():
    g = grid(4)
    for u in [(0, 0), (2, 1), (3, 3)]:
        for v in g.neighbors(*u):
            lid = link_index(g, u, v)
            assert 0 <= lid < g.num_nodes * 4
            assert link_coords(g, lid) == (u, v)
    with pytest.raises(ValueError):
        link_index(g, (0, 0), (2, 0))  # two hops is not a link
    t = make_topology("torus", 4, 4)
    lid = link_index(t, (3, 0), (0, 0))  # +x wrap resolves via signed delta
    assert link_coords(t, lid) == ((3, 0), (0, 0))
    # every directed link id is distinct (the planes index by it)
    ids = {
        link_index(t, u, v)
        for y in range(4) for x in range(4)
        for u in [(x, y)] for v in t.neighbors(x, y)
    }
    assert len(ids) == 4 * 4 * 4


# ---------------------------------------------------------------- histogram
def test_latency_histogram_buckets_quantile_overflow():
    h = LatencyHistogram()
    for lat in (0, 1, 2, 3, 4, 7, 8, 2**40):
        h.add(lat)
    # log2 buckets: [1,2) gets the clamped 0 and the 1
    assert h.counts[0] == 2
    assert h.counts[1] == 2  # 2, 3
    assert h.counts[2] == 2  # 4, 7
    assert h.counts[3] == 1  # 8
    assert h.counts[-1] == 1  # overflow absorbs into the last bucket
    assert h.total == 8
    assert h.quantile(0.0) == 2  # upper edge of the first nonempty bucket
    assert h.quantile(0.5) == 4
    assert LatencyHistogram().quantile(0.5) == 0  # empty
    with pytest.raises(ValueError):
        h.quantile(1.5)
    d = h.to_dict()
    assert d["total"] == 8 and sum(d["bins_log2"]) == 8
    assert LatencyHistogram.from_latencies([5, 5, 9]).total == 3


def test_epoch_rows_grow_on_demand():
    tm = Telemetry(num_nodes=4, vcs_per_class=2, epoch_len=1)
    tm.flit(0, 0, cycle=0)
    tm.flit(1, 1, cycle=5)
    tm.latency(3, cycle=5)
    assert tm.num_epochs == 6  # rows 1..4 exist but stay empty
    el = tm.epoch_link_flits()
    assert el.shape == (6, 16)
    assert el.sum() == 2 and el[5, 1] == 1
    rows = tm.epoch_series()
    assert rows[0] == {
        "epoch": 0, "cycle_start": 0, "flits": 1, "deliveries": 0,
        "avg_latency": None,
    }
    assert rows[5]["deliveries"] == 1 and rows[5]["avg_latency"] == 3.0
    with pytest.raises(ValueError):
        Telemetry(4, 2, epoch_len=0)
    # empty store still reads cleanly
    empty = Telemetry(4, 2)
    assert empty.epoch_link_flits().shape == (0, 16)
    assert empty.epoch_series() == []


# ------------------------------------------------- host conservation invariants
def test_host_telemetry_conserves_flat_counters():
    cfg = NoCConfig(n=5, multicast_fraction=0.5, dest_range=(3, 6),
                    drain_grace=GRACE)
    wl = synthetic_workload(cfg, 0.04, 150, seed=2)
    sim, st = _host_run(cfg, wl)
    tm = st.telemetry
    assert st.packets_finished == st.packets_created
    # the structured view and the flat aggregates count the same events
    assert int(tm.link_flits.sum()) == st.flit_link_traversals
    assert int(tm.vc_class_flits.sum()) == st.flit_link_traversals
    assert int(tm.epoch_link_flits().sum()) == st.flit_link_traversals
    assert tm.latency_hist.total == len(st.latencies)
    # both VC classes carry traffic under a multicast-heavy DPM mix
    assert (tm.vc_class_flits.sum(axis=0) > 0).all()
    # occupancy HWMs stay within the configured FIFO depth
    assert 1 <= tm.occupancy_hwm.max() <= cfg.buffer_depth
    # router view is the link view folded over outgoing directions
    assert int(tm.router_conflicts().sum()) == int(tm.link_conflicts.sum())
    g = grid(cfg.n)
    hm = tm.heatmap(g)
    assert hm.shape == (5, 5, 4) and int(hm.sum()) == st.flit_link_traversals
    snap = tm.to_dict()
    assert sum(snap["link_flits"]) == st.flit_link_traversals
    assert snap["latency_hist"]["total"] == len(st.latencies)
    assert sum(e["flits"] for e in snap["epochs"]) == st.flit_link_traversals


# --------------------------------------------- xsim planes match host exactly
@pytest.mark.parametrize(
    "case",
    [
        ("mesh", NoCConfig(n=5, multicast_fraction=0.5, dest_range=(3, 6),
                           drain_grace=GRACE), 0.04, 150, 2),
        ("degraded-8x8", NoCConfig(
            warmup=0, drain_grace=GRACE, multicast_fraction=0.4,
            dest_range=(3, 6),
            broken_links=(((3, 3), (4, 3)), ((3, 4), (3, 5)),
                          ((0, 0), (1, 0)), ((6, 6), (6, 7)))),
         0.025, 150, 2),
    ],
    ids=lambda c: c[0],
)
def test_xsim_link_planes_match_host_counters(case):
    _, cfg, rate, cycles, seed = case
    wl = synthetic_workload(cfg, rate, cycles, seed=seed)
    _, st = _host_run(cfg, wl)
    res = xsimulate(cfg, [wl], ("DPM",))
    # per-link flit traversals are conserved events: exact equality, link by
    # link, including on the degraded mesh with detoured routes
    assert np.array_equal(
        res.link_utilization(0, 0), st.telemetry.link_flits
    )
    g = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
    hm = res.link_heatmap(0, 0)
    assert hm.shape == (g.rows, g.n, 4)
    assert np.array_equal(hm, st.telemetry.heatmap(g))
    # conflicts are timing-dependent (simultaneous vs sequential
    # arbitration), so totals track but are NOT pinned equal cross-engine
    assert res.router_conflicts(0, 0).shape == (g.num_nodes,)


def test_xsim_epoch_buckets_partition_totals():
    cfg = NoCConfig(n=5, multicast_fraction=0.5, dest_range=(3, 6),
                    drain_grace=GRACE)
    wl = synthetic_workload(cfg, 0.04, 150, seed=2)
    whole = xsimulate(cfg, [wl], ("DPM",))
    res = xsimulate(cfg, [wl], ("DPM",), epoch_len=64)
    assert res.epoch_len == 64
    assert res.lutil.shape[-2] == -(-res.cycles // 64)
    # bucketing is a partition of time: epoch planes sum to the totals
    assert np.array_equal(
        res.link_utilization(0, 0), whole.link_utilization(0, 0)
    )
    assert np.array_equal(
        res.router_conflicts(0, 0), whole.router_conflicts(0, 0)
    )
    # per-epoch selection reads one row of the same partition
    e0 = res.link_utilization(0, 0, epoch=0)
    assert e0.sum() <= res.link_utilization(0, 0).sum()
    total = sum(
        res.link_utilization(0, 0, epoch=e).sum()
        for e in range(res.lutil.shape[-2])
    )
    assert total == res.link_utilization(0, 0).sum()


def test_xsim_telemetry_planes_backend_identical():
    cfg = NoCConfig(n=4, multicast_fraction=0.5, dest_range=(2, 4))
    wl = synthetic_workload(cfg, 0.05, 80, seed=1)
    ref = xsimulate(cfg, [wl], ("DPM",), backend="ref", epoch_len=32)
    pal = xsimulate(cfg, [wl], ("DPM",), backend="pallas_interpret",
                    epoch_len=32)
    # jnp reference and Pallas lower from the same cycle_core: bit-identical
    assert np.array_equal(ref.lutil, pal.lutil)
    assert np.array_equal(ref.rconf, pal.rconf)


# ----------------------------------------------------------- calibration loop
def test_measured_contention_cost_validation_and_hysteresis():
    g = grid(4)
    util = np.zeros(g.num_nodes * 4)
    util[5] = 100.0
    m = MeasuredContentionCost(g, util)
    u, v = link_coords(g, 5)
    assert m.link_cost(g, u, v) == 2.0  # 1 + lam * util/peak at the peak
    assert m.link_cost(g, *link_coords(g, 0)) == 1.0
    with pytest.raises(ValueError):  # wrong shape
        MeasuredContentionCost(g, np.zeros(3))
    with pytest.raises(ValueError):  # calibrated for another fabric
        m.link_cost(grid(5), (0, 0), (1, 0))
    # hysteresis: sub-quantum movement keeps the previous weights exactly
    drift = util + 100.0 / (3 * m.QUANT)  # < STICK quanta after scaling
    m2 = MeasuredContentionCost(g, drift, prev=m)
    assert np.array_equal(m2.weights, m.weights)
    # a full-quantum move does flip the weight
    util2 = util.copy()
    util2[7] = 50.0
    m3 = MeasuredContentionCost(g, util2, prev=m)
    assert m3.weights[7] > m.weights[7]
    # zero utilization fits uniform weights (cost-equal to hop counting)
    assert (MeasuredContentionCost(g, np.zeros(64)).weights == 1.0).all()


def test_fit_energy_cost_from_counters():
    cfg = NoCConfig()
    F = cfg.flits_per_packet
    ctr = {
        "flit_link_traversals": 10 * F, "buffer_writes": 10 * F,
        "buffer_reads": 10 * F, "xbar_traversals": 10 * F,
        "arbitrations": 10, "ni_flits": 2 * F, "packets_finished": 2,
    }
    m = fit_energy_cost(ctr, cfg.energy, F)
    e = cfg.energy
    per_hop = F * (e.e_buffer_write + e.e_buffer_read + e.e_xbar + e.e_link
                   ) + e.e_arbiter
    assert m._per_hop == pytest.approx(per_hop)
    assert m._per_packet == pytest.approx(F * e.e_ni)
    # attribute-style counters (a SimStats) fit identically
    class _C:
        pass
    c = _C()
    for k, v in ctr.items():
        setattr(c, k, v)
    assert fit_energy_cost(c, cfg.energy, F)._per_hop == m._per_hop


def test_calibration_reaches_fixed_point_and_never_regresses():
    cfg = NoCConfig(n=6, warmup=0, drain_grace=GRACE)
    wl = synthetic_workload(cfg, 0.06, 150, seed=3)
    try:
        res = calibrate_cost_model(cfg, wl, "DPM", name="cal-test",
                                   max_iters=8)
        # fixed point: one iteration reproduced its predecessor's plans
        assert res.converged
        assert res.iterations[-1]["plans_changed_vs_prev"] == 0
        # the registered model never regresses the calibration scenario
        assert res.calibrated_latency <= res.baseline_latency
        # the loop is closed: the name resolves to the chosen iterate
        from repro.core.algo import get_cost_model

        assert get_cost_model("cal-test") is res.model
        assert res.energy._per_hop > 0 and res.energy._per_packet > 0
        d = res.to_dict()
        assert d["converged"] and "signature" not in d["iterations"][0]
        assert len(d["iterations"]) == len(res.iterations)
    finally:
        unregister_cost_model("cal-test")


# ------------------------------------------------------------ plan-cache stats
def test_plan_cache_by_key_attribution():
    from repro.core import planner

    planner.plan_cache_clear()
    g = grid(4)
    plan("DPM", g, (0, 0), [(3, 3), (1, 2)])
    plan("DPM", g, (0, 0), [(3, 3), (1, 2)])  # hit
    plan("MU", g, (0, 0), [(3, 3)])
    info = planner.plan_cache_info()
    assert info.hits == 1 and info.misses == 2 and info.currsize == 2
    assert info.maxsize == planner._PLAN_CACHE_MAXSIZE
    by = info.by_key
    (dpm_key,) = [k for k in by if k[0] == "DPM"]
    assert by[dpm_key] == {"hits": 1, "misses": 1, "evictions": 0}
    (mu_key,) = [k for k in by if k[0] == "MU"]
    assert by[mu_key]["misses"] == 1
    # clear zeroes both the cache and the attribution
    planner.plan_cache_clear()
    info = planner.plan_cache_info()
    assert info.currsize == 0 and info.hits == 0 and info.by_key == {}


def test_plan_cache_eviction_attribution(monkeypatch):
    from repro.core import planner

    planner.plan_cache_clear()
    monkeypatch.setattr(planner, "_PLAN_CACHE_MAXSIZE", 3)
    g = grid(4)
    dests = [[(3, 3)], [(1, 2)], [(2, 1)], [(0, 3)], [(3, 0)]]
    for d in dests:
        plan("DPM", g, (0, 0), d)
    info = planner.plan_cache_info()
    assert info.currsize == 3  # LRU bounded at the patched maxsize
    (key,) = list(info.by_key)
    assert info.by_key[key]["evictions"] == 2
    assert info.by_key[key]["misses"] == 5
    # the survivors are the most recent entries: re-planning them hits
    for d in dests[-3:]:
        plan("DPM", g, (0, 0), d)
    assert planner.plan_cache_info().hits == 3
    planner.plan_cache_clear()


# ------------------------------------------------- mid-run faults in replay
def _two_phase_trace():
    return Trace(
        "midfault", 16,
        (
            TracePhase("healthy", (
                TraceEvent(0, 0, (5, 10), 64),
                TraceEvent(2, 3, (12,), 128),
            )),
            TracePhase("degraded", (
                TraceEvent(0, 0, (5, 10), 64),
                TraceEvent(2, 3, (12,), 128),
            )),
        ),
    )


def test_midrun_fault_injection_shows_in_timeline(tmp_path):
    tr = _two_phase_trace()
    cfg = NoCConfig(n=4, drain_grace=GRACE)
    dead = (((0, 0), (1, 0)),)
    over = {"degraded": dead}
    h = replay_host(tr, cfg, "DPM", phase_broken_links=over)
    x = replay_xsim(tr, cfg, "DPM", phase_broken_links=over)
    for r in (h, x):
        assert r.phase_faults == [None, dead]
        # the dead link carries flits while healthy, none once broken
        g = grid(cfg.n)
        lid = link_index(g, *dead[0])
        rid = link_index(g, dead[0][1], dead[0][0])
        assert r.phase_link_util[1][lid] == 0
        assert r.phase_link_util[1][rid] == 0
        # the detour rescues the traffic: the same destinations are served
        # (DPM may repartition into more child packets on the degraded mesh)
        served = [
            set().union(*d.values()) for d in r.phase_deliveries
        ]
        assert served[0] == served[1]
        tl = r.timeline()
        assert tl["phases"][0]["broken_links"] is None
        assert tl["phases"][1]["broken_links"] == [
            [list(u), list(v)] for u, v in dead
        ]
        assert tl["fabric"] == {"n": 4, "rows": 4}
        for ph in tl["phases"]:
            assert ph["total_flits"] > 0
            assert len(ph["link_heatmap"]) == 4
            assert ph["stragglers"] and all(
                {"pid", "node", "latency"} <= set(s) for s in ph["stragglers"]
            )
        # degradation is visible: the broken phase pays detour cycles
        assert r.phase_cycles[1] >= r.phase_cycles[0]
    # the artifact round-trips as plain JSON
    out = tmp_path / "timeline.json"
    written = export_timeline(h, out)
    assert json.loads(out.read_text()) == json.loads(
        json.dumps(written, sort_keys=True)
    )


def test_midrun_fault_parity_and_override_semantics():
    tr = _two_phase_trace()
    cfg = NoCConfig(n=4, drain_grace=GRACE)
    # both engines agree on delivery sets under the mid-run fault
    cross_validate(tr, cfg, "DPM",
                   phase_broken_links={1: (((0, 0), (1, 0)),)})
    # an override persists until the next one: () at phase 1 models repair
    h = replay_host(tr, cfg, "DPM",
                    phase_broken_links={0: (((0, 0), (1, 0)),), 1: ()})
    assert h.phase_faults == [(((0, 0), (1, 0)),), ()]
    with pytest.raises(KeyError):
        replay_host(tr, cfg, "DPM", phase_broken_links={"nope": ()})
    with pytest.raises(IndexError):
        replay_host(tr, cfg, "DPM", phase_broken_links={7: ()})
