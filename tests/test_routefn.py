"""Route-provider layer: fault-free bit-identity, detours, cache keying,
and degraded-mesh engine parity (ISSUE 5 / DESIGN.md §7)."""
from __future__ import annotations

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    DisconnectedError,
    FaultAwareProvider,
    MinimalRouteProvider,
    faulty,
    grid,
    plan,
    plan_cache_clear,
    plan_cache_info,
    provider_for,
    torus,
)
from repro.core.routing import (
    greedy_tour,
    label_route,
    label_route_step,
    path_multicast,
    xy_route,
)


# ---------------------------------------------------------------------------
# Inline legacy reference: the pre-provider routing functions, verbatim.
# ---------------------------------------------------------------------------
def _legacy_xy_route(g, src, dst):
    dx, dy = g.delta(src, dst)
    x, y = src
    path = [src]
    step = 1 if dx > 0 else -1
    for _ in range(abs(dx)):
        x, y = g.normalize(x + step, y)
        path.append((x, y))
    step = 1 if dy > 0 else -1
    for _ in range(abs(dy)):
        x, y = g.normalize(x, y + step)
        path.append((x, y))
    return path


def _legacy_label_step(g, cur, target, high):
    lt = g.label(*target)
    best, best_lab = None, None
    for v in g.neighbors(*cur):
        lv = g.label(*v)
        if high:
            if lv <= lt and (best_lab is None or lv > best_lab):
                best, best_lab = v, lv
        else:
            if lv >= lt and (best_lab is None or lv < best_lab):
                best, best_lab = v, lv
    assert best is not None
    return best


def _nodes(g):
    return [(x, y) for y in range(g.rows) for x in range(g.n)]


def _links(g):
    out = set()
    for u in _nodes(g):
        for v in g.neighbors(*u):
            out.add((u, v) if u <= v else (v, u))
    return sorted(out)


def _hops_ok(topo, path):
    """Every hop of ``path`` crosses a live link of ``topo``."""
    for u, v in zip(path, path[1:]):
        assert v in topo.neighbors(*u), (u, v)


# ---------------------------------------------------------------------------
# Fault-free bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("g", [grid(6), grid(5, 3), torus(5), torus(4, 6)])
def test_provider_routes_bit_identical_to_legacy_fault_free(g):
    assert isinstance(provider_for(g), MinimalRouteProvider)
    assert faulty(g, ()) is g  # empty fault set keeps the legacy path
    for src in _nodes(g):
        for dst in _nodes(g):
            assert xy_route(g, src, dst) == _legacy_xy_route(g, src, dst)
            if dst == src:
                continue
            ls, lt = g.label(*src), g.label(*dst)
            if lt != ls:
                high = lt > ls
                assert label_route_step(g, src, dst, high) == _legacy_label_step(
                    g, src, dst, high
                )


def test_fault_free_plans_unchanged_for_all_registered_algorithms():
    """plan() output on a healthy topology never reflects the provider
    refactor: every registered algorithm's paths are built from legacy
    XY routes / label chains (spot-checked structurally here; the figure
    benchmarks' pinned curves are the full regression)."""
    from repro.core import available_algorithms

    for g in (grid(8), torus(6)):
        src, dests = (1, 2), [(5, 5), (0, 4), (4, 0), (3, 3)]
        for name in available_algorithms(g):
            p = plan(name, g, src, dests)
            assert p.check_covers()
            for path in p.paths:
                _hops_ok(g, path.hops)
                # every leg-free unicast path is a legacy XY route
                if name == "MU":
                    assert path.hops == _legacy_xy_route(g, src, path.hops[-1])


# ---------------------------------------------------------------------------
# Detours (hypothesis)
# ---------------------------------------------------------------------------
_dims = st.tuples(st.integers(3, 7), st.integers(3, 7))


@given(
    _dims,
    st.integers(0, 2**30 - 1),
    st.integers(0, 9),
    st.integers(0, 2**30 - 1),
)
@settings(max_examples=120, deadline=None)
def test_detoured_routes_never_traverse_broken_links(dims, lseed, nbroken, pseed):
    import random

    n, m = dims
    base = grid(n, m)
    links = _links(base)
    rng = random.Random(lseed)
    broken = rng.sample(links, min(nbroken, len(links) // 3))
    topo = faulty(base, broken)
    if not broken:
        assert topo is base
        return
    prng = random.Random(pseed)
    src = (prng.randrange(n), prng.randrange(m))
    dst = (prng.randrange(n), prng.randrange(m))
    try:
        path = provider_for(topo).unicast(topo, src, dst)
    except DisconnectedError:
        with pytest.raises(DisconnectedError):
            topo.distance(src, dst)
        return
    assert path[0] == src and path[-1] == dst
    _hops_ok(topo, path)  # live links only — broken ones are not neighbors
    assert not any(topo.is_broken(u, v) for u, v in zip(path, path[1:]))
    assert len(path) - 1 == topo.distance(src, dst)  # detours stay shortest


@given(_dims, st.integers(0, 2**30 - 1))
@settings(max_examples=60, deadline=None)
def test_degraded_chain_walks_connected_complete(dims, seed):
    """path_multicast on a degraded topology delivers every reachable
    destination without crossing a broken link (loop-free termination of
    the constrained label rule + BFS fallback)."""
    import random

    n, m = dims
    base = grid(n, m)
    rng = random.Random(seed)
    topo = faulty(base, rng.sample(_links(base), min(4, len(_links(base)) // 4)))
    if topo is base:
        return
    src = (rng.randrange(n), rng.randrange(m))
    reach = [
        d for d in _nodes(base)
        if d != src and _reachable(topo, src, d)
    ]
    ls = topo.label(*src)
    for high in (True, False):
        group = [d for d in reach if (topo.label(*d) > ls) == high
                 and topo.label(*d) != ls]
        if not group:
            continue
        path = path_multicast(topo, src, group, high=high)
        _hops_ok(topo, path)
        assert set(group) <= set(path)  # connected-complete


def _reachable(topo, a, b):
    try:
        topo.distance(a, b)
        return True
    except DisconnectedError:
        return False


def test_label_route_detours_on_degraded_mesh():
    g = grid(4)
    # break the snake link (3,0)-(3,1): the high chain 0..15 must detour
    topo = faulty(g, [((3, 0), (3, 1))])
    path = label_route(topo, (0, 0), (3, 1), high=True)
    assert path[0] == (0, 0) and path[-1] == (3, 1)
    _hops_ok(topo, path)
    assert ((3, 0), (3, 1)) not in set(zip(path, path[1:]))


def test_disconnected_destination_raises_clear_error():
    g = grid(5)
    iso = faulty(g, [((0, 0), (1, 0)), ((0, 0), (0, 1))])
    with pytest.raises(DisconnectedError, match=r"unreachable"):
        plan("DPM", iso, (2, 2), [(0, 0)])
    with pytest.raises(DisconnectedError):
        provider_for(iso).unicast(iso, (0, 0), (4, 4))


def test_link_weights_price_live_links_in_xsim_id_space():
    """The provider's per-directed-link price vector: ids are the xsim
    link-id space (idx(u) * 4 + direction), live links carry the cost
    model's link_cost (1.0 under hop counting), and absent/broken links
    hold +inf so device-side plans price themselves out of crossing one."""
    import numpy as np

    from repro.core import get_cost_model

    g = grid(4)
    dirs = {(1, 0): 0, (-1, 0): 1, (0, 1): 2, (0, -1): 3}

    def lid(u, v):
        return g.idx(u) * 4 + dirs[(v[0] - u[0], v[1] - u[1])]

    w = provider_for(g).link_weights(g)
    assert w.shape == (g.num_nodes * 4,)
    for u in _nodes(g):
        live = set(g.neighbors(*u))
        for dv, d in dirs.items():
            v = (u[0] + dv[0], u[1] + dv[1])
            expect = 1.0 if v in live else np.inf
            assert w[g.idx(u) * 4 + d] == expect, (u, v)

    broken = ((1, 1), (2, 1))
    ft = faulty(g, [broken])
    wf = provider_for(ft).link_weights(ft)
    assert wf[lid(*broken)] == np.inf and wf[lid(broken[1], broken[0])] == np.inf
    assert wf[lid((0, 0), (1, 0))] == 1.0

    cm = get_cost_model("contention")  # model pricing reaches every link
    wc = provider_for(g).link_weights(g, cm)
    u, v = (1, 1), (2, 1)  # central cut: priced above 1
    assert wc[lid(u, v)] == cm.link_cost(g, u, v) > 1.0


def test_faulty_factory_validates_and_normalizes():
    g = grid(4)
    with pytest.raises(ValueError, match="not a link"):
        faulty(g, [((0, 0), (2, 0))])  # not adjacent
    a = faulty(g, [((1, 0), (0, 0))])
    b = faulty(g, [((0, 0), (1, 0))])
    assert a is b  # direction-insensitive, interned
    nested = faulty(a, [((2, 2), (2, 3))])
    assert set(nested.faults) == {((0, 0), (1, 0)), ((2, 2), (2, 3))}
    assert isinstance(provider_for(a), FaultAwareProvider)
    # geometry delegates; degraded distance detours
    assert a.label(3, 1) == g.label(3, 1)
    assert a.distance((0, 0), (1, 0)) == 3  # around the broken link
    assert g.distance((0, 0), (1, 0)) == 1


# ---------------------------------------------------------------------------
# Planner cache keying on fault sets (extends the PR 4 stale-cache fix)
# ---------------------------------------------------------------------------
def test_plan_cache_keyed_on_fault_sets():
    g = grid(8)
    fa = faulty(g, [((0, 0), (1, 0))])
    fb = faulty(g, [((0, 0), (0, 1))])
    plan_cache_clear()
    src, dests = (0, 0), [(3, 0), (0, 3)]
    p_healthy = plan("MU", g, src, dests)
    p_a = plan("MU", fa, src, dests)
    p_b = plan("MU", fb, src, dests)
    assert plan_cache_info().currsize == 3  # three distinct entries
    # the degraded plans actually detour, each around its own fault
    assert p_healthy.total_hops == 6
    assert p_a.total_hops > 6 and p_b.total_hops > 6
    assert [p.hops for p in p_a.paths] != [p.hops for p in p_healthy.paths]
    assert [p.hops for p in p_a.paths] != [p.hops for p in p_b.paths]
    # cache hits return the same instances — no cross-fault aliasing
    assert plan("MU", g, src, dests) is p_healthy
    assert plan("MU", fa, src, dests) is p_a
    assert plan("MU", fb, src, dests) is p_b
    for p, topo in ((p_a, fa), (p_b, fb)):
        for path in p.paths:
            assert not any(
                topo.is_broken(u, v) for u, v in zip(path.hops, path.hops[1:])
            )


# ---------------------------------------------------------------------------
# greedy_tour dedup unification (ISSUE 5 satellite)
# ---------------------------------------------------------------------------
def test_greedy_tour_dedup_unified_with_path_multicast():
    g = grid(5)
    src = (0, 0)
    # destination equal to src: delivered at injection in both functions
    tour = greedy_tour(g, src, [src, (2, 0)])
    assert tour == greedy_tour(g, src, [(2, 0)])
    chain = path_multicast(g, src, [src], high=True)
    assert chain == [src]
    # pass-through delivery: (1, 0) sits on the leg to (2, 0); the tour must
    # not revisit it, and the dedup rule is the same set-of-entered-nodes
    # rule whether the node was the leg target or a pass-through
    tour = greedy_tour(g, src, [(2, 0), (1, 0)])
    assert tour == [(0, 0), (1, 0), (2, 0)]
    # (1, 0) was a pass-through delivery of the first leg, so the tour never
    # targets it again — it heads straight back for (0, 1), only *transiting*
    # (1, 0)/(0, 0) (wormhole transit may revisit nodes; deliveries may not)
    tour = greedy_tour(g, src, [(2, 0), (1, 0), (0, 1)])
    assert tour == [(0, 0), (1, 0), (2, 0), (1, 0), (0, 0), (0, 1)]


def test_degraded_plan_with_src_equal_destination():
    """A destination equal to the source produces a degenerate single-node
    path (delivered at injection); segmentation must pass it through
    instead of crashing, and coverage must hold on the degraded mesh."""
    g = faulty(grid(6), [((2, 2), (3, 2))])
    p = plan("MU", g, (2, 2), [(2, 2), (4, 4)])
    assert p.check_covers()
    assert [path.hops for path in p.paths if len(path.hops) == 1] == [[(2, 2)]]
    for path in p.paths:
        _hops_ok(g, path.hops)


def test_greedy_tour_src_dest_terminates_on_torus():
    t = torus(4)
    tour = greedy_tour(t, (1, 1), [(1, 1), (3, 1)])
    assert tour[0] == (1, 1)
    assert (3, 1) in tour


# ---------------------------------------------------------------------------
# Degraded-mesh engine parity (WormholeSim vs xsim)
# ---------------------------------------------------------------------------
def test_degraded_mesh_parity_wormhole_vs_xsim():
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, WormholeSim, synthetic_workload
    from repro.noc.xsim import xsimulate

    broken = (((3, 3), (4, 3)), ((3, 4), (3, 5)), ((0, 0), (1, 0)),
              ((6, 6), (6, 7)))
    # moderate load: the 10% parity band's regime. Deeper into saturation
    # the degraded mesh's relay segments amplify xsim's static-child-order
    # delta (DESIGN.md §5/§7) and the band widens.
    cfg = NoCConfig(warmup=0, drain_grace=800, broken_links=broken,
                    multicast_fraction=0.4, dest_range=(3, 6))
    wl = synthetic_workload(cfg, 0.025, 150, seed=2)
    g = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)

    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_plan(plan("DPM", g, r.src, r.dests), r.time)
    pst = sim.run(wl.horizon + cfg.drain_grace)
    assert pst.packets_finished == pst.packets_created  # no wedge, all drain
    # no simulated flit crossed a broken link (host engine)
    for pk in sim.packets:
        assert not any(g.is_broken(u, v) for u, v in zip(pk.hops, pk.hops[1:]))

    res = xsimulate(cfg, [wl], ("DPM",))
    # no compiled route crosses a broken link (vector engine): broken
    # directed-link ids must be absent from every reachable stage
    broken_ids = set()
    for u, v in broken:
        for a, b in ((u, v), (v, u)):
            dx, dy = g.delta(a, b)
            d = {(1, 0): 0, (-1, 0): 1, (0, 1): 2, (0, -1): 3}[(dx, dy)]
            broken_ids.add(g.idx(a) * 4 + d)
    link = res.traffic["link"][0]
    ns = res.traffic["num_stages"][0]
    valid = res.traffic["valid"][0]
    for p in range(link.shape[0]):
        if not valid[p]:
            continue
        assert not (set(link[p, : ns[p]].tolist()) & broken_ids)

    psets = {pk.pid: {g.idx(c) for c in pk.delivery_times} for pk in sim.packets}
    assert psets == res.delivered_sets(0, 0)
    xlat = float(res.avg_latency(0, 0))
    assert xlat == pytest.approx(pst.avg_latency, rel=0.10)


# ---------------------------------------------------------------------------
# clustered fault regions: router_failure
# ---------------------------------------------------------------------------
def test_router_failure_expands_to_incident_links():
    from repro.core import router_failure

    g = grid(5)
    # interior router: all four incident links, canonicalized + sorted
    links = router_failure(g, (2, 2))
    assert links == (
        ((1, 2), (2, 2)), ((2, 1), (2, 2)), ((2, 2), (2, 3)),
        ((2, 2), (3, 2)),
    )
    # corner router: two links; edge router: three
    assert len(router_failure(g, (0, 0))) == 2
    assert len(router_failure(g, (2, 0))) == 3
    # multi-node regions merge (shared links deduplicate)
    region = router_failure(g, (2, 2), (3, 2))
    assert len(region) == 4 + 4 - 1
    # torus routers always have four incident links (wrap)
    assert len(router_failure(torus(4), (0, 0))) == 4
    with pytest.raises(ValueError):
        router_failure(g, (9, 9))


def test_router_failure_isolates_node_and_detours_around_it():
    from repro.core import router_failure

    g = grid(5)
    dead = (2, 2)
    topo = faulty(g, router_failure(g, dead))
    # the dead router is unreachable — planning to it raises
    with pytest.raises(DisconnectedError):
        plan("DPM", topo, (0, 0), [dead])
    # everything else routes around the hole, never touching it
    for algo in ("DPM", "MU"):
        p = plan(algo, topo, (1, 2), [(3, 2), (2, 1)])
        for path in p.paths:
            assert dead not in path.hops
            for a, b in zip(path.hops, path.hops[1:]):
                assert b in topo.neighbors(*a)
    # composes with an existing degraded topology
    t2 = faulty(topo, router_failure(topo, (0, 4)))
    assert len(t2.faults) == 4 + 2


def test_balanced_detours_reduce_max_link_load_on_degraded_8x8():
    """Equal-length BFS detours spread across flows (deterministic tie-break)
    instead of funneling through the BFS tree's first-expanded predecessor:
    on a degraded 8x8 mesh with a wide fault cut, the provider's max
    directed-link load over many crossing flows is strictly below the
    naive tree-walk's, every route stays BFS-shortest, and repeated calls
    are bit-identical."""
    from collections import Counter

    from repro.core.routefn import FaultAwareProvider, _bfs_from

    g = grid(8)
    # horizontal cut with three one-column gaps: crossing flows often have
    # two equidistant gaps to detour through — the tie the digest spreads
    cut = tuple(
        ((x, 3), (x, 4)) for x in range(8) if x not in (0, 3, 7)
    )
    topo = faulty(g, cut)
    provider = FaultAwareProvider()
    flows = [((sx, 0), (dx, 7)) for sx in range(8) for dx in range(8)]

    def tree_walk(src, dst):  # the old behavior: first predecessor wins
        tree = _bfs_from(topo, src)
        path = [dst]
        while path[-1] != src:
            path.append(tree[path[-1]][1])
        path.reverse()
        return path

    def max_load(paths):
        c = Counter(
            (u, v) for p in paths for u, v in zip(p, p[1:])
        )
        return max(c.values())

    balanced = [provider.unicast(topo, s, d) for s, d in flows]
    naive = [tree_walk(s, d) for s, d in flows]
    for (s, d), p in zip(flows, balanced):
        assert len(p) - 1 == topo.distance(s, d)  # still shortest
        for u, v in zip(p, p[1:]):
            assert not topo.is_broken(u, v)
    assert max_load(balanced) < max_load(naive), (
        max_load(balanced), max_load(naive)
    )
    # deterministic: same flow set -> same routes
    assert balanced == [provider.unicast(topo, s, d) for s, d in flows]
