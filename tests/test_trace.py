"""repro.noc.trace: IR round-trip, lowerers, barrier replay, engine parity."""
import json

import pytest

from repro.noc import NoCConfig
from repro.noc.trace import (
    Trace,
    TraceEvent,
    TracePhase,
    coherence_trace,
    compressed_allreduce_trace,
    cross_validate,
    ep_dispatch_trace,
    flits_for_bytes,
    from_hlo,
    from_schedule,
    pipeline_trace,
    replay_host,
    replay_xsim,
    serving_trace,
    zero1_gather_trace,
)

CFG = NoCConfig(n=4, topology="mesh")


# --------------------------------------------------------------------- IR
def _tiny():
    return Trace(
        "tiny", 4,
        (
            TracePhase("a", (TraceEvent(0, 0, (1, 2), 64),
                             TraceEvent(3, 3, (0,), 8))),
            TracePhase("b", (TraceEvent(0, 2, (3,), 1024),)),
        ),
        {"kind": "unit", "seed": 7},
    )


def test_json_round_trip_identity():
    t = _tiny()
    assert Trace.from_json(t.to_json()) == t
    # and once more through an indented dump (the committed-artifact form)
    assert Trace.from_json(t.to_json(indent=1)) == t
    # the wire format is plain JSON (diffable artifacts)
    d = json.loads(t.to_json())
    assert d["num_ranks"] == 4 and len(d["phases"]) == 2


def test_ir_validation():
    with pytest.raises(ValueError):  # dest out of range
        Trace("x", 4, (TracePhase("p", (TraceEvent(0, 0, (4,), 1),)),))
    with pytest.raises(ValueError):  # self-send
        Trace("x", 4, (TracePhase("p", (TraceEvent(0, 1, (1,), 1),)),))
    with pytest.raises(ValueError):  # duplicate dests
        Trace("x", 4, (TracePhase("p", (TraceEvent(0, 0, (1, 1), 1),)),))
    with pytest.raises(ValueError):  # negative time
        Trace("x", 4, (TracePhase("p", (TraceEvent(-1, 0, (1,), 1),)),))


def test_flits_for_bytes():
    assert flits_for_bytes(0) == 1  # control messages still need a worm
    assert flits_for_bytes(16) == 1
    assert flits_for_bytes(17) == 2
    assert flits_for_bytes(10**9) == 64  # clamp
    assert flits_for_bytes(100, flit_bytes=10, max_flits=5) == 5
    with pytest.raises(ValueError):
        flits_for_bytes(1, max_flits=128)  # int8 plane cap


# --------------------------------------------------------------- lowerers
def test_from_schedule_preserves_round_structure():
    from repro.dist.multicast import alltoall_schedule

    sched = alltoall_schedule(8, "DPM")
    t = from_schedule(sched, "a2a", 128)
    assert len(t.phases) == sched.num_rounds
    for ph, rnd in zip(t.phases, sched.rounds):
        assert len(ph.events) == len(rnd)
        assert {(e.src, e.dests[0]) for e in ph.events} == set(rnd)


def test_pipeline_trace_step_count():
    # GPipe: M + S - 1 steps; the final step has no handoff (last stage
    # drains), so the trace carries M + S - 2 phases
    t = pipeline_trace(4, 6)
    assert len(t.phases) == 6 + 4 - 2
    # stage s only ever ships to s + 1
    for ph in t.phases:
        assert all(e.dests == (e.src + 1,) for e in ph.events)


def test_generators_deterministic():
    assert coherence_trace(16, seed=3) == coherence_trace(16, seed=3)
    assert serving_trace(16, seed=3) == serving_trace(16, seed=3)
    assert coherence_trace(16, seed=3) != coherence_trace(16, seed=4)


def test_from_hlo_scaling_preserves_mix():
    coll = {"all-reduce": 4e9, "all-gather": 1e9}
    t = from_hlo(coll, 8, scale_to=256)
    by_kind: dict[str, set[int]] = {}
    for ph in t.phases:
        for e in ph.events:
            by_kind.setdefault(ph.name.split(".")[0], set()).add(
                e.payload_bytes
            )
    # largest per-event payload hits scale_to; the 4:1 ratio survives
    assert max(b for s in by_kind.values() for b in s) == 256
    assert by_kind["all-reduce"] == {256}
    assert by_kind["all-gather"] == {64}


# ------------------------------------------------------- barrier semantics
def test_phase_barrier_no_early_injection():
    """No phase-k+1 flit moves before phase k's last delivery: end-to-end
    completion of the serialized trace equals the sum of per-phase
    completions, and each phase's duration is independent of its
    predecessors (replaying a suffix gives identical phase cycles)."""
    t = ep_dispatch_trace(16, chunk_bytes=96)
    r = replay_host(t, CFG, "DPM")
    assert r.total_cycles == sum(r.phase_cycles)
    # a suffix trace replays with the same per-phase durations: phases
    # share no simulator state (the literal barrier)
    suffix = Trace(t.name, t.num_ranks, t.phases[3:], t.meta)
    rs = replay_host(suffix, CFG, "DPM")
    assert rs.phase_cycles == r.phase_cycles[3:]


def test_heterogeneous_payloads_change_completion():
    base = pipeline_trace(4, 3, activation_bytes=16)  # 1-flit worms
    fat = pipeline_trace(4, 3, activation_bytes=16 * 9)  # 9-flit worms
    rb = replay_host(base, CFG, "DPM")
    rf = replay_host(fat, CFG, "DPM")
    assert rf.total_cycles > rb.total_cycles
    # worm length rides per-packet: same phase structure either way
    assert rb.phase_names == rf.phase_names


# ------------------------------------------------------------ engine parity
def test_ep_dispatch_host_vs_xsim_delivery_sets():
    """The issue's acceptance gate: EP all-to-all on 16 ranks / 4x4 mesh,
    identical per-packet delivery sets in both simulators, every phase."""
    t = ep_dispatch_trace(16, chunk_bytes=96)
    h, x = cross_validate(t, CFG, "DPM")  # raises on any divergence
    assert h.phase_deliveries == x.phase_deliveries
    assert h.total_cycles > 0
    # per-phase delivery counts match the schedule's transfers
    for ph, d in zip(t.phases, h.phase_deliveries):
        assert sum(len(s) for s in d.values()) == len(ph.events)


@pytest.mark.parametrize("maker", [
    lambda: zero1_gather_trace(16, 4096),
    lambda: compressed_allreduce_trace(16, 16384),
    lambda: coherence_trace(16, num_bursts=2, lines_per_burst=2, sharers=3,
                            seed=1),
    lambda: serving_trace(16, num_requests=6, rate=0.05, seed=2),
], ids=["zero1", "int8_allreduce", "coherence", "serving"])
def test_workload_classes_cross_validate(maker):
    t = maker()
    h, x = cross_validate(t, CFG, "DPM")
    assert h.phase_deliveries == x.phase_deliveries


def test_replay_on_degraded_fabric():
    t = zero1_gather_trace(16, 4096)
    broken = ((((1, 1), (1, 2))),)
    dcfg = NoCConfig(n=4, topology="mesh", broken_links=broken)
    h, x = cross_validate(t, dcfg, "DPM")
    hh = replay_host(t, CFG, "DPM")
    # detours cost cycles but deliver the same payload everywhere
    assert h.phase_deliveries == x.phase_deliveries
    assert h.total_cycles >= hh.total_cycles


def test_trace_too_big_for_fabric_raises():
    t = ep_dispatch_trace(32, chunk_bytes=16)
    with pytest.raises(ValueError, match="cannot embed"):
        replay_host(t, CFG)
    with pytest.raises(ValueError, match="cannot embed"):
        replay_xsim(t, CFG)
