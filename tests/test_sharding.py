"""dist.sharding rule tables — main-process (1-device view) tests.

AbstractMesh carries axis names/sizes without devices, so rule lookup,
divisibility fallback, and ZeRO-1 extension are all testable here; the
multi-device placement behaviour is covered by tests/dist_checks.py.
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    CACHE_RULES,
    DEFAULT_RULES,
    SEQ_RULES,
    abstract_mesh,
    param_shardings,
    spec_for_shape,
    tree_shardings,
    zero1_shardings,
)

SDS = jax.ShapeDtypeStruct
MESH = abstract_mesh(("data", 2), ("model", 4))
POD = abstract_mesh(("pod", 2), ("data", 4), ("model", 2))


def test_rule_candidate_precedence():
    """batch tries ("pod", "data") before ("data",): the first candidate
    whose axes all exist and divide wins."""
    # pod present and 32 % (2*4) == 0 -> co-sharded over both
    assert spec_for_shape(("batch", "embed"), (32, 17), POD) == P(
        ("pod", "data"), None
    )
    # no pod axis -> the ("data",) fallback candidate
    assert spec_for_shape(("batch", "embed"), (32, 17), MESH) == P("data", None)
    # pod*data=8 does not divide 4, data=4 does -> precedence steps down
    assert spec_for_shape(("batch", "embed"), (4, 16), POD) == P("data", None)


def test_rule_table_override_precedence():
    """An explicit rules table replaces DEFAULT_RULES wholesale."""
    axes, shape = ("batch", "seq", "embed"), (8, 64, 96)
    assert spec_for_shape(axes, shape, MESH) == P("data", None, None)
    assert spec_for_shape(axes, shape, MESH, SEQ_RULES) == P("data", "model", None)
    assert spec_for_shape(axes, shape, MESH, DEFAULT_RULES) == P(
        "data", None, None
    )


def test_spec_for_shape_odd_shapes_replicate():
    assert spec_for_shape(("vocab", "embed"), (49153, 577), MESH) == P(None, None)
    # one odd dim falls back alone, the other still shards
    assert spec_for_shape(("vocab", "embed"), (49152, 577), MESH) == P(
        "model", None
    )


def test_no_mesh_axis_reuse_within_an_array():
    """model goes to the first dim wanting it; later dims replicate."""
    assert spec_for_shape(("heads", "mlp"), (8, 8), MESH) == P("model", None)
    # under SEQ_RULES seq takes model before mlp can
    assert spec_for_shape(
        ("batch", "seq", "mlp"), (8, 64, 64), MESH, SEQ_RULES
    ) == P("data", "model", None)


def test_cache_rules_shard_seq_not_heads():
    assert spec_for_shape(
        ("layers", "batch", "seq", "kv_heads", "head_dim"),
        (2, 8, 64, 4, 16),
        MESH,
        CACHE_RULES,
    ) == P(None, "data", "model", None, None)


def test_tree_and_param_shardings():
    specs = {"w": ("embed", "mlp"), "n": ("embed",)}
    shapes = {"w": SDS((96, 256), jnp.float32), "n": SDS((96,), jnp.float32)}
    tr = tree_shardings(specs, shapes, MESH)
    assert tr["w"].spec == P(None, "model")
    assert tr["n"].spec == P(None)
    # shape-free structural mapping skips the divisibility check
    ps = param_shardings({"w": ("embed", "heads")}, MESH)
    assert ps["w"].spec == P(None, "model")


def test_zero1_adds_data_shard_with_replication_fallback():
    specs = {
        "emb": ("vocab", "embed"),
        "norm": ("layers", "embed"),
        "odd": ("layers", "embed"),
    }
    shapes = {
        "emb": SDS((512, 96), jnp.float32),
        "norm": SDS((3, 96), jnp.float32),
        "odd": SDS((3, 97), jnp.float32),  # nothing divides by data=2
    }
    zs = zero1_shardings(specs, shapes, MESH)
    assert zs["emb"].spec == P("model", "data")
    assert zs["norm"].spec == P(None, "data")
    assert zs["odd"].spec == P(None, None)  # fallback: stays replicated


def test_zero1_multi_data_axis_precedence():
    """Full pod*data degree first, then single data axes."""
    specs = {"a": ("layers", "embed"), "b": ("layers", "embed")}
    shapes = {
        "a": SDS((3, 64), jnp.float32),  # 64 % (2*4) == 0 -> ("pod","data")
        "b": SDS((3, 4), jnp.float32),  # only data=4 divides
    }
    zs = zero1_shardings(specs, shapes, POD)
    assert zs["a"].spec == P(None, ("pod", "data"))
    assert zs["b"].spec == P(None, "data")
