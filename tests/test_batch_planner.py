"""Batched device planning + plan arena (core/batch_planner, ISSUE 10).

The load-bearing property is **bit-identity**: every plan the batched
planner returns equals host ``plan()`` field for field — across algorithms
(DPM / DPM-E), cost models (hops / weighted), every registered topology
kind, and on degraded fabrics via the host fallback path. Plus: canonical
dest-set interning shared with the plan cache, arena LRU hit/miss/eviction
attribution mirroring ``plan_cache_info()``, and the consumer wiring
(simulator bulk admission, dist schedule builder).
"""
from __future__ import annotations

import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BatchPlanner,
    arena_clear,
    arena_info,
    batch_support,
    bulk_plan,
    canonical_dests,
    chiplet,
    faulty,
    grid,
    mesh3d,
    plan,
    plan_cache_clear,
    plan_cache_info,
    planner_for,
    registered_topology_kinds,
    torus,
    torus3d,
)
import repro.core.batch_planner as bpm


@pytest.fixture(autouse=True)
def _fresh_caches():
    plan_cache_clear()
    arena_clear()
    yield
    plan_cache_clear()
    arena_clear()


def _requests(g, n, seed, kmax=8):
    nodes = g.nodes()
    rng = random.Random(seed)
    out, seen = [], set()
    while len(out) < n:
        src = rng.choice(nodes)
        k = rng.randint(2, min(kmax, len(nodes) - 1))
        dests = tuple(
            sorted(rng.sample([x for x in nodes if x != src], k))
        )
        if (src, dests) in seen:
            continue
        seen.add((src, dests))
        out.append((src, list(dests)))
    return out


# the 2-D kinds and the chiplet package share one jit specialization
# (NN=16, np_=8); the 3-D kinds exercise the 26-wedge candidate table and
# heterogeneous z-links
FABRICS = {
    "mesh": grid(4),
    "torus": torus(4, 4),
    "mesh3d": mesh3d(3, 3, 3, z_weight=2.0),
    "torus3d": torus3d(3, 3, 2),
    "chiplet": chiplet(4),
}


def test_fabric_fixtures_cover_every_registered_kind():
    """If a new topology kind registers, this file must grow a fabric for
    it — the bit-identity sweep below is only as wide as this dict."""
    assert set(FABRICS) == set(registered_topology_kinds())


# ---------------------------------------------------------------------------
# Bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", sorted(FABRICS))
@pytest.mark.parametrize("algo,cm", [("DPM", "hops"), ("DPM-E", "weighted")])
def test_batched_plans_bit_identical_all_kinds(kind, algo, cm):
    g = FABRICS[kind]
    bp = BatchPlanner(g, algo, cm)
    assert bp.support.ok, bp.support.reason
    reqs = _requests(g, 10, seed=sum(map(ord, kind + algo + cm)))
    got = bp.plan_many(reqs)
    for (src, dests), pb in zip(reqs, got):
        assert pb == plan(algo, g, src, dests, cost_model=cm)
    assert bp.info().batched_plans == len(reqs)
    assert bp.info().host_plans == 0


@pytest.mark.parametrize("algo,cm", [("DPM", "weighted"), ("DPM-E", "hops")])
def test_batched_plans_bit_identical_remaining_combos(algo, cm):
    """The algorithm x cost-model combinations the kind sweep skips."""
    g = FABRICS["mesh"]
    bp = BatchPlanner(g, algo, cm)
    assert bp.support.ok, bp.support.reason
    reqs = _requests(g, 10, seed=7)
    for (src, dests), pb in zip(reqs, bp.plan_many(reqs)):
        assert pb == plan(algo, g, src, dests, cost_model=cm)


@settings(max_examples=40)
@given(st.integers(0, 10**9))
def test_batched_plan_bit_identical_property(seed):
    """Property form: random (src, dest-set) instances on the shared mesh
    fabric, one at a time through the arena, always equal host plan()."""
    g = FABRICS["mesh"]
    bp = planner_for(g, "DPM")
    (src, dests), = _requests(g, 1, seed)
    assert bp.plan_one(src, dests) == plan("DPM", g, src, dests)


def test_degraded_fabric_falls_back_to_host():
    g = faulty(grid(4), (((0, 0), (1, 0)),))
    sup = batch_support(g)
    assert not sup.ok and "degraded" in sup.reason
    bp = BatchPlanner(g, "DPM")
    reqs = _requests(g, 6, seed=3)
    got = bp.plan_many(reqs)
    for (src, dests), pb in zip(reqs, got):
        assert pb == plan("DPM", g, src, dests)
    info = bp.info()
    assert info.host_plans == len(reqs)
    assert info.batched_plans == 0 and info.dispatches == 0


def test_energy_objective_is_gated_off_device():
    """The energy model's pJ constants are not dyadic rationals — the
    f32-exactness gate must reject it (DPM-E then host-plans)."""
    sup = batch_support(grid(4), "DPM-E")  # default model: energy
    assert not sup.ok and "dyadic" in sup.reason


def test_non_dpm_algorithms_have_no_device_twin():
    sup = batch_support(grid(4), "MU")
    assert not sup.ok and "device twin" in sup.reason


# ---------------------------------------------------------------------------
# Canonical dest-set interning (shared helper)
# ---------------------------------------------------------------------------
def test_canonical_dests_sorts_dedups_and_normalizes():
    assert canonical_dests([(2, 1), (0, 3), (2, 1)]) == ((0, 3), (2, 1))
    assert canonical_dests([[2, 1], (0, 3)]) == ((0, 3), (2, 1))  # lists ok
    assert canonical_dests([]) == ()


def test_permuted_dests_share_one_plan_cache_entry():
    g = grid(4)
    dests = [(1, 2), (3, 0), (2, 3)]
    p1 = plan("DPM", g, (0, 0), dests)
    p2 = plan("DPM", g, (0, 0), list(reversed(dests)))
    p3 = plan("DPM", g, (0, 0), dests + [dests[0]])  # duplicate entry
    assert p1 is p2 is p3  # literally the same cached object
    info = plan_cache_info()
    assert info.misses == 1 and info.hits == 2


def test_permuted_dests_share_one_arena_entry():
    g = grid(4)
    bp = BatchPlanner(g, "DPM")
    dests = [(1, 2), (3, 0), (2, 3)]
    a, b = bp.plan_many(
        [((0, 0), dests), ((0, 0), list(reversed(dests)))]
    )
    assert a is b
    info = bp.info()
    # second request deduped against the first inside one plan_many call
    assert info.misses == 2 and info.currsize == 1
    c = bp.plan_one((0, 0), dests + [dests[-1]])
    assert c is a
    assert bp.info().hits == 1


# ---------------------------------------------------------------------------
# Arena LRU accounting (mirrors plan_cache_info semantics)
# ---------------------------------------------------------------------------
def test_arena_lru_hit_miss_eviction_attribution():
    g = grid(4)
    bp = BatchPlanner(g, "DPM", maxsize=4)
    reqs = _requests(g, 6, seed=11)
    bp.plan_many(reqs)
    info = bp.info()
    assert info.misses == 6 and info.evictions == 2 and info.currsize == 4
    # the two oldest were evicted: re-planning them misses again; the
    # newest still hits and refreshes its LRU slot
    bp.plan_many([reqs[-1]])
    assert bp.info().hits == 1
    bp.plan_many([reqs[0]])
    assert bp.info().misses == 7


def test_arena_info_aggregates_by_algo_and_cost_model():
    g = grid(4)
    reqs = _requests(g, 4, seed=5)
    bulk_plan(g, reqs, "DPM")
    bulk_plan(g, reqs, "DPM", cost_model="weighted")
    bulk_plan(g, reqs[:2], "DPM")  # hits on the first planner
    info = arena_info()
    assert info.hits == 2 and info.misses == 8
    assert info.by_key[("DPM", "hops")]["misses"] == 4
    assert info.by_key[("DPM", "hops")]["hits"] == 2
    assert info.by_key[("DPM", "weighted")]["misses"] == 4
    arena_clear()
    assert arena_info().misses == 0 and arena_info().currsize == 0


def test_planner_for_shares_one_arena_per_config():
    g = grid(4)
    assert planner_for(g, "DPM") is planner_for(g, "DPM")
    assert planner_for(g, "DPM") is not planner_for(g, "DPM", "weighted")


def test_bulk_plan_empty_and_order_preserving():
    g = grid(4)
    assert bulk_plan(g, []) == []
    reqs = _requests(g, 5, seed=9)
    plans = bulk_plan(g, reqs)
    for (src, dests), p in zip(reqs, plans):
        assert p.src == src and set(p.dests) == set(dests)


# ---------------------------------------------------------------------------
# Consumer wiring: simulator driver + dist schedule builder
# ---------------------------------------------------------------------------
def test_simulator_bulk_admission_matches_per_request(monkeypatch):
    from repro.noc.config import NoCConfig
    from repro.noc.simulator import WormholeSim
    from repro.noc.traffic import Request

    cfg = NoCConfig(n=4, m=4)
    reqs = [
        Request(0, (0, 0), [(3, 3), (1, 2)]),
        Request(1, (2, 2), [(0, 3)], flits=3),
        Request(3, (0, 0), [(1, 2), (3, 3)]),  # permuted duplicate
    ]
    sim_a = WormholeSim(cfg)
    sim_a.add_requests("DPM", reqs)
    assert planner_for(grid(4), "DPM").info().batched_plans > 0
    sim_b = WormholeSim(cfg)
    for r in reqs:
        sim_b.add_request("DPM", r.src, r.dests, r.time, flits=r.flits)
    sa = sim_a.run(300, drain=True)
    sb = sim_b.run(300, drain=True)
    assert sa.packets_finished == sb.packets_finished
    assert sa.flit_link_traversals == sb.flit_link_traversals


def test_dist_schedule_builder_uses_arena_and_matches_host(monkeypatch):
    from repro.dist.multicast import schedule_multicasts

    t = torus(4, 4)
    reqs = [((0, 0), [(2, 2), (1, 3)]), ((3, 3), [(0, 1), (2, 0)])]
    sched = schedule_multicasts(t, reqs)
    assert planner_for(t, "DPM").info().batched_plans > 0
    # force the host path (support gate off) and require identical rounds
    arena_clear()
    monkeypatch.setattr(
        bpm, "batch_support",
        lambda *a, **k: bpm._Support(False, "forced by test"),
    )
    sched_host = schedule_multicasts(t, reqs)
    assert planner_for(t, "DPM").info().host_plans > 0
    assert sched.rounds == sched_host.rounds
    assert sched.hops == sched_host.hops


def test_xsim_compile_bulk_plans_through_arena():
    from repro.noc.config import NoCConfig
    from repro.noc.traffic import Request, Workload
    from repro.noc.xsim.compile import compile_workload

    cfg = NoCConfig(n=4, m=4)
    wl = Workload(
        "t",
        [Request(0, (0, 0), [(3, 3)]), Request(1, (2, 2), [(0, 3), (1, 0)])],
        1,
    )
    ct = compile_workload(cfg, wl, "DPM")
    assert ct.num_packets >= 2
    assert planner_for(grid(4), "DPM").info().batched_plans > 0


def test_registry_change_clears_arenas():
    from repro.core import temporary_algorithm, plan_dpm

    g = grid(4)
    bulk_plan(g, _requests(g, 3, seed=2))
    assert arena_info().misses == 3
    with temporary_algorithm(plan_dpm, name="DPM-tmp"):
        pass  # registration mutates the registry -> arenas must drop
    assert arena_info().misses == 0


def test_batch_padding_and_multi_chunk_batches():
    g = grid(4)
    bp = BatchPlanner(g, "DPM")
    one = bp.plan_many(_requests(g, 1, seed=21))
    assert len(one) == 1
    n = bpm.DISPATCH_CHUNK + 3  # forces a second (padded) chunk
    reqs = _requests(g, n, seed=22, kmax=6)
    got = bp.plan_many(reqs)
    assert len(got) == n
    assert bp.info().dispatches >= 3  # 1 + ceil(n / DISPATCH_CHUNK)
    sample = random.Random(0).sample(range(n), 12)
    for i in sample:
        src, dests = reqs[i]
        assert got[i] == plan("DPM", g, src, dests)
