"""Per-arch smoke tests + attention/SSD/MoE unit tests (reduced configs).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); everything here runs real numbers on CPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKES
from repro.models import (
    RunConfig,
    count_params,
    decode_step,
    forward,
    init_caches,
    model_init,
    prefill,
)
from repro.models.attention import chunked_attention, dequantize_kv, quantize_kv
from repro.models.ssm import ssd_reference, ssd_scan

RUN = RunConfig(
    remat="none",
    attn_chunk_q=32,
    attn_chunk_k=32,
    vocab_round=64,
    activations_dtype="float32",
    kv_cache_dtype="float32",
)


def _batch(cfg, B, S, key, labels=True):
    out = {}
    if cfg.embed_input == "tokens":
        out["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        out["frames"] = jax.random.normal(key, (B, S, cfg.d_model))
    if labels:
        out["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return out


# ------------------------------------------------------------------ smoke
@pytest.mark.parametrize("name", sorted(SMOKES))
def test_arch_smoke_forward(name):
    """One forward/train step on CPU: output shapes + no NaNs (deliverable f)."""
    cfg = SMOKES[name]
    params, specs = model_init(jax.random.PRNGKey(0), cfg, RUN)
    # specs mirror params structurally
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda x: isinstance(x, tuple))
    )
    batch = _batch(cfg, 2, 64, jax.random.PRNGKey(1))
    loss, metrics = forward(params, batch, cfg, RUN)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert float(loss) > 0
    # grads flow and are finite
    g = jax.grad(lambda p: forward(p, batch, cfg, RUN)[0])(params)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)


@pytest.mark.parametrize("name", sorted(SMOKES))
def test_arch_smoke_decode_shapes(name):
    cfg = SMOKES[name]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    B = 2
    caches = init_caches(cfg, RUN, B, 128)
    db = _batch(cfg, B, 1, jax.random.PRNGKey(2), labels=False)
    db["pos"] = jnp.int32(5)
    logits, caches2 = decode_step(params, caches, db, cfg, RUN)
    assert logits.shape[:2] == (B, 1)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize(
    "name",
    ["hymba-1.5b", "deepseek-v2-236b", "musicgen-medium", "smollm-135m",
     "mamba2-1.3b"],
)
def test_decode_matches_teacher_forcing(name):
    """Prefill + decode logits == fresh full-forward logits (cache logic,
    ring SWA, MLA absorption, SSD state, sinusoidal offsets)."""
    cfg = SMOKES[name]
    if cfg.moe:  # capacity drops are legitimate differences; remove them
        cfg = cfg.scaled(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    B, S, STEPS = 2, 128, 2
    full = _batch(cfg, B, S + STEPS, jax.random.PRNGKey(1), labels=False)

    def cut(n):
        return {k: v[:, :n] for k, v in full.items()}

    def one(i):
        return {k: v[:, i : i + 1] for k, v in full.items()}

    _, caches = prefill(params, cut(S), cfg, RUN, cache_len=S + STEPS)
    for t in range(STEPS):
        pos = S + t
        db = dict(one(pos))
        db["pos"] = jnp.int32(pos)
        logits_dec, caches = decode_step(params, caches, db, cfg, RUN)
        ref, _ = prefill(params, cut(pos + 1), cfg, RUN)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0]), np.asarray(ref[:, 0]), atol=2e-3
        )


def test_full_configs_param_counts():
    """Full configs' parameter counts match the advertised sizes (via
    eval_shape — no allocation)."""
    run = RunConfig()
    expect = {  # billions, generous brackets (embeddings/vocab padding vary)
        "smollm-135m": (0.12, 0.16),
        "stablelm-1.6b": (1.2, 1.9),
        "starcoder2-7b": (6.0, 8.0),
        "qwen1.5-32b": (28.0, 37.0),  # assignment MHA kv=40 (> real kv=8)
        "hymba-1.5b": (1.2, 2.0),
        "mamba2-1.3b": (1.0, 1.6),
        "musicgen-medium": (1.3, 2.2),
        "deepseek-v2-236b": (210.0, 250.0),
        # assignment's 48L/64e/1408ff is larger than real Moonlight (27L):
        "moonshot-v1-16b-a3b": (26.0, 31.0),
        "qwen2-vl-72b": (65.0, 78.0),
    }
    from repro.models import model_init as mi

    for name, (lo, hi) in expect.items():
        cfg = ARCHS[name]
        shapes = jax.eval_shape(
            lambda k: mi(k, cfg, run)[0], jax.random.PRNGKey(0)
        )
        n = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)) / 1e9
        assert lo <= n <= hi, f"{name}: {n:.2f}B outside [{lo},{hi}]"


# ------------------------------------------------------------- unit tests
def test_chunked_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    B, S, H, KH, D = 2, 96, 8, 2, 16  # ragged S (not a chunk multiple)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D))

    def naive(q, k, v, window=None):
        G = H // KH
        kk = jnp.repeat(k, G, axis=2)
        vv = jnp.repeat(v, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * D**-0.5
        i, j = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
        mask = j <= i
        if window:
            mask &= j > i - window
        s = jnp.where(mask, s, -1e30)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)

    for window in (None, 24):
        for cq, ck in ((32, 32), (16, 64), (96, 96)):
            # repeat k along groups: naive uses kh-major grouping like impl
            out = chunked_attention(
                q, k, v, causal=True, window=window, chunk_q=cq, chunk_k=ck
            )
            # impl groups q as (KH, G); naive repeats kv G-per-kh: reorder q
            qg = q.reshape(B, S, KH, H // KH, D).reshape(B, S, H, D)
            ref = naive(qg, k, v, window)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=2e-5
            )


def test_ssd_scan_matches_reference():
    key = jax.random.PRNGKey(3)
    B, S, H, P, G, N = 2, 80, 4, 8, 2, 16  # ragged S
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    for chunk in (16, 32, 80):
        y, h = ssd_scan(x, dt, A, Bm, Cm, chunk, return_state=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=2e-4)


def test_int8_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32)) * 3.0
    q, s = quantize_kv(x)
    y = dequantize_kv(q, s, jnp.float32)
    err = jnp.abs(y - x).max() / jnp.abs(x).max()
    assert float(err) < 0.02  # ~1/127 relative


def test_int8_kv_decode_close_to_bf16():
    cfg = SMOKES["qwen1.5-32b"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    run8 = dataclasses.replace(RUN, kv_cache_dtype="int8")
    B, S = 2, 64
    full = _batch(cfg, B, S + 1, jax.random.PRNGKey(1), labels=False)
    cut = {k: v[:, :S] for k, v in full.items()}
    one = {k: v[:, S : S + 1] for k, v in full.items()}
    outs = {}
    for label, run in (("fp32", RUN), ("int8", run8)):
        _, caches = prefill(params, cut, cfg, run, cache_len=S + 1)
        db = dict(one)
        db["pos"] = jnp.int32(S)
        logits, _ = decode_step(params, caches, db, cfg, run)
        outs[label] = np.asarray(logits[:, 0, : cfg.vocab])
    # int8 KV must preserve the argmax and stay close in logit space
    assert (outs["fp32"].argmax(-1) == outs["int8"].argmax(-1)).all()
    assert np.abs(outs["fp32"] - outs["int8"]).max() < 0.35
