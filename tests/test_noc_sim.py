"""Behaviour tests for the flit-level wormhole NoC simulator."""
import pytest

from repro.core import grid, plan
from repro.noc import (
    DEST_RANGES,
    NoCConfig,
    WormholeSim,
    parsec_workload,
    simulate,
    synthetic_workload,
)

CFG = NoCConfig()
G = grid(8)


def test_zero_load_unicast_latency():
    """Unobstructed wormhole latency = hops + F - 2 in this model
    (one-cycle header per hop, tail F-1 flits behind, same-cycle ejection)."""
    for src, dst in [((0, 0), (2, 2)), ((0, 0), (7, 7)), ((3, 3), (3, 4))]:
        sim = WormholeSim(CFG)
        sim.add_plan(plan("MU", G, src, [dst]), 0)
        st = sim.run(200)
        hops = G.manhattan(src, dst)
        assert st.latencies == [hops + CFG.flits_per_packet - 2]


def test_all_destinations_delivered_every_algorithm():
    wl = synthetic_workload(CFG, 0.03, 400, seed=11)
    for algo in ("MU", "MP", "NMP", "DPM"):
        sim = WormholeSim(CFG)
        expect = 0
        for r in wl.requests:
            p = plan(algo, G, r.src, r.dests)
            sim.add_plan(p, r.time)
            expect += len(r.dests)
        st = sim.run(100_000)
        assert st.packets_created == st.packets_finished
        delivered = sum(len(pk.delivery_times) for pk in sim.packets)
        assert delivered >= expect  # >= because reps absorb + pass-through


def test_flit_conservation():
    """Every flit of every packet traverses every link of its route once."""
    wl = synthetic_workload(CFG, 0.02, 300, seed=7)
    sim = WormholeSim(CFG)
    total_stage_flits = 0
    for r in wl.requests:
        p = plan("DPM", G, r.src, r.dests)
        sim.add_plan(p, r.time)
    st = sim.run(100_000)
    total_stage_flits = sum(
        pk.num_stages * CFG.flits_per_packet for pk in sim.packets
    )
    assert st.flit_link_traversals == total_stage_flits
    assert st.buffer_writes == total_stage_flits  # one write per traversal


def test_wormhole_serialization_on_shared_link():
    """Two packets over the same link: second header waits (1 flit/link/cyc)."""
    sim = WormholeSim(CFG)
    sim.add_plan(plan("MU", G, (0, 0), [(4, 0)]), 0)
    sim.add_plan(plan("MU", G, (0, 0), [(4, 0)]), 0)
    st = sim.run(200)
    lats = sorted(st.latencies)
    base = 4 + CFG.flits_per_packet - 2
    assert lats[0] == base
    # second packet's header must wait for 4 flits of the first
    assert lats[1] >= base + CFG.flits_per_packet - 1


def test_multicast_chain_delivery_order():
    """Path-based chain delivers in path order with increasing times."""
    dests = [(2, 0), (5, 0), (7, 0)]
    sim = WormholeSim(CFG)
    sim.add_plan(plan("MP", G, (0, 0), dests), 0)
    sim.run(500)
    pk = next(p for p in sim.packets if len(p.deliveries) > 1)
    times = [pk.delivery_times[d] for d in dests if d in pk.delivery_times]
    assert times == sorted(times)


def test_dpm_child_released_after_parent_header():
    sim = WormholeSim(CFG)
    # far-apart clusters force MU-mode children somewhere
    dests = [(6, 6), (7, 6), (6, 7), (1, 1), (0, 1), (1, 0)]
    sim.add_plan(plan("DPM", G, (3, 3), dests), 0)
    st = sim.run(2000)
    assert st.packets_created == st.packets_finished
    for pk in sim.packets:
        if pk.parent is not None:
            par = sim.packets[pk.parent]
            assert par.header_times[pk.hops[0]] < pk.delivery_times[pk.hops[-1]]


def test_deterministic_given_seed():
    wl1 = synthetic_workload(CFG, 0.03, 300, seed=5)
    wl2 = synthetic_workload(CFG, 0.03, 300, seed=5)
    s1 = simulate(CFG, wl1, "DPM")
    s2 = simulate(CFG, wl2, "DPM")
    assert s1.latencies == s2.latencies
    assert s1.flit_link_traversals == s2.flit_link_traversals


def test_latency_ordering_medium_load():
    """Paper Fig 6 qualitative claim at a mid-load point: DPM/NMP < MP < MU
    fails only if the sim regresses badly; exact margins live in benchmarks."""
    cfg = NoCConfig(dest_range=(4, 8))
    wl = synthetic_workload(cfg, 0.05, 800, seed=3)
    lat = {a: simulate(cfg, wl, a).avg_latency for a in ("MU", "MP", "NMP", "DPM")}
    # The paper's core latency claim: DPM beats every baseline.
    assert lat["DPM"] < lat["MP"]
    assert lat["DPM"] < lat["MU"]
    assert lat["DPM"] < lat["NMP"] * 1.1  # parity-or-better vs idealized NMP


def test_power_counters_track_hops():
    cfg = NoCConfig()
    wl = synthetic_workload(cfg, 0.04, 400, seed=9)
    st_mu = simulate(cfg, wl, "MU")
    st_dpm = simulate(cfg, wl, "DPM")
    e = cfg.energy
    # DPM's whole point: fewer flit-hops => less dynamic energy than MU
    assert st_dpm.dyn_energy_pj(e) < st_mu.dyn_energy_pj(e)


def test_parsec_trace_stable_across_processes():
    """fig8 regression: the per-benchmark seed must come from a stable digest
    (zlib.crc32), not salted ``hash(str)`` — pin a literal trace prefix so a
    PYTHONHASHSEED-style nondeterminism can never creep back in."""
    cfg = NoCConfig()
    wl = parsec_workload(cfg, "blackscholes", 400, seed=1)
    assert len(wl.requests) == 374
    prefix = [(r.time, r.src, tuple(r.dests)) for r in wl.requests[:5]]
    assert prefix == [
        (0, (3, 0), ((1, 6),)),
        (0, (2, 1), ((7, 6),)),
        (2, (4, 5), ((7, 2),)),
        (4, (5, 4), ((5, 5),)),
        (5, (2, 3), ((7, 3),)),
    ]
    wl2 = parsec_workload(cfg, "fluidanimate", 300, seed=7)
    assert len(wl2.requests) == 783
    r0 = wl2.requests[0]
    assert (r0.time, r0.src, tuple(r0.dests)) == (0, (7, 0), ((0, 3),))


@pytest.mark.parametrize("bench", ["blackscholes", "fluidanimate"])
def test_parsec_workloads_run(bench):
    cfg = NoCConfig()
    wl = parsec_workload(cfg, bench, 400, seed=1)
    assert wl.requests, "trace must generate traffic"
    st = simulate(cfg, wl, "DPM")
    assert st.packets_created == st.packets_finished


@pytest.mark.parametrize("dr", DEST_RANGES)
def test_all_dest_ranges_drain(dr):
    cfg = NoCConfig(dest_range=dr)
    wl = synthetic_workload(cfg, 0.02, 300, seed=2)
    st = simulate(cfg, wl, "DPM")
    assert st.packets_created == st.packets_finished
