"""Launch-layer tests: HLO analyzer, mesh/spec builders (1-device view)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo import analyze, collective_bytes


def test_analyzer_counts_scan_trip_counts():
    """cost_analysis() counts a scan body once; analyze() multiplies by the
    trip count (the whole reason the module exists)."""
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)

    def scanned(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None

        h, _ = jax.lax.scan(body, x, w)
        return h

    def unrolled(w, x):
        h = x
        for i in range(8):
            h = jnp.tanh(h @ w[i])
        return h

    a_scan = analyze(jax.jit(scanned).lower(w, x).compile().as_text())
    a_unrl = analyze(jax.jit(unrolled).lower(w, x).compile().as_text())
    expect = 2 * 32 * 128 * 128 * 8
    assert abs(a_scan["flops"] - a_unrl["flops"]) / a_unrl["flops"] < 0.05
    assert a_scan["flops"] >= expect
    xla = jax.jit(scanned).lower(w, x).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax returns [dict]
        xla = xla[0]
    assert xla["flops"] < expect / 4  # demonstrates the undercount


def test_analyzer_dus_inplace():
    """In-place cache update: bytes ~ update size, not buffer size."""
    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (5, 0))

    a = analyze(jax.jit(f, donate_argnums=0).lower(buf, upd).compile().as_text())
    assert a["bytes"] < 1024 * 1024 * 4 / 4  # far less than the full buffer


def test_collective_bytes_on_sharded_program():
    devs = jax.device_count()
    if devs < 2:
        pytest.skip("needs >1 device (run under forced host device count)")


def test_production_mesh_requires_512_devices():
    """make_production_mesh needs the dry-run env; verify the error path."""
    from repro.launch.mesh import make_production_mesh

    if jax.device_count() >= 512:
        m = make_production_mesh()
        assert m.shape == {"data": 16, "model": 16}
    else:
        with pytest.raises(Exception):
            make_production_mesh()


def test_model_flops_accounting():
    from repro.configs import ARCHS, SHAPES
    from repro.launch.specs import model_flops, param_counts
    from repro.models import RunConfig

    run = RunConfig()
    c = param_counts(ARCHS["deepseek-v2-236b"], run)
    # active ~ 21-22B of 236B for top-6/160 + shared
    assert 15e9 < c["active"] < 35e9 < 200e9 < c["total"] < 250e9
    mf_train = model_flops(ARCHS["smollm-135m"], SHAPES["train_4k"], run)
    n = param_counts(ARCHS["smollm-135m"], run)["total"]
    assert abs(mf_train - 6 * n * 256 * 4096) / mf_train < 1e-6
