"""serve layer tests: greedy generate determinism, BatchServer batch
formation (max_batch cutoff, left-pad alignment, per-request slicing, rid
routing), deterministic plan reuse across serve_once calls, the shared
``take_batch`` deadline-batching primitive + close/drain lifecycle, and the
streaming ``PlanServer`` over the device plan arena."""
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core import arena_clear, grid, plan, plan_cache_clear
from repro.models import RunConfig, model_init
from repro.serve import PlanServer
from repro.serve.engine import BatchServer, Request, generate, take_batch

RUN = RunConfig(
    remat="none",
    attn_chunk_q=32,
    attn_chunk_k=32,
    vocab_round=64,
    activations_dtype="float32",
    kv_cache_dtype="float32",
)


@pytest.fixture(scope="module")
def tiny():
    cfg = SMOKES["smollm-135m"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    return params, cfg


def _prompts(cfg, B, S, seed):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)


# ----------------------------------------------------------------- generate
def test_generate_shapes_and_greedy_determinism(tiny):
    params, cfg = tiny
    prompts = _prompts(cfg, 2, 8, seed=1)
    r1 = generate(params, cfg, RUN, prompts, steps=5)
    r2 = generate(params, cfg, RUN, prompts, steps=5)
    assert r1.tokens.shape == (2, 5)
    assert r1.tokens.dtype == np.int32
    assert (0 <= r1.tokens).all() and (r1.tokens < cfg.vocab).all()
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy is pure
    assert r1.prefill_ms > 0 and r1.decode_ms_per_token > 0


def test_generate_temperature_uses_seed(tiny):
    params, cfg = tiny
    prompts = _prompts(cfg, 2, 8, seed=2)
    a = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=3)
    b = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=3)
    np.testing.assert_array_equal(a.tokens, b.tokens)  # seeded sampling
    c = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=4)
    assert not np.array_equal(a.tokens, c.tokens)


# -------------------------------------------------------------- BatchServer
def test_batch_server_formation_and_slicing(tiny):
    """max_batch caps the first batch, the rest drain on the next call;
    every response carries its request id and exactly max_tokens tokens."""
    params, cfg = tiny
    srv = BatchServer(params, cfg, RUN, max_batch=3, max_wait_s=0.01)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4 + i),
                max_tokens=2 + (i % 3))
        for i in range(5)
    ]
    for r in reqs:
        srv.submit(r)
    first = srv.serve_once()
    second = srv.serve_once()
    assert [r.rid for r in first] == [0, 1, 2]  # FIFO, cut at max_batch
    assert [r.rid for r in second] == [3, 4]
    for resp in first + second:
        want = reqs[resp.rid].max_tokens
        assert resp.tokens.shape == (want,)  # sliced per request
        assert resp.latency_s >= 0
    assert srv.stats["batches"] == 2
    assert srv.stats["requests"] == 5
    # tokens counts padded batch work: B * max(max_tokens) per batch
    assert srv.stats["tokens"] == 3 * max(2, 3, 4) + 2 * max(2, 3)


def test_batch_server_left_pads_to_longest(tiny):
    """Prompts of unequal length align on the last token (left padding), so
    a request batched with longer peers still decodes from its own final
    prompt token — pinned by comparing against a pad-free solo batch of the
    same aligned layout."""
    params, cfg = tiny
    prompt = np.asarray(_prompts(cfg, 1, 6, seed=5)[0])
    srv = BatchServer(params, cfg, RUN, max_batch=2, max_wait_s=0.01)
    srv.submit(Request(rid=0, prompt=prompt, max_tokens=3))
    srv.submit(Request(rid=1, prompt=prompt[2:], max_tokens=3))
    r0, r1 = srv.serve_once()
    padded = np.zeros((1, 6), np.int32)
    padded[0, 2:] = prompt[2:]
    solo = generate(params, cfg, RUN, jnp.asarray(padded), steps=3)
    np.testing.assert_array_equal(r1.tokens, solo.tokens[0])
    solo0 = generate(params, cfg, RUN, jnp.asarray(prompt[None]), steps=3)
    np.testing.assert_array_equal(r0.tokens, solo0.tokens[0])


def test_batch_server_reuse_is_deterministic(tiny):
    """Identical request batches produce identical tokens across serve_once
    calls — the jitted prefill/decode plans are reused, never re-randomized."""
    params, cfg = tiny
    prompt = np.asarray(_prompts(cfg, 1, 8, seed=6)[0])
    srv = BatchServer(params, cfg, RUN, max_batch=2, max_wait_s=0.01)
    outs = []
    for _ in range(2):
        srv.submit(Request(rid=0, prompt=prompt, max_tokens=4))
        srv.submit(Request(rid=1, prompt=prompt[::-1].copy(), max_tokens=4))
        outs.append(srv.serve_once())
    for a, b in zip(outs[0], outs[1]):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert srv.stats == {"batches": 2, "requests": 4, "tokens": 16}


# --------------------------------------------------------------- take_batch
def test_take_batch_cuts_at_max_batch_then_drains():
    q = queue.Queue()
    for i in range(5):
        q.put(i)
    assert take_batch(q, 3, 0.01) == [0, 1, 2]
    assert take_batch(q, 8, 0.01) == [3, 4]


def test_take_batch_stop_event_drains_then_returns_empty():
    q = queue.Queue()
    stop = threading.Event()
    stop.set()
    q.put("x")  # items queued before the stop still form a batch
    assert take_batch(q, 4, 0.01, stop=stop) == ["x"]
    assert take_batch(q, 4, 0.01, stop=stop) == []  # stopped + empty


def test_batch_server_queue_depth_and_close_drain(tiny):
    params, cfg = tiny
    srv = BatchServer(params, cfg, RUN, max_batch=4, max_wait_s=0.01)
    rng = np.random.default_rng(1)
    for i in range(3):
        srv.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=5),
                           max_tokens=2))
    assert srv.queue_depth == 3 and not srv.closed
    out = srv.close(drain=True)
    assert [r.rid for r in out] == [0, 1, 2]  # queued work served out
    assert srv.closed and srv.queue_depth == 0
    with pytest.raises(RuntimeError):
        srv.submit(Request(rid=9, prompt=rng.integers(0, cfg.vocab, size=5),
                           max_tokens=1))
    assert srv.serve_once() == []  # closed + drained: returns, no block


def test_batch_server_close_without_drain_drops_queue(tiny):
    params, cfg = tiny
    srv = BatchServer(params, cfg, RUN, max_batch=4, max_wait_s=0.01)
    srv.submit(Request(rid=0, prompt=np.zeros(4, np.int32), max_tokens=1))
    assert srv.close(drain=False) == []
    assert srv.queue_depth == 0 and srv.stats["requests"] == 0


# --------------------------------------------------------------- PlanServer
@pytest.fixture()
def _fresh_arena():
    plan_cache_clear()
    arena_clear()
    yield
    plan_cache_clear()
    arena_clear()


def test_plan_server_futures_match_host_plan(_fresh_arena):
    g = grid(4)
    reqs = [((0, 0), [(3, 3), (1, 2)]), ((2, 2), [(0, 3)])]
    with PlanServer(g, "DPM", max_wait_s=0.01) as ps:
        futs = [ps.submit(src, dests) for src, dests in reqs]
        plans = [f.result(timeout=60) for f in futs]
    for p, (src, dests) in zip(plans, reqs):
        assert p == plan("DPM", g, src, dests)
    assert ps.closed
    with pytest.raises(RuntimeError):
        ps.submit((0, 0), [(1, 1)])
    assert ps.stats["requests"] == 2


def test_plan_server_prefetch_warms_arena(_fresh_arena):
    g = grid(4)
    reqs = [((0, 0), ((1, 3), (2, 2))), ((3, 0), ((0, 2),))]
    with PlanServer(g, "DPM", max_wait_s=0.005) as ps:
        ps.prefetch(reqs)
        deadline = time.monotonic() + 60
        while ps.info().misses < len(reqs) and time.monotonic() < deadline:
            time.sleep(0.01)
        before = ps.info().misses
        p = ps.plan(*reqs[0])  # arena hit — prefetch already decoded it
    assert p == plan("DPM", g, reqs[0][0], list(reqs[0][1]))
    assert ps.info().misses == before
    assert ps.info().hits >= 1


def test_plan_server_close_drains_pending_futures(_fresh_arena):
    g = grid(4)
    ps = PlanServer(g, "DPM", max_wait_s=0.001)
    futs = [ps.submit((0, 0), [((i % 3) + 1, 3)]) for i in range(8)]
    ps.close(drain=True)
    assert all(f.result(timeout=5) is not None for f in futs)
    assert ps.stats["requests"] == 8


def test_plan_server_propagates_planning_errors(_fresh_arena):
    g = grid(4)
    with PlanServer(g, "DPM", max_wait_s=0.001) as ps:
        bad = ps.submit((0, 0), [(9, 9)])  # off-fabric destination
        with pytest.raises(Exception):
            bad.result(timeout=60)
        ok = ps.submit((0, 0), [(1, 1)])  # the worker keeps serving
        assert ok.result(timeout=60) == plan("DPM", g, (0, 0), [(1, 1)])
