"""serve/engine.py tests: greedy generate determinism, BatchServer batch
formation (max_batch cutoff, left-pad alignment, per-request slicing, rid
routing), and deterministic plan reuse across serve_once calls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.models import RunConfig, model_init
from repro.serve.engine import BatchServer, Request, generate

RUN = RunConfig(
    remat="none",
    attn_chunk_q=32,
    attn_chunk_k=32,
    vocab_round=64,
    activations_dtype="float32",
    kv_cache_dtype="float32",
)


@pytest.fixture(scope="module")
def tiny():
    cfg = SMOKES["smollm-135m"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    return params, cfg


def _prompts(cfg, B, S, seed):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab)


# ----------------------------------------------------------------- generate
def test_generate_shapes_and_greedy_determinism(tiny):
    params, cfg = tiny
    prompts = _prompts(cfg, 2, 8, seed=1)
    r1 = generate(params, cfg, RUN, prompts, steps=5)
    r2 = generate(params, cfg, RUN, prompts, steps=5)
    assert r1.tokens.shape == (2, 5)
    assert r1.tokens.dtype == np.int32
    assert (0 <= r1.tokens).all() and (r1.tokens < cfg.vocab).all()
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy is pure
    assert r1.prefill_ms > 0 and r1.decode_ms_per_token > 0


def test_generate_temperature_uses_seed(tiny):
    params, cfg = tiny
    prompts = _prompts(cfg, 2, 8, seed=2)
    a = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=3)
    b = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=3)
    np.testing.assert_array_equal(a.tokens, b.tokens)  # seeded sampling
    c = generate(params, cfg, RUN, prompts, steps=8, temperature=1.5, seed=4)
    assert not np.array_equal(a.tokens, c.tokens)


# -------------------------------------------------------------- BatchServer
def test_batch_server_formation_and_slicing(tiny):
    """max_batch caps the first batch, the rest drain on the next call;
    every response carries its request id and exactly max_tokens tokens."""
    params, cfg = tiny
    srv = BatchServer(params, cfg, RUN, max_batch=3, max_wait_s=0.01)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=4 + i),
                max_tokens=2 + (i % 3))
        for i in range(5)
    ]
    for r in reqs:
        srv.submit(r)
    first = srv.serve_once()
    second = srv.serve_once()
    assert [r.rid for r in first] == [0, 1, 2]  # FIFO, cut at max_batch
    assert [r.rid for r in second] == [3, 4]
    for resp in first + second:
        want = reqs[resp.rid].max_tokens
        assert resp.tokens.shape == (want,)  # sliced per request
        assert resp.latency_s >= 0
    assert srv.stats["batches"] == 2
    assert srv.stats["requests"] == 5
    # tokens counts padded batch work: B * max(max_tokens) per batch
    assert srv.stats["tokens"] == 3 * max(2, 3, 4) + 2 * max(2, 3)


def test_batch_server_left_pads_to_longest(tiny):
    """Prompts of unequal length align on the last token (left padding), so
    a request batched with longer peers still decodes from its own final
    prompt token — pinned by comparing against a pad-free solo batch of the
    same aligned layout."""
    params, cfg = tiny
    prompt = np.asarray(_prompts(cfg, 1, 6, seed=5)[0])
    srv = BatchServer(params, cfg, RUN, max_batch=2, max_wait_s=0.01)
    srv.submit(Request(rid=0, prompt=prompt, max_tokens=3))
    srv.submit(Request(rid=1, prompt=prompt[2:], max_tokens=3))
    r0, r1 = srv.serve_once()
    padded = np.zeros((1, 6), np.int32)
    padded[0, 2:] = prompt[2:]
    solo = generate(params, cfg, RUN, jnp.asarray(padded), steps=3)
    np.testing.assert_array_equal(r1.tokens, solo.tokens[0])
    solo0 = generate(params, cfg, RUN, jnp.asarray(prompt[None]), steps=3)
    np.testing.assert_array_equal(r0.tokens, solo0.tokens[0])


def test_batch_server_reuse_is_deterministic(tiny):
    """Identical request batches produce identical tokens across serve_once
    calls — the jitted prefill/decode plans are reused, never re-randomized."""
    params, cfg = tiny
    prompt = np.asarray(_prompts(cfg, 1, 8, seed=6)[0])
    srv = BatchServer(params, cfg, RUN, max_batch=2, max_wait_s=0.01)
    outs = []
    for _ in range(2):
        srv.submit(Request(rid=0, prompt=prompt, max_tokens=4))
        srv.submit(Request(rid=1, prompt=prompt[::-1].copy(), max_tokens=4))
        outs.append(srv.serve_once())
    for a, b in zip(outs[0], outs[1]):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert srv.stats == {"batches": 2, "requests": 4, "tokens": 16}
