"""Topology-layer tests: Torus geometry/routing, planners on the torus,
the repro.dist.multicast scheduler, and the wrap=True Pallas cost table."""
import random

import numpy as np
import pytest

from repro.core import (
    PLANNERS,
    MeshGrid,
    candidate_cost,
    grid,
    make_topology,
    plan,
    ring_delta,
    torus,
    xy_route,
)
from repro.core.partition import ALL_CANDIDATE_IDS, basic_partitions
from repro.dist.multicast import (
    Torus,
    dp_broadcast_schedule,
    plan_torus_multicast,
    schedule_multicasts,
)

T8 = torus(8)
G8 = grid(8)


def _nodes(t):
    return [(x, y) for x in range(t.n) for y in range(t.rows)]


def _instances(t, count, kmax, seed):
    rng = random.Random(seed)
    nodes = _nodes(t)
    for _ in range(count):
        picks = rng.sample(nodes, rng.randint(3, kmax + 1))
        yield picks[0], picks[1:]


# ---------------------------------------------------------------- geometry
@pytest.mark.parametrize("dims", [(8, 8), (5, 7), (16, 16), (8, 1)])
def test_torus_delta_is_shortest_wrap(dims):
    """Wrap legs are valid displacements and never longer than non-wrap."""
    t = torus(*dims)
    rng = random.Random(1)
    nodes = _nodes(t)
    for _ in range(300):
        a, b = rng.choice(nodes), rng.choice(nodes)
        dx, dy = t.delta(a, b)
        assert (a[0] + dx) % t.n == b[0] and (a[1] + dy) % t.rows == b[1]
        assert abs(dx) <= t.n // 2 and abs(dy) <= t.rows // 2
        assert t.distance(a, b) <= MeshGrid.manhattan(a, b)
        assert t.distance(a, b) == t.distance(b, a)


def test_ring_delta_matches_kernel_convention():
    """Half-way ties break negative, exactly like the wrap=True kernel."""
    for size in (2, 4, 8, 16):
        assert ring_delta(size // 2, size) == -size // 2
    for size in (1, 2, 3, 5, 8):
        for d in range(-size, size + 1):
            r = ring_delta(d, size)
            assert (d - r) % size == 0 if size > 1 else r == 0


@pytest.mark.parametrize("dims", [(8, 8), (6, 4), (3, 3)])
def test_torus_xy_route_shortest_and_adjacent(dims):
    t = torus(*dims)
    rng = random.Random(2)
    nodes = _nodes(t)
    for _ in range(200):
        a, b = rng.choice(nodes), rng.choice(nodes)
        path = xy_route(t, a, b)
        assert path[0] == a and path[-1] == b
        assert len(path) - 1 == t.distance(a, b)
        for u, v in zip(path, path[1:]):
            assert v in t.neighbors(*u)


def test_torus_neighbors_degree_and_ring_degeneration():
    for (x, y) in _nodes(torus(8)):
        assert len(torus(8).neighbors(x, y)) == 4
    ring = torus(8, 1)
    assert ring.neighbors(0, 0) == [(1, 0), (7, 0)]
    assert ring.distance((0, 0), (7, 0)) == 1


def test_basic_partitions_wedges_on_torus():
    """Partition membership is the sign pattern of the shortest delta."""
    src = (0, 0)
    dests = [d for d in _nodes(T8) if d != src]
    parts = basic_partitions(src, dests, T8)
    flat = [d for p in parts for d in p]
    assert sorted(flat) == sorted(dests)  # disjoint exact cover
    for i, p in enumerate(parts):
        for d in p:
            dx, dy = T8.delta(src, d)
            expect = [
                dx > 0 and dy > 0, dx == 0 and dy > 0, dx < 0 and dy > 0,
                dx < 0 and dy == 0, dx < 0 and dy < 0, dx == 0 and dy < 0,
                dx > 0 and dy < 0, dx > 0 and dy == 0,
            ]
            assert expect[i]
    # (7, 0) is one wrap hop left of the source: P3, not P7
    assert (7, 0) in parts[3]


# ---------------------------------------------------------------- planners
@pytest.mark.parametrize("algo", list(PLANNERS))
def test_planners_cover_on_torus(algo):
    for src, dests in _instances(T8, 40, 12, seed=len(algo)):
        p = plan(algo, T8, src, dests)
        assert p.check_covers(), (algo, src, dests)
        for path in p.paths:  # hop-adjacency under torus links
            for a, b in zip(path.hops, path.hops[1:]):
                assert b in T8.neighbors(*a)


def test_torus_dpm_beats_mesh_dpm_on_wrapped_sets():
    """Wraparound shortcuts must pay off: clearly on an edge-hugging set,
    and in aggregate over random instances (per-instance the greedy
    heuristic may occasionally flip)."""
    src, dests = (0, 0), [(7, 0), (0, 7), (7, 7), (6, 1), (1, 6)]
    assert plan("DPM", T8, src, dests).total_hops < plan("DPM", G8, src, dests).total_hops
    tot_t = tot_m = 0
    for src, dests in _instances(T8, 100, 10, seed=3):
        tot_t += plan("DPM", T8, src, dests).total_hops
        tot_m += plan("DPM", G8, src, dests).total_hops
    assert tot_t <= tot_m


def test_planner_cache_normalized_and_topology_keyed():
    assert grid(8) is grid(8, 8)
    assert torus(8) is torus(8, 8)
    assert make_topology("torus", 8).kind == "torus"
    src, dests = (0, 0), [(7, 0)]
    pm = plan("MU", grid(8), src, dests)
    pt = plan("MU", torus(8), src, dests)
    assert pm.paths[0].hop_count == 7
    assert pt.paths[0].hop_count == 1  # no mesh/torus cache collision
    assert plan("MU", grid(8, 8), src, dests) is pm  # one entry per geometry


# ---------------------------------------------------------------- dist layer
def test_plan_torus_multicast_covers():
    t = Torus(16, 16)
    for src, dests in _instances(t, 25, 10, seed=7):
        assert plan_torus_multicast(t, src, dests).check_covers()


def test_schedule_multicasts_delivers_all_with_causality():
    t = Torus(16, 16)
    rng = random.Random(9)
    nodes = _nodes(t)
    reqs = []
    for _ in range(8):
        picks = rng.sample(nodes, rng.randint(4, 9))
        reqs.append((picks[0], picks[1:]))
    sched = schedule_multicasts(t, reqs)
    have = [{t.idx(s)} for s, _ in reqs]
    for rnd, rr in zip(sched.rounds, sched.round_reqs):
        senders = [s for s, _ in rnd]
        receivers = [d for _, d in rnd]
        # one ppermute per round: unique senders, unique receivers
        assert len(set(senders)) == len(senders)
        assert len(set(receivers)) == len(receivers)
        # store-and-forward causality per request
        for (s, d), rid in zip(rnd, rr):
            assert s in have[rid]
        for (s, d), rid in zip(rnd, rr):
            have[rid].add(d)
    for rid, (src, dests) in enumerate(reqs):
        assert {t.idx(d) for d in dests} <= have[rid]


@pytest.mark.parametrize("algo", ["MU", "DP", "DPM"])
def test_dp_broadcast_schedule_reaches_all_ranks(algo):
    for nr in (2, 4, 8, 16):
        sched = dp_broadcast_schedule(nr, algo)
        have = {0}
        for rnd in sched.rounds:
            assert all(s in have for s, _ in rnd)
            have |= {d for _, d in rnd}
        assert have == set(range(nr))


def test_dpm_ring_broadcast_beats_mu_rounds_and_hops():
    mu = dp_broadcast_schedule(16, "MU")
    dpm = dp_broadcast_schedule(16, "DPM")
    assert dpm.num_rounds < mu.num_rounds  # two relay chains vs serial sends
    assert dpm.total_hops < mu.total_hops
    c_mu, c_dpm = mu.cost(2**20), dpm.cost(2**20)
    assert c_dpm["time_us"] < c_mu["time_us"]
    assert c_dpm["link_bytes"] < c_mu["link_bytes"]


# ---------------------------------------------------------------- simulator
def test_wormhole_sim_on_torus_dpm_beats_mu():
    from repro.noc import NoCConfig, WormholeSim

    cfg = NoCConfig(topology="torus")
    src, dests = (0, 0), [(7, 7), (7, 0), (0, 7), (6, 6), (1, 7)]
    flits = {}
    for algo in ("MU", "DPM"):
        sim = WormholeSim(cfg)
        sim.add_plan(plan(algo, torus(8), src, dests), 0)
        st = sim.run(5000)
        assert st.packets_created == st.packets_finished
        flits[algo] = st.flit_link_traversals
    assert flits["DPM"] < flits["MU"]


def test_torus_workload_drains():
    from repro.noc import NoCConfig, simulate, synthetic_workload

    cfg = NoCConfig(topology="torus")
    wl = synthetic_workload(cfg, 0.02, 300, seed=2)
    st = simulate(cfg, wl, "DPM")
    assert st.packets_created == st.packets_finished


# ---------------------------------------------------------------- kernels
def _mask_instances(t, P, seed):
    import jax.numpy as jnp

    rng = random.Random(seed)
    nodes = _nodes(t)
    masks, sxy, insts = [], [], []
    for _ in range(P):
        k = rng.randint(1, min(14, len(nodes) - 1))
        picks = rng.sample(nodes, k + 1)
        src, dests = picks[0], picks[1:]
        row = np.zeros(t.num_nodes, np.int32)
        for d in dests:
            row[t.idx(d)] = 1
        masks.append(row)
        sxy.append(src)
        insts.append((src, dests))
    return (
        jnp.array(np.stack(masks)),
        jnp.array(np.array(sxy, np.int32)),
        insts,
    )


@pytest.mark.parametrize("dims", [(8, 8), (6, 4), (5, 5)])
@pytest.mark.parametrize("leg", [True, False])
def test_dpm_cost_wrap_kernel_vs_ref_and_host(dims, leg):
    """wrap=True Pallas table == jnp oracle == host planner C_t on the torus."""
    from repro.kernels.dpm_cost.dpm_cost import dpm_cost_table
    from repro.kernels.dpm_cost.ref import dpm_cost_table_ref

    n, m = dims
    t = torus(n, m)
    masks, sxy, insts = _mask_instances(t, 16, seed=n * 31 + m + leg)
    ck, rk = dpm_cost_table(
        masks, sxy, n=n, m=m, wrap=True, include_source_leg=leg,
        interpret=True, tile=8,
    )
    cr, rr = dpm_cost_table_ref(
        masks, sxy, n=n, m=m, wrap=True, include_source_leg=leg
    )
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    for p, (src, dests) in enumerate(insts):
        parts = basic_partitions(src, dests, t)
        for ci, ids in enumerate(ALL_CANDIDATE_IDS):
            union = [d for i in ids for d in parts[i]]
            cc = candidate_cost(t, src, ids, union)
            host = (cc.cost_mu + (cc.source_leg if leg else 0)) if union else 0
            assert host == int(ck[p, ci]), (dims, leg, p, ids)
            if union:
                assert int(rk[p, ci]) == t.idx(cc.rep)


def test_dpm_plan_wrap_covers_nonempty_partitions():
    from repro.kernels.dpm_cost.dpm_cost import CANDS
    from repro.kernels.dpm_cost.ops import dpm_plan

    t = torus(8)
    masks, sxy, insts = _mask_instances(t, 32, seed=13)
    chosen, costs, reps = dpm_plan(masks, sxy, n=8, wrap=True, interpret=True)
    bits = np.array([sum(1 << i for i in ids) for ids in CANDS])
    for p, (src, dests) in enumerate(insts):
        parts = basic_partitions(src, dests, t)
        nonempty = sum(1 << i for i in range(8) if parts[i])
        cover = 0
        for ci in np.where(np.asarray(chosen[p]))[0]:
            assert cover & bits[ci] & nonempty == 0  # disjoint
            cover |= bits[ci]
        assert cover & nonempty == nonempty  # exact cover


# ------------------------------------------- conformance (all registered kinds)
# Property suite over every registered topology kind: any new kind must add a
# representative fabric here, and the coverage test fails until it does. Uses
# hypothesis (or the conftest shim) with integer seeds only — the shim's
# @given wrapper takes no pytest-injected parameters, so kinds are looped
# inside each property body.
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.topo3d import chiplet, mesh3d, torus3d  # noqa: E402
from repro.core.topology import registered_topology_kinds  # noqa: E402

FABRICS = {
    "mesh": grid(5, 4),
    "torus": torus(5, 4),
    "mesh3d": mesh3d(3, 4, 2),
    "torus3d": torus3d(3, 4, 3),
    "chiplet": chiplet(8, 8, 2, 2),
}
_SEED = st.integers(min_value=0, max_value=2**31 - 1)


def test_conformance_fabrics_cover_all_registered_kinds():
    assert set(FABRICS) == set(registered_topology_kinds())
    for kind, g in FABRICS.items():
        assert g.kind == kind


@given(_SEED)
@settings(max_examples=60)
def test_conformance_label_unlabel_roundtrip(seed):
    for g in FABRICS.values():
        lab = seed % g.num_nodes
        c = g.unlabel(lab)
        assert g.label(*c) == lab
        i = (seed * 7919 + 13) % g.num_nodes
        assert g.idx(g.from_idx(i)) == i


@given(_SEED)
@settings(max_examples=60)
def test_conformance_snake_successor_is_neighbor(seed):
    """The label order is a Hamiltonian path — consecutive labels are
    physically adjacent, which is what makes label-monotone dual-path
    routing deadlock-free on every fabric."""
    for g in FABRICS.values():
        lab = seed % (g.num_nodes - 1)
        u, v = g.unlabel(lab), g.unlabel(lab + 1)
        assert v in g.neighbors(*u)


@given(_SEED, _SEED)
@settings(max_examples=60)
def test_conformance_delta_matches_distance(s1, s2):
    for g in FABRICS.values():
        a, b = g.unlabel(s1 % g.num_nodes), g.unlabel(s2 % g.num_nodes)
        dv = g.delta(a, b)
        # the signed displacement lands on b (modulo wrap)
        assert g.normalize(*(c + d for c, d in zip(a, dv))) == b
        l1 = sum(abs(d) for d in dv)
        if g.kind == "chiplet":
            # sparse NoI crossings: BFS distance prices the geometric
            # displacement or more, never less
            assert g.distance(a, b) >= l1
        else:
            assert g.distance(a, b) == l1
        assert g.distance(a, b) == g.distance(b, a)
        assert (g.distance(a, b) == 0) == (a == b)


@given(_SEED)
@settings(max_examples=60)
def test_conformance_neighbors_symmetric(seed):
    for g in FABRICS.values():
        u = g.unlabel(seed % g.num_nodes)
        ns = g.neighbors(*u)
        assert len(ns) == len(set(ns)) and u not in ns
        for v in ns:
            assert u in g.neighbors(*v)
            assert g.distance(u, v) == 1


@given(_SEED, _SEED)
@settings(max_examples=60)
def test_conformance_normalize_idempotent(s1, s2):
    for g in FABRICS.values():
        c = g.unlabel(s1 % g.num_nodes)
        assert g.normalize(*c) == c  # in-range coords are fixed points
        off = (s2 % 7 - 3, (s2 // 7) % 7 - 3) + (0,) * (len(c) - 2)
        w = g.normalize(*(x + k for x, k in zip(c, off)))
        assert g.normalize(*w) == w
