"""Test-suite bootstrap: minimal `hypothesis` fallback.

requirements-dev.txt installs the real hypothesis; some minimal containers
(CPU-only CI images, the repro sandbox) don't have it. Rather than skip the
property tests there, install a tiny shim implementing exactly the subset
this suite uses — @given/@settings and strategies.integers/tuples/lists —
with seeded random sampling. Less exhaustive than real hypothesis (no
shrinking, no database), but the properties still get hundreds of examples.

The shim registers in sys.modules only when the real package is absent, so
environments with hypothesis installed are untouched.
"""
from __future__ import annotations

import importlib.util
import sys

if importlib.util.find_spec("hypothesis") is None:  # pragma: no cover - env-dependent
    import functools
    import random
    import types

    class _Strategy:
        def __init__(self, gen):
            self.gen = gen

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def tuples(*strategies: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.gen(rng) for s in strategies))

    def lists(elem: _Strategy, min_size=0, max_size=10, unique=False) -> _Strategy:
        def gen(rng):
            k = rng.randint(min_size, max_size)
            if not unique:
                return [elem.gen(rng) for _ in range(k)]
            out, seen = [], set()
            for _ in range(100 * max(1, k)):
                v = elem.gen(rng)
                if v not in seen:
                    seen.add(v)
                    out.append(v)
                if len(out) == k:
                    break
            return out

        return _Strategy(gen)

    def given(*strategies: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(
                    wrapper, "_max_examples", getattr(fn, "_max_examples", 100)
                )
                rng = random.Random(fn.__name__)  # deterministic per test
                for _ in range(n):
                    fn(*(s.gen(rng) for s in strategies))

            # wraps() copies __wrapped__, which would make pytest read the
            # original (src, dests, ...) signature and hunt for fixtures.
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples: int = 100, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers, st_mod.tuples, st_mod.lists = integers, tuples, lists
    mod.given, mod.settings, mod.strategies = given, settings, st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
