"""Unit + property tests for the DPM core (grid, routing, Algorithm 1)."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    PLANNERS,
    basic_partitions,
    brute_force_partition,
    candidate_cost,
    dpm_partition,
    dual_path_cost,
    grid,
    label_route,
    multi_unicast_cost,
    plan,
    representative,
    xy_route,
)

G8 = grid(8)


# ---------------------------------------------------------------- labeling
def test_label_roundtrip():
    for y in range(8):
        for x in range(8):
            assert G8.unlabel(G8.label(x, y)) == (x, y)


def test_label_is_hamiltonian_path():
    """Consecutive labels must be mesh neighbors (boustrophedon snake)."""
    for lab in range(G8.num_nodes - 1):
        a, b = G8.unlabel(lab), G8.unlabel(lab + 1)
        assert G8.manhattan(a, b) == 1


def test_paper_labeling_examples():
    # even row y=0: L = x ; odd row y=1 on 8x8: L = 8 + 7 - x
    assert G8.label(0, 0) == 0
    assert G8.label(7, 0) == 7
    assert G8.label(7, 1) == 8
    assert G8.label(0, 1) == 15


# ---------------------------------------------------------------- routing
coord8 = st.tuples(st.integers(0, 7), st.integers(0, 7))


@given(coord8, coord8)
@settings(max_examples=200, deadline=None)
def test_label_route_monotone_and_reaches(s, d):
    if s == d:
        return
    high = G8.label(*d) > G8.label(*s)
    path = label_route(G8, s, d, high)
    assert path[0] == s and path[-1] == d
    labs = [G8.label(*p) for p in path]
    deltas = [labs[i + 1] - labs[i] for i in range(len(labs) - 1)]
    assert all(dd > 0 for dd in deltas) if high else all(dd < 0 for dd in deltas)


@given(coord8, coord8)
@settings(max_examples=200, deadline=None)
def test_xy_route_is_shortest(s, d):
    path = xy_route(G8, s, d)
    assert len(path) - 1 == G8.manhattan(s, d)
    assert path[0] == s and path[-1] == d


# ------------------------------------------------------------- partitions
dest_sets = st.lists(coord8, min_size=1, max_size=16, unique=True)


@given(coord8, dest_sets)
@settings(max_examples=200, deadline=None)
def test_basic_partitions_disjoint_cover(src, dests):
    dests = [d for d in dests if d != src]
    parts = basic_partitions(src, dests)
    flat = [d for p in parts for d in p]
    assert sorted(flat) == sorted(dests)  # disjoint exact cover
    # correct geometric placement
    for i, p in enumerate(parts):
        for (x, y) in p:
            sx, sy = src
            expect = [
                x > sx and y > sy, x == sx and y > sy, x < sx and y > sy,
                x < sx and y == sy, x < sx and y < sy, x == sx and y < sy,
                x > sx and y < sy, x > sx and y == sy,
            ]
            assert expect[i]


@given(coord8, dest_sets)
@settings(max_examples=150, deadline=None)
def test_dpm_invariants(src, dests):
    dests = [d for d in dests if d != src]
    if not dests:
        return
    res = dpm_partition(G8, src, dests)
    # exact cover
    flat = [d for p in res.partitions for d in p.dests]
    assert sorted(flat) == sorted(dests)
    # paper: greedy converges within 4 merge selections
    assert res.iterations <= 4
    # savings recorded were positive
    assert all(a > 0 for _, a in res.savings_trace)
    # every partition chose the cheaper routing mode
    for p in res.partitions:
        assert p.mode == ("MU" if p.cost_mu <= p.cost_dp else "DP")


@given(coord8, dest_sets)
@settings(max_examples=150, deadline=None)
def test_definition2_cost_is_min(src, dests):
    dests = [d for d in dests if d != src]
    if not dests:
        return
    c = candidate_cost(G8, src, (0,), dests)
    rep = representative(G8, src, dests)
    rest = [d for d in dests if d != rep]
    assert c.cost_mu == multi_unicast_cost(G8, rep, rest)
    assert c.cost_dp == dual_path_cost(G8, rep, rest)
    assert c.cost(False) == min(c.cost_mu, c.cost_dp)


@given(coord8, st.lists(coord8, min_size=2, max_size=7, unique=True))
@settings(max_examples=60, deadline=None)
def test_dpm_never_beats_restricted_optimum(src, dests):
    dests = [d for d in dests if d != src]
    if not dests:
        return
    res = dpm_partition(G8, src, dests)
    opt, _ = brute_force_partition(G8, src, dests)
    assert res.total_cost() >= opt


# ---------------------------------------------------------------- planners
@pytest.mark.parametrize("algo", list(PLANNERS))
def test_planners_cover_all_destinations(algo):
    rng = random.Random(42)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    for _ in range(100):
        picks = rng.sample(nodes, rng.randint(3, 17))
        src, dests = picks[0], picks[1:]
        p = plan(algo, G8, src, dests)
        assert p.check_covers(), (algo, src, dests)
        for path in p.paths:  # hop-adjacency of every path
            for a, b in zip(path.hops, path.hops[1:]):
                assert G8.manhattan(a, b) == 1


def test_algorithm_cost_ordering_on_average():
    """Paper claim (hop proxy): DPM <= NMP <= MP <= MU on average."""
    rng = random.Random(7)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    tot = {k: 0 for k in ("MU", "MP", "NMP", "DPM")}
    for _ in range(300):
        picks = rng.sample(nodes, rng.randint(3, 17))
        src, dests = picks[0], picks[1:]
        for k in tot:
            tot[k] += plan(k, G8, src, dests).total_hops
    assert tot["DPM"] <= tot["NMP"] <= tot["MP"] <= tot["MU"]


def test_fig3_example_merges():
    """Fig. 3 of the paper, reconstructed on a 6x6 mesh.

    The text's checkable facts: source node 20; the lower partition's
    representative is node 9 and MU is chosen because C_t == C_p (both 3);
    merging regroups the basic partitions into FOUR final partitions; DPM
    delivers with fewer hops than NMP which beats MP. All four reproduce
    under include_source_leg=True (and merging vanishes entirely under the
    literal Definition 2 — see DESIGN.md §2).
    """
    g6 = grid(6)
    src = g6.unlabel(20)
    assert src == (3, 3)
    dest_labels = [25, 33, 35, 29, 30, 32, 11, 9, 7, 2]
    dests = [g6.unlabel(l) for l in dest_labels]
    res = dpm_partition(g6, src, dests, include_source_leg=True)
    assert len(res.partitions) == 4
    low = next(p for p in res.partitions if 4 in p.ids)
    assert g6.label(*low.rep) == 9
    assert low.mode == "MU" and low.cost_mu == 3 and low.cost_dp == 3
    upper = next(p for p in res.partitions if p.ids == (0, 1))
    assert upper.mode == "DP"  # "a dual-path routing is performed"
    hops = {k: plan(k, g6, src, dests).total_hops for k in ("MP", "NMP", "DPM")}
    assert hops["DPM"] < hops["NMP"] < hops["MP"]
    # literal Definition 2 never merges on this instance
    res_literal = dpm_partition(g6, src, dests, include_source_leg=False)
    assert res_literal.iterations == 0


def test_edge_and_corner_sources():
    """Edge/corner sources have fewer non-empty partitions but still cover."""
    for src in [(0, 0), (7, 7), (0, 3), (3, 0), (7, 3)]:
        dests = [(x, y) for x in range(0, 8, 3) for y in range(0, 8, 3) if (x, y) != src]
        res = dpm_partition(G8, src, dests)
        flat = [d for p in res.partitions for d in p.dests]
        assert sorted(flat) == sorted(dests)
        p = plan("DPM", G8, src, dests)
        assert p.check_covers()


def test_dpm_children_injected_at_representative():
    rng = random.Random(3)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    for _ in range(50):
        picks = rng.sample(nodes, rng.randint(4, 12))
        src, dests = picks[0], picks[1:]
        p = plan("DPM", G8, src, dests)
        for path in p.paths:
            if path.parent is not None:
                parent = p.paths[path.parent]
                # child is injected where the parent path visits
                assert path.hops[0] in parent.hops
