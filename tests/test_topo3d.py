"""3-D mesh/torus and chiplet-package topology tests (DESIGN.md §11).

Covers the full thread: geometry registration, planner coverage on the 26
3-D wedges and the sparse chiplet link set, weighted heterogeneous links
changing DPM merge choices, fault detours, host-vs-xsim delivery-set
equality, telemetry conservation, the generic-topology DPM kernel path, and
the dist-layer scheduler on 3-D / chiplet fabrics.
"""
import random

import numpy as np
import pytest

from repro.core import (
    PLANNERS,
    WeightedLinkCost,
    make_topology,
    plan,
)
from repro.core.partition import (
    basic_partitions,
    candidate_ids_for,
    dpm_partition,
    wedge_patterns,
)
from repro.core.routefn import faulty, provider_for, route_cost_matrices
from repro.core.topo3d import chiplet, mesh3d, torus3d
from repro.core.topology import register_topology, registered_topology_kinds
from repro.noc import NoCConfig, WormholeSim, synthetic_workload, xsimulate
from repro.noc.telemetry import link_coords, link_index

M333 = mesh3d(3, 3, 3)
T333 = torus3d(3, 3, 3)
CP = chiplet(8, 8, 2, 2)  # 2x2 chiplets of 4x4 routers

GRACE = 800


def _instances(g, count, kmax, seed):
    rng = random.Random(seed)
    nodes = g.nodes()
    for _ in range(count):
        picks = rng.sample(nodes, rng.randint(3, kmax + 1))
        yield picks[0], picks[1:]


# ------------------------------------------------------------ registration
def test_registered_kinds_include_topo3d():
    kinds = registered_topology_kinds()
    for k in ("mesh", "torus", "mesh3d", "torus3d", "chiplet"):
        assert k in kinds


def test_make_topology_unknown_kind_lists_registered():
    with pytest.raises(ValueError, match="unknown topology kind 'hypercube'"):
        make_topology("hypercube", 4)
    with pytest.raises(ValueError, match="chiplet.*mesh3d.*torus3d"):
        make_topology("hypercube", 4)


def test_register_topology_duplicate_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_topology("mesh3d", mesh3d)


def test_factories_interned_and_cache_keyed():
    assert mesh3d(3, 3, 3) is make_topology("mesh3d", 3, 3, params=(3,))
    assert torus3d(3, 3, 3) is make_topology("torus3d", 3, 3, params=(3,))
    assert chiplet(8, 8, 2, 2) is make_topology("chiplet", 8, 8, params=(2, 2))
    # distinct weight classes are distinct planner-cache keys
    assert mesh3d(3, 3, 3, z_weight=2.0) is not mesh3d(3, 3, 3)
    assert mesh3d(3, 3, 3, z_weight=2.0).params == (3, 2.0)


def test_topology_protocol_invariants():
    for g in (M333, T333, CP):
        assert g.num_nodes == g.rows * g.n
        for i in (0, 1, g.num_nodes // 2, g.num_nodes - 1):
            assert g.idx(g.from_idx(i)) == i
        # directed-link id space: ports per router, dir_delta inverts
        for u in g.nodes():
            for v in g.neighbors(*u):
                d = g.direction(u, v)
                assert 0 <= d < g.ports
                dd = g.dir_delta(d)
                assert g.normalize(*(c + e for c, e in zip(u, dd))) == v


# ------------------------------------------------------------ partitions
def test_wedge_patterns_3d_extend_ring2():
    p2 = wedge_patterns(2)
    p3 = wedge_patterns(3)
    assert len(p2) == 8 and len(p3) == 26
    # dz=0 block keeps the 2-D ring order so flat sets partition identically
    assert [p[:2] for p in p3[:8]] == list(p2)
    assert p3[16] == (0, 0, 1) and p3[25] == (0, 0, -1)
    assert len(candidate_ids_for(26)) == 78


def test_basic_partitions_3d_sign_patterns():
    src = (1, 1, 1)
    dests = [d for d in M333.nodes() if d != src]
    parts = basic_partitions(src, dests, M333)
    assert len(parts) == 26
    flat = [d for p in parts for d in p]
    assert sorted(flat) == sorted(dests)  # disjoint exact cover
    pats = wedge_patterns(3)
    for i, p in enumerate(parts):
        for d in p:
            dv = M333.delta(src, d)
            assert tuple((x > 0) - (x < 0) for x in dv) == pats[i]


# ------------------------------------------------------------ planning
@pytest.mark.parametrize("g", [M333, T333, CP], ids=["mesh3d", "torus3d", "chiplet"])
@pytest.mark.parametrize("algo", list(PLANNERS))
def test_planners_cover_on_new_topologies(g, algo):
    for src, dests in _instances(g, 15, 8, seed=len(algo)):
        p = plan(algo, g, src, dests)
        assert p.check_covers(), (g.kind, algo, src, dests)
        for path in p.paths:
            for a, b in zip(path.hops, path.hops[1:]):
                assert b in g.neighbors(*a)


def test_chiplet_plans_label_monotone_per_worm():
    """On the chiplet package every worm is label-monotone: BFS routes are
    auto-segmented at direction reversals (needs_bfs_routes), so the
    dual-path VC-class deadlock argument applies per worm. (Healthy 2-D/3-D
    dimension-ordered worms are only per-hop classed, not globally
    monotone.)"""
    g = CP
    for src, dests in _instances(g, 20, 8, seed=11):
        p = plan("DPM", g, src, dests)
        for path in p.paths:
            labs = [g.label(*h) for h in path.hops]
            assert all(b > a for a, b in zip(labs, labs[1:])) or all(
                b < a for a, b in zip(labs, labs[1:])
            ), (g.kind, path.hops)


def test_weighted_z_links_change_dpm_merges():
    """Pricing TSV z-links makes the weighted objective prefer merges that
    stay in-plane: plans must differ from uniform-cost plans somewhere, and
    the weighted objective must price the weighted plan no worse."""
    cheap = mesh3d(4, 4, 4)  # z_weight 1.0
    dear = mesh3d(4, 4, 4, z_weight=4.0)
    wcost = WeightedLinkCost()
    diffs = 0
    for src, dests in _instances(dear, 40, 10, seed=5):
        r_u = dpm_partition(dear, src, list(dests))
        r_w = dpm_partition(dear, src, list(dests), cost_model=wcost)
        ids_u = sorted(p.ids for p in r_u.partitions)
        ids_w = sorted(p.ids for p in r_w.partitions)
        if ids_u != ids_w:
            diffs += 1
        # uniform fabric: the weighted model degenerates to hop counting
        r_c = dpm_partition(cheap, src, list(dests), cost_model=wcost)
        r_h = dpm_partition(cheap, src, list(dests))
        assert sorted(p.ids for p in r_c.partitions) == sorted(
            p.ids for p in r_h.partitions
        )
    assert diffs > 0, "z_weight=4.0 never changed a merge choice"


def test_weighted_noi_links_change_chiplet_dpm_merges():
    dear = chiplet(8, 8, 2, 2, noi_weight=6.0)
    wcost = WeightedLinkCost()
    diffs = 0
    for src, dests in _instances(dear, 40, 10, seed=6):
        r_u = dpm_partition(dear, src, list(dests))
        r_w = dpm_partition(dear, src, list(dests), cost_model=wcost)
        if sorted(p.ids for p in r_u.partitions) != sorted(
            p.ids for p in r_w.partitions
        ):
            diffs += 1
    assert diffs > 0, "noi_weight=6.0 never changed a merge choice"


def test_route_cost_matrices_price_heterogeneous_links():
    g = mesh3d(3, 3, 3, z_weight=2.5)
    dist, weight, _ = route_cost_matrices(g, WeightedLinkCost())
    a, b = g.idx((0, 0, 0)), g.idx((0, 0, 1))  # one z-hop
    c = g.idx((1, 0, 0))  # one x-hop
    assert dist[a, b] == 1 and weight[a, b] == 2.5
    assert dist[a, c] == 1 and weight[a, c] == 1.0
    # the default (hop-count) model ignores link weights entirely
    _, w_hops, _ = route_cost_matrices(g)
    assert w_hops[a, b] == 1.0


# ------------------------------------------------------------ faults
def test_fault_detour_on_mesh3d():
    broken = (((1, 1, 0), (1, 1, 1)),)
    g = faulty(M333, broken)
    p = plan("DPM", g, (1, 1, 0), [(1, 1, 1), (1, 1, 2), (0, 0, 2)])
    assert p.check_covers()
    for path in p.paths:
        for a, b in zip(path.hops, path.hops[1:]):
            assert not g.is_broken(a, b)


def test_fault_detour_on_chiplet_noi():
    # break one of the two east-west interposer crossings
    broken = (((3, 0), (4, 0)),)
    g = faulty(CP, broken)
    p = plan("DPM", g, (0, 0), [(7, 0), (7, 7), (4, 3)])
    assert p.check_covers()
    for path in p.paths:
        for a, b in zip(path.hops, path.hops[1:]):
            assert not g.is_broken(a, b)


def test_provider_dispatch_for_new_topologies():
    # chiplet needs BFS routes; 3-D meshes route dimension-ordered
    assert provider_for(CP).__class__.__name__ == "BFSRouteProvider"
    assert provider_for(M333).__class__.__name__ == "MinimalRouteProvider"
    assert provider_for(faulty(M333, (((0, 0, 0), (1, 0, 0)),))
                        ).__class__.__name__ == "FaultAwareProvider"


# ------------------------------------------------------ host sim vs xsim
CASES = [
    ("mesh3d-DPM",
     NoCConfig(n=3, m=3, topology="mesh3d", topology_params=(3,),
               dest_range=(2, 5)), 0.03, 100, 1, "DPM"),
    ("mesh3d-MU",
     NoCConfig(n=3, m=3, topology="mesh3d", topology_params=(3,),
               dest_range=(2, 5)), 0.03, 100, 1, "MU"),
    ("torus3d-DPM",
     NoCConfig(n=3, m=3, topology="torus3d", topology_params=(3,),
               dest_range=(2, 5)), 0.03, 100, 2, "DPM"),
    ("mesh3d-weighted-z-DPM",
     NoCConfig(n=3, m=3, topology="mesh3d", topology_params=(3, 2.0),
               dest_range=(2, 5)), 0.03, 100, 4, "DPM"),
    ("chiplet-DPM",
     NoCConfig(n=8, m=8, topology="chiplet", topology_params=(2, 2),
               dest_range=(2, 5)), 0.02, 100, 3, "DPM"),
    ("chiplet-MP",
     NoCConfig(n=8, m=8, topology="chiplet", topology_params=(2, 2),
               dest_range=(2, 5)), 0.02, 100, 3, "MP"),
]


@pytest.mark.parametrize("case", CASES, ids=[c[0] for c in CASES])
def test_xsim_matches_wormhole_on_new_topologies(case):
    _, cfg, rate, cycles, seed, algo = case
    wl = synthetic_workload(cfg, rate, cycles, seed=seed)
    res = xsimulate(cfg, [wl], (algo,), warmup=0, drain_grace=GRACE)
    g = cfg.make_topology()
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_request(algo, r.src, r.dests, r.time)
    pst = sim.run(wl.horizon + GRACE, drain=True)
    psets = {
        pk.pid: {g.idx(c) for c in pk.delivery_times} for pk in sim.packets
    }
    xst = res.stats(0, 0)
    # both engines fully drain (covered-and-drained acceptance)
    assert res.all_drained(0, 0)
    assert pst.packets_finished == pst.packets_created
    # identical per-packet delivery sets (the hard contract)
    assert res.delivered_sets(0, 0) == psets
    assert xst.flit_link_traversals == pst.flit_link_traversals
    assert xst.packets_created == pst.packets_created


def test_xsim_heatmap_shape_tracks_ports():
    cfg = NoCConfig(n=3, m=3, topology="mesh3d", topology_params=(3,),
                    dest_range=(2, 4))
    wl = synthetic_workload(cfg, 0.02, 60, seed=0)
    res = xsimulate(cfg, [wl], ("DPM",), warmup=0, drain_grace=GRACE)
    hm = res.link_heatmap(0, 0)
    assert hm.shape == (9, 3, 6)  # rows = m*d, 6 ports in 3-D
    assert hm.sum() == res.stats(0, 0).flit_link_traversals


# ------------------------------------------------------------ telemetry
@pytest.mark.parametrize("cfg", [
    NoCConfig(n=3, m=3, topology="mesh3d", topology_params=(3,),
              dest_range=(2, 4)),
    NoCConfig(n=8, m=8, topology="chiplet", topology_params=(2, 2),
              dest_range=(2, 4)),
], ids=["mesh3d", "chiplet"])
def test_telemetry_conservation_on_new_topologies(cfg):
    """Structured telemetry views must equal the flat conserved counters on
    the new port/link id spaces (satellite of DESIGN.md §10)."""
    wl = synthetic_workload(cfg, 0.03, 120, seed=7)
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_request("DPM", r.src, r.dests, r.time)
    st = sim.run(wl.horizon + GRACE, drain=True)
    tel = st.telemetry
    g = cfg.make_topology()
    ports = g.ports
    assert tel.link_flits.shape == (g.num_nodes * ports,)
    assert int(tel.link_flits.sum()) == st.flit_link_traversals
    assert np.array_equal(tel.heatmap(g).reshape(-1), tel.link_flits)
    # link_coords round-trips every used link id through the topology
    for lid in np.flatnonzero(tel.link_flits):
        u, v = link_coords(g, int(lid))
        assert v in g.neighbors(*u)
        assert link_index(g, u, v) == int(lid)


# ------------------------------------------------------ generic DPM kernel
def _mask_instances(g, P, seed):
    rng = np.random.default_rng(seed)
    NN = g.num_nodes
    srcs = [g.from_idx(int(i)) for i in rng.integers(0, NN, P)]
    masks = np.zeros((P, NN), np.int32)
    for p in range(P):
        ds = rng.choice(
            [i for i in range(NN) if i != g.idx(srcs[p])], size=6,
            replace=False,
        )
        masks[p, ds] = 1
    return srcs, masks


@pytest.mark.parametrize("kind,n,m,params", [
    ("mesh", 6, None, ()), ("torus", 5, None, ()),
])
def test_dpm_plan_topo_matches_2d_kernel(kind, n, m, params):
    """The generic-topology path must reproduce the closed-form 2-D kernel
    bit for bit (chosen/costs/reps) when fed the same geometry as tables."""
    import jax.numpy as jnp

    from repro.kernels.dpm_cost.ops import (
        dpm_plan,
        dpm_plan_topo,
        partition_membership,
        snake_labels,
    )

    g = make_topology(kind, n, m, (), params)
    srcs, masks = _mask_instances(g, 12, seed=3)
    sxy = np.array([list(s) for s in srcs], np.int32)
    ch0, c0, r0 = dpm_plan(
        jnp.asarray(masks), jnp.asarray(sxy), n=n, wrap=(kind == "torus"),
        interpret=True,
    )
    dist, weight, overhead = route_cost_matrices(g)
    part = np.where(masks > 0, partition_membership(g, srcs), -1)
    cht, ct, rt = dpm_plan_topo(
        jnp.asarray(part),
        jnp.asarray([g.idx(s) for s in srcs], dtype=jnp.int32),
        jnp.asarray(snake_labels(g)), jnp.asarray(dist),
        jnp.asarray(weight), np_=8, overhead=float(overhead),
    )
    np.testing.assert_array_equal(np.asarray(ch0), np.asarray(cht))
    np.testing.assert_allclose(np.asarray(c0), np.asarray(ct))
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(rt))


@pytest.mark.parametrize("g", [M333, T333, CP], ids=["mesh3d", "torus3d", "chiplet"])
def test_dpm_plan_topo_covers_and_matches_host_reps(g):
    """On the new topologies the kernel's chosen candidates tile the
    nonempty wedges without overlap, and singles agree with the host's
    Definition 1 representative and MU cost C_t."""
    import jax.numpy as jnp

    from repro.core.partition import candidate_cost
    from repro.kernels.dpm_cost.ops import (
        dpm_plan_topo,
        partition_membership,
        snake_labels,
    )

    ndim = len(g.from_idx(0))
    np_ = len(wedge_patterns(ndim))
    cands = candidate_ids_for(np_)
    srcs, masks = _mask_instances(g, 8, seed=9)
    dist, weight, overhead = route_cost_matrices(g, WeightedLinkCost())
    part = np.where(masks > 0, partition_membership(g, srcs), -1)
    ch, c, r = dpm_plan_topo(
        jnp.asarray(part),
        jnp.asarray([g.idx(s) for s in srcs], dtype=jnp.int32),
        jnp.asarray(snake_labels(g)), jnp.asarray(dist),
        jnp.asarray(weight), np_=np_, overhead=float(overhead),
    )
    ch, c, r = np.asarray(ch), np.asarray(c), np.asarray(r)
    for p, src in enumerate(srcs):
        dests = [g.from_idx(int(i)) for i in np.flatnonzero(masks[p])]
        parts = basic_partitions(src, dests, g)
        nonempty = {i for i in range(np_) if parts[i]}
        covered = [i for ci in np.flatnonzero(ch[p]) for i in cands[ci]]
        assert sorted(covered) == sorted(set(covered))  # no overlap
        assert set(covered) >= nonempty  # every nonempty wedge served
        for i in nonempty:  # singles: host C_t + source leg, host rep
            cc = candidate_cost(g, src, (i,), parts[i],
                                cost_model=WeightedLinkCost())
            assert c[p, i] == pytest.approx(cc.cost_mu + cc.source_leg)
            assert int(r[p, i]) == g.idx(cc.rep)


# ------------------------------------------------------------ dist layer
@pytest.mark.parametrize("g", [T333, CP], ids=["torus3d", "chiplet"])
def test_schedule_multicasts_on_new_fabrics(g):
    from repro.dist.multicast import schedule_multicasts

    rng = random.Random(9)
    nodes = g.nodes()
    reqs = []
    for _ in range(6):
        picks = rng.sample(nodes, rng.randint(4, 9))
        reqs.append((picks[0], picks[1:]))
    sched = schedule_multicasts(g, reqs)
    have = [{g.idx(s)} for s, _ in reqs]
    for rnd, rr in zip(sched.rounds, sched.round_reqs):
        senders = [s for s, _ in rnd]
        receivers = [d for _, d in rnd]
        assert len(set(senders)) == len(senders)
        assert len(set(receivers)) == len(receivers)
        for (s, d), rid in zip(rnd, rr):
            assert s in have[rid]
        for (s, d), rid in zip(rnd, rr):
            have[rid].add(d)
    for rid, (src, dests) in enumerate(reqs):
        assert {g.idx(d) for d in dests} <= have[rid]
