"""Multi-device distribution checks. Run with 8 forced host devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python tests/dist_checks.py

Invoked as a subprocess by tests/test_dist.py so the main pytest process
keeps its single-device view.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)

import dataclasses  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402


def check_ep_matches_dense():
    """shard_map EP MoE == dense MoE path on a 2x4 (data, model) mesh."""
    from repro.configs import SMOKES
    from repro.dist.ep import moe_apply_ep
    from repro.models.moe import moe_apply_dense, moe_init

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = SMOKES["moonshot-v1-16b-a3b"]
    cfg = cfg.scaled(
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )  # no drops => exact equality modulo reduction order
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y_dense, aux_d = moe_apply_dense(p, x, cfg)
    set_mesh = getattr(jax, "set_mesh", None)  # jax<0.6: Mesh is the ctx mgr
    with (set_mesh(mesh) if set_mesh else mesh):
        y_ep, aux_e = moe_apply_ep(p, x, cfg, mesh)
    np.testing.assert_allclose(
        np.asarray(y_dense), np.asarray(y_ep), atol=2e-5
    )
    print("ep == dense: OK")


def check_dpm_broadcast():
    """DPM ppermute schedule delivers the rank-0 payload to every rank."""
    from repro.dist.multicast import apply_schedule, dp_broadcast_schedule

    mesh = jax.make_mesh((8,), ("data",))
    sched = dp_broadcast_schedule(8, "DPM")

    x = jnp.arange(8, dtype=jnp.float32) * 100.0  # rank i holds 100*i

    def fn(xl):
        return apply_schedule(xl, sched, "data")

    out = shard_map(
        fn, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_rep=False,
    )(x)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(8))
    print("dpm broadcast: OK (all ranks got rank-0 payload)")


def check_compressed_psum():
    """int8 RS+AG all-reduce ~= psum; error feedback shrinks the residual."""
    from repro.dist.compress import compressed_psum

    mesh = jax.make_mesh((8,), ("data",))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))

    def fn(gl):
        gl = gl[0]
        err = jnp.zeros_like(gl)
        s1, e1 = compressed_psum(gl, err, "data")
        exact = jax.lax.psum(gl, "data")
        return (
            s1[None],
            exact[None],
            jnp.sum(jnp.abs(e1))[None],
        )

    s1, exact, errn = shard_map(
        fn,
        mesh=mesh,
        in_specs=P("data"),
        out_specs=(P("data"), P("data"), P("data")),
        check_rep=False,
    )(g)
    rel = float(
        jnp.abs(s1 - exact).max() / jnp.abs(exact).max()
    )
    assert rel < 0.05, rel
    print(f"compressed psum: OK (rel err {rel:.4f})")


def check_pipeline_forward():
    """4-stage GPipe == sequential layer application."""
    from repro.dist.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("pipe",))
    L, d = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.1

    def layer_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, d))  # 8 microbatches
    stage_params = ws.reshape(4, L // 4, d, d)
    out = pipeline_apply(layer_fn, stage_params, x, mesh, axis="pipe")

    ref = x
    for i in range(L):
        ref = layer_fn(ws[i], ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    print("pipeline forward: OK")

    # grads flow through the pipeline
    def loss(sp):
        return jnp.sum(pipeline_apply(layer_fn, sp, x, mesh, axis="pipe") ** 2)

    gr = jax.grad(loss)(stage_params)
    assert bool(jnp.isfinite(gr).all()) and float(jnp.abs(gr).max()) > 0
    print("pipeline grad: OK")


def check_zero1_shardings():
    from repro.configs import SMOKES
    from repro.dist.sharding import param_shardings, zero1_shardings
    from repro.models import RunConfig
    from repro.models.model import abstract_init

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = SMOKES["smollm-135m"]
    run = RunConfig()
    shapes, specs = abstract_init(cfg, run)
    ps = param_shardings(specs, mesh)
    zs = zero1_shardings(specs, shapes, mesh)
    n_extra = 0
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(zs)):
        sa = [x for x in a.spec if x is not None]
        sb = [x for x in b.spec if x is not None]
        assert set(sa) <= set(map(str, sb)) | set(sb) or len(sb) >= len(sa)
        if len(sb) > len(sa):
            n_extra += 1
    assert n_extra > 0, "zero1 must shard extra dims over data"
    print(f"zero1 shardings: OK ({n_extra} leaves gained a data shard)")


def check_ep_dispatch_uses_dpm_schedule():
    """EP dispatch is lowered through the DPM multicast schedule: the
    traced program runs ppermute rounds, not a bare all_to_all."""
    from repro.configs import SMOKES
    from repro.dist.ep import moe_apply_ep
    from repro.dist.multicast import alltoall_schedule
    from repro.models.moe import moe_init

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sched = alltoall_schedule(4, "DPM")
    pairs = sorted(pr for rnd in sched.rounds for pr in rnd)
    assert pairs == sorted(
        (i, j) for i in range(4) for j in range(4) if i != j
    ), pairs

    cfg = SMOKES["moonshot-v1-16b-a3b"]
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    jaxpr = str(
        jax.make_jaxpr(lambda q, z: moe_apply_ep(q, z, cfg, mesh)[0])(p, x)
    )
    assert "ppermute" in jaxpr, "EP dispatch must run the schedule's rounds"
    assert "all_to_all" not in jaxpr, "EP dispatch must not use bare all_to_all"
    n_perm = jaxpr.count("ppermute")
    assert n_perm >= 2 * sched.num_rounds, (n_perm, sched.num_rounds)
    print(
        f"ep dispatch schedule: OK (DPM, {sched.num_rounds} rounds, "
        f"{n_perm} ppermutes in jaxpr)"
    )


if __name__ == "__main__":
    assert jax.device_count() == 8, jax.devices()
    check_dpm_broadcast()
    check_compressed_psum()
    check_pipeline_forward()
    check_zero1_shardings()
    check_ep_matches_dense()
    check_ep_dispatch_uses_dpm_schedule()
    print("ALL DIST CHECKS PASSED")
