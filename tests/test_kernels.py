"""Pallas kernel sweeps: shapes x dtypes vs pure-jnp oracles (interpret=True).

Per the deliverable: for each kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import candidate_cost, grid
from repro.core.partition import ALL_CANDIDATE_IDS, basic_partitions
from repro.kernels.dpm_cost.dpm_cost import CANDS, dpm_cost_table
from repro.kernels.dpm_cost.ops import dpm_plan, total_plan_cost
from repro.kernels.dpm_cost.ref import dpm_cost_table_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.noc_step.noc_step import NOC_INF, segmented_min
from repro.kernels.noc_step.ops import arbitrate
from repro.kernels.noc_step.ref import segmented_min_ref
from repro.kernels.ssd.ops import ssd_scan_pallas
from repro.kernels.ssd.ref import ssd_reference


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_SHAPES = [
    # (B, S, H, KH, D, bq, bk, window)
    (1, 128, 4, 4, 64, 64, 64, None),  # MHA
    (2, 256, 8, 2, 64, 128, 128, None),  # GQA 4:1
    (2, 256, 8, 1, 32, 64, 128, None),  # MQA
    (1, 200, 4, 2, 64, 64, 64, None),  # ragged (pad path)
    (2, 256, 4, 4, 128, 64, 64, 96),  # sliding window
    (1, 512, 2, 2, 64, 128, 256, 128),  # window, rectangular blocks
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(shape, dtype):
    B, S, H, KH, D, bq, bk, window = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    q = jax.random.normal(key, (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D), dtype)
    out = flash_attention(
        q, k, v, window=window, block_q=bq, block_k=bk, interpret=True
    )
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        window=window,
    ).transpose(0, 2, 1, 3)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol
    )


def test_flash_attention_q_offset_decode_chunk():
    """Chunked decode/extension: q_offset shifts the causal diagonal."""
    key = jax.random.PRNGKey(7)
    B, H, D = 1, 2, 64
    Sk, Sq, off = 256, 64, 192  # queries are positions 192..255
    q = jax.random.normal(key, (B, Sq, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, H, D))
    out = flash_attention(q, k, v, q_offset=off, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        q_offset=off,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
SSD_SHAPES = [
    # (B, S, H, P, G, N, chunk)
    (1, 64, 2, 8, 1, 16, 16),
    (2, 128, 4, 16, 2, 8, 32),
    (2, 96, 4, 16, 2, 8, 32),  # ragged
    (1, 256, 8, 32, 1, 64, 64),  # mamba2-like ratios
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_sweep(shape, dtype):
    B, S, H, P, G, N, chunk = shape
    key = jax.random.PRNGKey(hash(shape) & 0xFFFF)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype)
    y, h = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hr = ssd_reference(x, dt, A, Bm, Cm)
    atol = 5e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )
    np.testing.assert_allclose(
        np.asarray(h, np.float32), np.asarray(hr, np.float32), atol=atol
    )


# ---------------------------------------------------------------------------
# noc_step (xsim arbitration segmented-min)
# ---------------------------------------------------------------------------
SEGMIN_SHAPES = [
    # (num candidates, num segments)
    (64, 7),
    (1000, 256),
    (4096, 64),
    (37, 300),  # more segments than candidates
    (512, 320),  # the link+ejection fused id space of an 8x8 mesh
]


@pytest.mark.parametrize("shape", SEGMIN_SHAPES)
def test_noc_step_segmented_min_pallas_vs_ref(shape):
    N, L = shape
    rng = np.random.default_rng(N * L)
    keys = rng.integers(0, 2**22, N).astype(np.int32)
    keys[rng.random(N) < 0.3] = NOC_INF  # masked (no-candidate) entries
    segs = rng.integers(0, L, N).astype(np.int32)
    out_k = segmented_min(jnp.asarray(keys), jnp.asarray(segs), L,
                          interpret=True)
    out_r = segmented_min_ref(jnp.asarray(keys), jnp.asarray(segs), L)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    # empty segments must hold exactly NOC_INF on both paths
    empty = np.setdiff1d(np.arange(L), segs[keys < NOC_INF])
    assert (np.asarray(out_r)[empty] == NOC_INF).all()


@pytest.mark.parametrize("backend", ["ref", "pallas_interpret"])
def test_noc_step_arbitrate_one_winner_per_resource(backend):
    rng = np.random.default_rng(9)
    N, L = 777, 61
    keys = jnp.asarray(rng.permutation(N).astype(np.int32))  # unique
    segs = jnp.asarray(rng.integers(0, L, N).astype(np.int32))
    adm = jnp.asarray(rng.random(N) < 0.4)
    win = np.asarray(arbitrate(adm, keys, segs, L, backend=backend))
    assert (win & ~np.asarray(adm)).sum() == 0  # winners are admissible
    for seg in range(L):
        mask = (np.asarray(segs) == seg) & np.asarray(adm)
        if mask.any():
            # exactly the min-key admissible candidate wins
            expect = np.flatnonzero(mask)[np.asarray(keys)[mask].argmin()]
            assert win[np.asarray(segs) == seg].sum() == 1
            assert win[expect]
        else:
            assert win[np.asarray(segs) == seg].sum() == 0


# ---------------------------------------------------------------------------
# dpm_cost
# ---------------------------------------------------------------------------
def _instances(n, m, P, seed):
    g = grid(n, m)
    rng = random.Random(seed)
    nodes = [(x, y) for x in range(n) for y in range(m)]
    masks, sxy, insts = [], [], []
    for _ in range(P):
        k = rng.randint(1, min(16, len(nodes) - 1))
        picks = rng.sample(nodes, k + 1)
        src, dests = picks[0], picks[1:]
        row = np.zeros(n * m, np.int32)
        for (x, y) in dests:
            row[y * n + x] = 1
        masks.append(row)
        sxy.append(src)
        insts.append((src, dests))
    return jnp.array(np.stack(masks)), jnp.array(np.array(sxy, np.int32)), insts


@pytest.mark.parametrize("mesh", [(4, 4), (8, 8), (16, 16), (8, 4)])
@pytest.mark.parametrize("leg", [True, False])
def test_dpm_cost_kernel_vs_ref(mesh, leg):
    n, m = mesh
    masks, sxy, _ = _instances(n, m, 33, seed=n * m + leg)
    ck, rk = dpm_cost_table(
        masks, sxy, n=n, m=m, include_source_leg=leg, interpret=True, tile=16
    )
    cr, rr = dpm_cost_table_ref(masks, sxy, n=n, m=m, include_source_leg=leg)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cr))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))


def test_dpm_cost_vs_host_planner():
    """Kernel MU-costs equal the host planner's Definition 1/2 values."""
    n = 8
    g = grid(n)
    masks, sxy, insts = _instances(n, n, 25, seed=3)
    ck, rk = dpm_cost_table(masks, sxy, n=n, interpret=True, tile=8)
    for p, (src, dests) in enumerate(insts):
        parts = basic_partitions(src, dests)
        for ci, ids in enumerate(ALL_CANDIDATE_IDS):
            assert CANDS[ci] == ids
            union = [d for i in ids for d in parts[i]]
            cc = candidate_cost(g, src, ids, union)
            host = (cc.cost_mu + cc.source_leg) if union else 0
            assert host == int(ck[p, ci]), (p, ids)
            if union:
                rep = cc.rep
                assert int(rk[p, ci]) == rep[1] * n + rep[0]


def test_dpm_plan_greedy_invariants():
    """On-device greedy merge: exact disjoint cover of non-empty partitions,
    and merged selections never increase cost vs unmerged singles."""
    n = 8
    masks, sxy, insts = _instances(n, n, 64, seed=11)
    chosen, costs, reps = dpm_plan(masks, sxy, n=n, interpret=True)
    bits = np.array([sum(1 << i for i in ids) for ids in CANDS])
    singles_cost = np.asarray(costs[:, :8])
    for p, (src, dests) in enumerate(insts):
        parts = basic_partitions(src, dests)
        nonempty = sum(1 << i for i in range(8) if parts[i])
        sel = np.where(np.asarray(chosen[p]))[0]
        cover = 0
        for ci in sel:
            assert cover & bits[ci] & nonempty == 0
            cover |= bits[ci]
        assert cover & nonempty == nonempty
        tot = int(np.asarray(total_plan_cost(chosen, costs))[p])
        assert tot <= singles_cost[p].sum()  # merging never hurts


# ---------------------------------------------------------------------------
# dpm_cost — weighted route tensors (route-provider layer, DESIGN.md §7)
# ---------------------------------------------------------------------------
def test_dpm_cost_weighted_hop_tensors_match_int_kernel():
    """With hop-count route matrices the weighted kernel reproduces the
    analytic kernel bit for bit, on mesh and torus geometry."""
    from repro.core import route_cost_matrices, torus
    from repro.kernels.dpm_cost.dpm_cost import dpm_cost_table_weighted
    from repro.kernels.dpm_cost.ref import dpm_cost_table_weighted_ref

    for topo, wrap in ((grid(8), False), (torus(8), True)):
        masks, sxy, _ = _instances(8, 8, 17, seed=5 + wrap)
        dist, w, oh = route_cost_matrices(topo)
        ck, rk = dpm_cost_table(masks, sxy, n=8, wrap=wrap, interpret=True)
        cw, rw = dpm_cost_table_weighted(
            masks, sxy, jnp.array(dist), jnp.array(w),
            n=8, wrap=wrap, overhead=oh, interpret=True, tile=8,
        )
        cr, rr = dpm_cost_table_weighted_ref(
            masks, sxy, jnp.array(dist), jnp.array(w), n=8, wrap=wrap,
            overhead=oh,
        )
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(cw, np.int32))
        np.testing.assert_array_equal(np.asarray(rk), np.asarray(rw))
        np.testing.assert_array_equal(np.asarray(cw), np.asarray(cr))
        np.testing.assert_array_equal(np.asarray(rw), np.asarray(rr))


def test_dpm_cost_weighted_vs_host_on_degraded_mesh():
    """Fault-priced batching: with (dist, weight) lowered from a degraded
    8x8 mesh the kernel's candidate costs equal the host cost model
    exactly (detoured integer hop counts), and reps follow the degraded
    Definition 1 distances."""
    from repro.core import faulty, get_cost_model, route_cost_matrices
    from repro.kernels.dpm_cost.dpm_cost import dpm_cost_table_weighted

    n = 8
    fg = faulty(
        grid(n), [((3, 3), (4, 3)), ((3, 4), (3, 5)), ((0, 0), (1, 0))]
    )
    masks, sxy, insts = _instances(n, n, 21, seed=7)
    dist, w, oh = route_cost_matrices(fg)
    cw, rw = dpm_cost_table_weighted(
        masks, sxy, jnp.array(dist), jnp.array(w), n=n, overhead=oh,
        interpret=True, tile=8,
    )
    for p, (src, dests) in enumerate(insts):
        parts = basic_partitions(src, dests, fg)
        for ci, ids in enumerate(ALL_CANDIDATE_IDS):
            union = [d for i in ids for d in parts[i]]
            cc = candidate_cost(fg, src, ids, union)
            host = (cc.cost_mu + cc.source_leg) if union else 0
            assert host == float(cw[p, ci]), (p, ids)
            if union:
                assert int(rw[p, ci]) == fg.idx(cc.rep)

    # an arbitrary float model (energy) batches too, to f32 rounding
    cm = get_cost_model("energy")
    dist_e, w_e, oh_e = route_cost_matrices(fg, cm)
    ce, re = dpm_cost_table_weighted(
        masks, sxy, jnp.array(dist_e), jnp.array(w_e), n=n, overhead=oh_e,
        interpret=True, tile=8,
    )
    for p, (src, dests) in enumerate(insts[:5]):
        parts = basic_partitions(src, dests, fg)
        for ci, ids in enumerate(ALL_CANDIDATE_IDS):
            union = [d for i in ids for d in parts[i]]
            if not union:
                continue
            cc = candidate_cost(fg, src, ids, union, cm)
            assert float(ce[p, ci]) == pytest.approx(
                cc.cost_mu + cc.source_leg, rel=1e-5
            )


def test_dpm_plan_weighted_matches_int_plan_under_hop_weights():
    from repro.core import route_cost_matrices
    from repro.kernels.dpm_cost.ops import dpm_plan_weighted

    n = 8
    masks, sxy, _ = _instances(n, n, 32, seed=13)
    dist, w, oh = route_cost_matrices(grid(n))
    ch0, *_ = dpm_plan(masks, sxy, n=n, interpret=True)
    chw, cw, rw = dpm_plan_weighted(
        masks, sxy, jnp.array(dist), jnp.array(w), n=n, overhead=oh,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(ch0), np.asarray(chw))


def _host_greedy(costs, reps):
    """Host-semantics greedy merge over one candidate table (the exact
    Algorithm 1 loop of dpm_partition: max saving, then fewer partitions,
    then smallest index; leftover non-empty singles appended)."""
    nonempty = reps >= 0
    savings = {}
    for ci, ids in enumerate(CANDS):
        if len(ids) == 1 or not nonempty[ci]:
            continue
        savings[ci] = max(0.0, sum(costs[i] for i in ids) - costs[ci])
    chosen = np.zeros(24, bool)
    covered: set = set()
    while True:
        best, best_a = None, 0
        for ci, a in savings.items():
            if a <= 0:
                continue
            ids = CANDS[ci]
            if (
                best is None
                or a > best_a
                or (a == best_a and (len(ids), ids) < (len(CANDS[best]),
                                                       CANDS[best]))
            ):
                best, best_a = ci, a
        if best is None:
            break
        chosen[best] = True
        covered |= set(CANDS[best])
        for ci in list(savings):
            if covered & set(CANDS[ci]):
                savings[ci] = 0
    for i in range(8):
        if i not in covered and nonempty[i]:
            chosen[i] = True
    return chosen


def test_dpm_plan_weighted_float_tie_breaks_match_host_greedy():
    """Under a float objective (energy) the device merge must reproduce the
    host loop's exact-tie semantics — near-tied float savings are where a
    scalar priority encoding would silently pick the wrong candidate."""
    from repro.core import get_cost_model, route_cost_matrices
    from repro.kernels.dpm_cost.ops import dpm_plan_weighted

    n = 8
    masks, sxy, _ = _instances(n, n, 40, seed=17)
    dist, w, oh = route_cost_matrices(grid(n), get_cost_model("energy"))
    chw, cw, rw = dpm_plan_weighted(
        masks, sxy, jnp.array(dist), jnp.array(w), n=n, overhead=oh,
        interpret=True,
    )
    cw, rw, chw = np.asarray(cw), np.asarray(rw), np.asarray(chw)
    for p in range(cw.shape[0]):
        np.testing.assert_array_equal(chw[p], _host_greedy(cw[p], rw[p]), p)


# ---------------------------------------------------------------------------
# fused wormhole-cycle kernel (kernels/noc_cycle): ref <-> Pallas parity
# ---------------------------------------------------------------------------
def _cycle_fixture(cfg, rate, cycles, seed, algo):
    """Compile one workload down to the fused engine's operands."""
    from repro.kernels.noc_cycle import ref as R
    from repro.noc import synthetic_workload
    from repro.noc.xsim.compile import (
        compile_workload,
        geometry_tables,
        stack_traffic,
    )

    wl = synthetic_workload(cfg, rate, cycles, seed=seed)
    ct = compile_workload(cfg, wl, algo)
    refm, stacked = stack_traffic([ct])
    tb = {
        f: jnp.asarray(stacked[f][0]) for f in R.TABLE_FIELDS
    }
    geom = geometry_tables(
        refm.kind, refm.n, refm.m, refm.params, cfg.vcs_per_class
    )
    params = dict(
        F=cfg.flits_per_packet, V=cfg.vcs_per_class, BD=cfg.buffer_depth,
        L=refm.num_links, NN=refm.num_nodes,
    )
    C = stacked["child_parent"].shape[1]
    planes = R.init_planes(
        refm.num_links, 2 * cfg.vcs_per_class, refm.num_nodes, C
    )
    return R, tb, geom, params, planes, stacked["link"].shape[2]


CYCLE_TOPOS = [
    ("mesh", dict(n=4, dest_range=(2, 4))),
    ("torus", dict(n=4, topology="torus", dest_range=(2, 4))),
    ("degraded", dict(n=4, dest_range=(2, 4),
                      broken_links=(((1, 1), (2, 1)),))),
]


@pytest.mark.parametrize(
    "topo_kw", [c[1] for c in CYCLE_TOPOS], ids=[c[0] for c in CYCLE_TOPOS]
)
def test_noc_cycle_pallas_lockstep_state_parity(topo_kw):
    """The fused kernel must reproduce the jnp reference *per cycle*: every
    packed state plane bit-equal after each single-cycle chunk, and the
    packed arrival-event row decoding to the reference arrival tuple."""
    from repro.noc import NoCConfig
    from repro.kernels.noc_cycle.noc_cycle import make_chunk_runner

    cfg = NoCConfig(**topo_kw)
    R, tb, geom, params, planes, S = _cycle_fixture(cfg, 0.06, 30, 5, "DPM")
    F = params["F"]
    runner = jax.jit(
        make_chunk_runner(geom, S=S, Tc=1, interpret=True, **params)
    )
    step_r = jax.jit(
        lambda st, t: R.cycle_core(st, tb, t, geom, **params)
    )
    st_r, st_p = planes, planes
    for t in range(24):
        st_r, (aval, apid, astage, afid) = step_r(st_r, jnp.int32(t))
        st_p, ev = runner(st_p, tb, t)
        for name, a, b in zip(R.CycleState._fields, st_r, st_p):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"plane {name} @ t={t}"
            )
        ev_ref = np.where(
            np.asarray(aval),
            1 + (np.asarray(apid) * S + np.asarray(astage)) * 4
            + (np.asarray(afid) == F - 1) * 2 + (np.asarray(afid) == 0),
            0,
        )
        np.testing.assert_array_equal(np.asarray(ev[0]), ev_ref, err_msg=f"ev @ t={t}")
    assert int(st_r.ctr[0]) > 0  # traffic actually moved


@given(st.integers(0, 10**6), st.integers(0, 6), st.integers(0, 2))
@settings(max_examples=3, deadline=None)
def test_noc_cycle_pallas_chunked_parity_randomized(seed, ri, ti):
    """Property: for randomized traffic on mesh/torus/degraded, a chunked
    fused-kernel run (several cycles per launch) ends in exactly the
    reference scan's state."""
    from repro.noc import NoCConfig
    from repro.kernels.noc_cycle.noc_cycle import make_chunk_runner

    cfg = NoCConfig(**CYCLE_TOPOS[ti][1])
    rate = 0.02 + 0.01 * ri
    R, tb, geom, params, planes, S = _cycle_fixture(cfg, rate, 20, seed, "DPM")
    Tc, chunks = 8, 2

    @jax.jit
    def ref_end(planes):
        def body(st, t):
            st, _ = R.cycle_core(st, tb, t, geom, **params)
            return st, None
        st, _ = jax.lax.scan(
            body, planes, jnp.arange(Tc * chunks, dtype=jnp.int32)
        )
        return st

    st_r = ref_end(planes)
    runner = jax.jit(
        make_chunk_runner(geom, S=S, Tc=Tc, interpret=True, **params)
    )
    st_p = planes
    for c in range(chunks):
        st_p, _ = runner(st_p, tb, c * Tc)
    for name, a, b in zip(R.CycleState._fields, st_r, st_p):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"plane {name} seed={seed} rate={rate} topo={ti}",
        )
