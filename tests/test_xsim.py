"""xsim <-> WormholeSim cross-validation + purity checks (DESIGN.md §5).

The fidelity contract: on small configurations xsim must deliver exactly the
same per-packet delivery sets as the event-ordered host simulator, conserve
the same flit/link event counts, and track average latency within 10%
(simultaneous vs sequential arbitration may shift individual stall cycles).
"""
import jax
import numpy as np
import pytest

from repro.core import plan
from repro.core.topology import make_topology
from repro.noc import (
    NoCConfig,
    WormholeSim,
    synthetic_workload,
    xsimulate,
)
from repro.noc.xsim import compile_workload, latency_vs_rate_batched

# (name, cfg, rate, cycles, seed, algo) — mesh and torus, unicast-only and
# multicast-heavy, with DPM (child packets), path-chains (MP) and tours (NMP)
CASES = [
    ("mesh-unicast-MU",
     NoCConfig(n=4, multicast_fraction=0.0), 0.05, 100, 1, "MU"),
    ("mesh-mcheavy-DPM",
     NoCConfig(n=5, multicast_fraction=0.5, dest_range=(3, 6)),
     0.04, 150, 2, "DPM"),
    ("mesh-mcheavy-MP",
     NoCConfig(n=5, multicast_fraction=0.5, dest_range=(3, 6)),
     0.04, 150, 2, "MP"),
    ("torus-DPM",
     NoCConfig(n=4, topology="torus", dest_range=(2, 5)), 0.06, 150, 3,
     "DPM"),
    ("torus-NMP",
     NoCConfig(n=4, topology="torus", dest_range=(2, 5)), 0.06, 150, 3,
     "NMP"),
]
GRACE = 800


def _host_run(cfg, wl, algo):
    g = make_topology(cfg.topology, cfg.n, cfg.m)
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_plan(plan(algo, g, r.src, r.dests), r.time)
    stats = sim.run(wl.horizon + GRACE)
    sets = {
        pk.pid: {g.idx(c) for c in pk.delivery_times} for pk in sim.packets
    }
    return stats, sets


@pytest.mark.parametrize(
    "case", CASES, ids=[c[0] for c in CASES]
)
def test_xsim_matches_wormhole(case):
    _, cfg, rate, cycles, seed, algo = case
    wl = synthetic_workload(cfg, rate, cycles, seed=seed)
    res = xsimulate(cfg, [wl], (algo,), warmup=0, drain_grace=GRACE)
    pst, psets = _host_run(cfg, wl, algo)
    xst = res.stats(0, 0)
    # both engines fully drain these workloads
    assert res.all_drained(0, 0)
    assert pst.packets_finished == pst.packets_created
    # identical per-packet delivery sets (the hard contract)
    assert res.delivered_sets(0, 0) == psets
    # identical conserved event counts
    assert xst.flit_link_traversals == pst.flit_link_traversals
    assert xst.packets_created == pst.packets_created
    assert xst.packets_finished == pst.packets_finished
    # latency within the documented band (usually well under 2%)
    assert xst.avg_latency == pytest.approx(pst.avg_latency, rel=0.10)
    assert sorted(xst.latencies) == xst.latencies
    assert len(xst.latencies) == len(pst.latencies)


def test_xsim_smoke_4x4_batched_jit():
    """Tiny batched sweep under jit — the CI smoke job entry point."""
    cfg = NoCConfig(n=4, dest_range=(2, 4), warmup=0, drain_grace=300)
    curves, res = latency_vs_rate_batched(
        cfg, [0.02, 0.05], ("MP", "DPM"), cycles=80, seed=1
    )
    assert set(curves) == {"MP", "DPM"}
    for algo, pts in curves.items():
        assert len(pts) == 2
        for _, lat in pts:
            assert 0 < lat < 100, (algo, lat)
    for w in range(2):
        for a in range(2):
            assert res.all_drained(w, a)


def test_xsim_pure_no_callbacks_and_vmap_stable_shapes():
    """The scan/vmap path must stay jit-pure: no host callbacks, and padded
    compiles share one shape across injection rates."""
    from repro.noc.xsim.run import _run_one
    import functools
    import jax.numpy as jnp

    cfg = NoCConfig(n=4, dest_range=(2, 4))
    wls = [synthetic_workload(cfg, r, 60, seed=0) for r in (0.02, 0.06)]
    cts = [
        compile_workload(cfg, wl, "DPM", pad_packets=256, pad_stages=16)
        for wl in wls
    ]
    shapes = [(c.enqueue.shape, c.link.shape) for c in cts]
    assert shapes[0] == shapes[1]  # stable shapes across rates

    tr = {
        f: getattr(cts[0], f)
        for f in ("enqueue", "lane", "num_stages", "link", "vcls",
                  "dslot", "lane_seq", "chl", "child_pid",
                  "child_parent", "child_rs", "child_enq", "watch_link")
    }
    fn = functools.partial(
        _run_one, T=50, F=cfg.flits_per_packet, V=cfg.vcs_per_class,
        BD=cfg.buffer_depth, L=cts[0].num_links, NN=cts[0].num_nodes,
        ND=int(cts[0].dslot.max()) + 1,
        kind=cts[0].kind, n=cts[0].n, m=cts[0].m, params=cts[0].params,
        backend="ref",
    )
    jaxpr = str(jax.make_jaxpr(fn)({k: jnp.asarray(v) for k, v in tr.items()}))
    assert "callback" not in jaxpr  # no host round-trips inside the scan
    assert "scan" in jaxpr  # the cycle loop is a lax.scan


def test_xsim_pallas_backend_matches_ref():
    """Full-engine cross-check: the Pallas arbitration path must reproduce
    the jnp reference bit for bit on a small run."""
    cfg = NoCConfig(n=4, dest_range=(2, 4))
    wl = synthetic_workload(cfg, 0.05, 40, seed=1)
    r_ref = xsimulate(cfg, [wl], ("DPM",), warmup=0, drain_grace=120,
                      backend="ref")
    r_pal = xsimulate(cfg, [wl], ("DPM",), warmup=0, drain_grace=120,
                      backend="pallas_interpret")
    assert r_ref.latencies(0, 0) == r_pal.latencies(0, 0)
    np.testing.assert_array_equal(r_ref.ctr, r_pal.ctr)
    np.testing.assert_array_equal(r_ref.dtime, r_pal.dtime)


def test_xsim_capacity_is_structural_and_slots_hint_ignored():
    """The packed-plane engine has no slot pool: capacity is the structural
    bound 2*V*L + 2*NN, a legacy ``slots=`` hint changes nothing, and the
    observed worm high-water mark stays within the bound."""
    cfg = NoCConfig(n=4, dest_range=(2, 4))
    wl = synthetic_workload(cfg, 0.10, 120, seed=2)
    big = xsimulate(cfg, [wl], ("MP",), warmup=0, drain_grace=400)
    small = xsimulate(cfg, [wl], ("MP",), warmup=0, drain_grace=400, slots=8)
    bound = 2 * cfg.vcs_per_class * (cfg.num_nodes * 4) + 2 * cfg.num_nodes
    assert big.slots == small.slots == bound  # hint ignored, bound structural
    assert 0 < big.slots_hwm() <= bound
    assert small.delivered_sets(0, 0) == big.delivered_sets(0, 0)


def test_xsim_warmup_window_matches_host_sim():
    """warmup/drain_grace flow from NoCConfig identically in both engines."""
    from repro.noc import simulate

    cfg = NoCConfig(n=4, dest_range=(2, 4), warmup=30, drain_grace=500)
    wl = synthetic_workload(cfg, 0.04, 120, seed=4)
    pst = simulate(cfg, wl, "DPM")  # uses cfg.warmup / cfg.drain_grace
    res = xsimulate(cfg, [wl], ("DPM",))
    xst = res.stats(0, 0)
    # same measured-packet set (window semantics identical), latency in band
    assert len(xst.latencies) == len(pst.latencies)
    assert xst.avg_latency == pytest.approx(pst.avg_latency, rel=0.10)


def test_xsim_counters_golden_perf_smoke():
    """Deterministic counter pin for the CI perf-regression smoke: the
    engine's conserved event counts on a fixed seeded workload are exact
    reproducible integers — any engine change that alters arbitration
    behavior (the thing per-cycle cost is spent on) moves them. Wall-clock
    is useless in CI; these are the deterministic proxy."""
    cfg = NoCConfig(n=4, dest_range=(2, 4))
    wl = synthetic_workload(cfg, 0.08, 60, seed=7)
    res = xsimulate(cfg, [wl], ("DPM", "MP"), warmup=0, drain_grace=240)
    from repro.noc.xsim.run import CTR

    golden = {
        "DPM": {"flit_link_traversals": 936, "arbitrations": 1039,
                "ni_flits": 728, "packets_finished": 91, "slots_hwm": 18},
        "MP": {"flit_link_traversals": 996, "arbitrations": 1130,
               "ni_flits": 664, "packets_finished": 83, "slots_hwm": 17},
    }
    for a, algo in enumerate(("DPM", "MP")):
        assert res.all_drained(0, a), algo
        got = dict(zip(CTR, res.ctr[a].tolist()))
        for name, want in golden[algo].items():
            assert got[name] == want, (algo, name, got)
