"""Distribution-layer tests (run in a subprocess with 8 forced host devices
so the main pytest process keeps its 1-device view)."""
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dist_checks_subprocess():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = {
        "PYTHONPATH": str(root / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "dist_checks.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL DIST CHECKS PASSED" in proc.stdout
