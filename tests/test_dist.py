"""Distribution-layer tests.

The multi-device placement checks run in a subprocess with 8 forced host
devices (so the main pytest process keeps its 1-device view); the schedule
*planning* tests below are pure host-side and run here directly.
"""
import pathlib
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dist_checks_subprocess():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = {
        "PYTHONPATH": str(root / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "JAX_PLATFORMS": "cpu",
    }
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "dist_checks.py")],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL DIST CHECKS PASSED" in proc.stdout


def test_alltoall_schedule_covers_all_pairs_as_permutation_rounds():
    """The EP dispatch schedule: every (src, dst) chunk exactly once,
    unique senders/receivers per round (the ppermute constraint), and
    wraparound hop counts from the DPM planner."""
    from repro.dist.multicast import alltoall_schedule

    for n in (4, 8):
        s = alltoall_schedule(n, "DPM")
        pairs = sorted(p for rnd in s.rounds for p in rnd)
        assert pairs == sorted(
            (i, j) for i in range(n) for j in range(n) if i != j
        )
        for rnd in s.rounds:
            senders = [a for a, _ in rnd]
            receivers = [b for _, b in rnd]
            assert len(set(senders)) == len(senders)
            assert len(set(receivers)) == len(receivers)
        # wraparound: no transfer walks more than half the ring
        assert all(h <= n // 2 for rh in s.hops for h in rh)


def test_degraded_mesh_multicast_schedule_still_covers():
    """Broken ICI links: the schedule builders plan on the degraded torus
    (detoured relay edges, segmentation-transparent hop counts) and every
    rank is still served exactly once per request, under the ppermute
    constraint, at >= the healthy hop total."""
    from repro.core import DisconnectedError, faulty, torus
    from repro.dist.multicast import schedule_multicasts

    t = torus(4, 4)
    dests = [(x, y) for x in range(4) for y in range(4) if (x, y) != (0, 0)]
    healthy = schedule_multicasts(t, [((0, 0), dests)])
    degraded = schedule_multicasts(
        t, [((0, 0), dests)],
        broken_links=[((0, 0), (1, 0)), ((2, 2), (2, 3))],
    )
    for s in (healthy, degraded):
        served = [d for rnd in s.rounds for _, d in rnd]
        assert sorted(served) == sorted(set(served))  # once per rank
        assert set(served) == {t.idx(d) for d in dests}
        for rnd in s.rounds:
            assert len({a for a, _ in rnd}) == len(rnd)
            assert len({b for _, b in rnd}) == len(rnd)
    assert degraded.total_hops >= healthy.total_hops  # detours cost hops
    # a rank cut off from the fabric fails loudly at planning time
    cut = [((3, 3), (0, 3)), ((3, 3), (2, 3)), ((3, 3), (3, 0)),
           ((3, 3), (3, 2))]
    with pytest.raises(DisconnectedError):
        schedule_multicasts(t, [((0, 0), [(3, 3)])], broken_links=cut)
    assert faulty(t, ()) is t


def test_dpm_alltoall_beats_ring_shift_on_link_bytes():
    from repro.dist.multicast import alltoall_schedule, ring_alltoall_schedule

    for n in (8, 16):
        dpm = alltoall_schedule(n, "DPM").cost(1 << 20)
        ring = ring_alltoall_schedule(n).cost(1 << 20)
        assert dpm["link_bytes"] < ring["link_bytes"]
        assert dpm["rounds"] <= ring["rounds"]


def test_dpm_broadcast_halves_ring_rounds():
    from repro.dist.multicast import dp_broadcast_schedule, ring_broadcast_schedule

    dpm = dp_broadcast_schedule(16, "DPM")
    ring = ring_broadcast_schedule(16)
    assert dpm.num_rounds < ring.num_rounds
    assert dpm.cost(1 << 20)["time_us"] < ring.cost(1 << 20)["time_us"]


def test_schedule_cost_per_request_payloads():
    """cost(req_payload_bytes=...) prices each transfer by its own chunk."""
    from repro.dist.multicast import Schedule, alltoall_schedule

    s = alltoall_schedule(4, "DPM")
    uniform = s.cost(1 << 10)
    per_req = s.cost(1 << 10, req_payload_bytes={})  # all fall back
    assert per_req["link_bytes"] == uniform["link_bytes"]
    half = {r: 1 << 9 for rr in s.round_reqs for r in rr}
    assert s.cost(1 << 10, req_payload_bytes=half)["link_bytes"] == (
        uniform["link_bytes"] / 2
    )
    # a hand-built Schedule without round_reqs must not drop transfers
    bare = Schedule(4, [[(0, 1), (2, 3)]], [[1, 1]])
    assert bare.cost(1 << 10, req_payload_bytes={})["link_bytes"] == (
        bare.cost(1 << 10)["link_bytes"]
    )


def test_pipeline_rejects_stage_count_mismatch():
    """More stage slices than pipe ranks must raise, not silently drop
    layers (the per-stage [0] slice would otherwise eat them)."""
    import jax.numpy as jnp

    from repro.dist.pipeline import pipeline_apply
    from repro.dist.sharding import abstract_mesh

    mesh = abstract_mesh(("pipe", 2))
    ws = jnp.zeros((4, 1, 8, 8))  # 4 stage slices on a 2-rank axis
    x = jnp.zeros((4, 2, 8))
    with pytest.raises(ValueError, match="stage_params leading dim"):
        pipeline_apply(lambda w, h: h @ w, ws, x, mesh)
