"""Training loop, checkpointing (incl. elastic re-shard), serving tests."""
import dataclasses
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore, save
from repro.configs import SMOKES
from repro.models import RunConfig, model_init
from repro.serve import BatchServer, Request, generate
from repro.train import (
    LoopConfig,
    build_train_step,
    init_state,
    synthetic_batch,
    train,
)

RUN = RunConfig(
    remat="none", attn_chunk_q=32, attn_chunk_k=32, vocab_round=64,
    learning_rate=3e-3,
)


def test_loss_decreases_and_restart_resumes(tmp_path):
    cfg = SMOKES["smollm-135m"]
    res = train(
        cfg, RUN,
        LoopConfig(steps=25, batch=4, seq=64, ckpt_every=10,
                   ckpt_dir=str(tmp_path), log_every=0),
    )
    assert res.losses[-1] < res.losses[0] - 0.5
    assert latest_step(tmp_path) == 25
    # restart continues from the checkpoint, not from scratch
    res2 = train(
        cfg, RUN,
        LoopConfig(steps=30, batch=4, seq=64, ckpt_every=10,
                   ckpt_dir=str(tmp_path), log_every=0),
    )
    assert res2.resumed_from == 25
    assert len(res2.losses) == 5


def test_restart_stream_is_bitwise_deterministic():
    """Data pipeline is a pure function of step: the same batch at step k."""
    cfg = SMOKES["smollm-135m"]
    b1 = synthetic_batch(cfg, 4, 32, seed=0, step=17)
    b2 = synthetic_batch(cfg, 4, 32, seed=0, step=17)
    for k in b1:
        np.testing.assert_array_equal(np.asarray(b1[k]), np.asarray(b2[k]))


def test_grad_accumulation_matches_full_batch():
    cfg = SMOKES["smollm-135m"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    state = init_state(params)
    batch = synthetic_batch(cfg, 8, 32, seed=0, step=0)
    s1, m1 = jax.jit(build_train_step(cfg, RUN, accum=1))(state, batch)
    s2, m2 = jax.jit(build_train_step(cfg, RUN, accum=4))(state, batch)
    # same loss and (nearly) same updated params
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params))
    )
    assert d < 1e-4


def test_checkpoint_elastic_reshard():
    """A checkpoint restores under a different device/mesh layout (here:
    different target shardings on 1 device — the device_put path)."""
    cfg = SMOKES["mamba2-1.3b"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    state = init_state(params)
    with tempfile.TemporaryDirectory() as td:
        save(td, 7, state)
        assert latest_step(td) == 7
        restored = restore(td, 7, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_checkpoint_ignored(tmp_path):
    cfg = SMOKES["smollm-135m"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    state = init_state(params)
    save(tmp_path, 5, state)
    # simulate a crashed save at step 10
    broken = pathlib.Path(tmp_path) / "step_00000010"
    (broken / "arrays").mkdir(parents=True)
    assert latest_step(tmp_path) == 5


def test_generate_and_batch_server():
    cfg = SMOKES["smollm-135m"]
    params, _ = model_init(jax.random.PRNGKey(0), cfg, RUN)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    res = generate(params, cfg, RUN, prompts, steps=8)
    assert res.tokens.shape == (2, 8)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab).all()
    # greedy decoding is deterministic
    res2 = generate(params, cfg, RUN, prompts, steps=8)
    np.testing.assert_array_equal(res.tokens, res2.tokens)

    server = BatchServer(params, cfg, RUN, max_batch=4, max_wait_s=0.01)
    rng = np.random.default_rng(0)
    for rid in range(4):
        server.submit(Request(rid, rng.integers(0, cfg.vocab, 12), 4))
    got = []
    while len(got) < 4:
        got.extend(server.serve_once())
    assert sorted(r.rid for r in got) == [0, 1, 2, 3]
    assert all(r.tokens.shape == (4,) for r in got)


def test_straggler_watchdog_records():
    """The loop's per-step EWMA watchdog exists and runs (no stragglers on
    a quiet box, but the field must be populated)."""
    cfg = SMOKES["smollm-135m"]
    res = train(cfg, RUN, LoopConfig(steps=6, batch=2, seq=32, log_every=0))
    assert isinstance(res.straggler_steps, list)
    assert res.wall_s > 0
