"""End-to-end behaviour tests for the paper's system (replaces scaffold)."""
import jax
import jax.numpy as jnp

from repro.configs import SMOKES, cells
from repro.core import dpm_partition, grid, plan
from repro.dist.multicast import Torus, plan_torus_multicast, schedule_multicasts
from repro.models import RunConfig
from repro.train import LoopConfig, train


def test_end_to_end_training_reduces_loss():
    """Deliverable (b): the end-to-end driver trains and learns."""
    run = RunConfig(remat="none", attn_chunk_q=32, attn_chunk_k=32,
                    vocab_round=64, learning_rate=3e-3)
    res = train(SMOKES["smollm-135m"], run,
                LoopConfig(steps=20, batch=4, seq=64, log_every=0))
    assert res.losses[-1] < res.losses[0] - 0.3


def test_paper_pipeline_plan_to_simulation():
    """Plan -> partitions -> simulator, the paper's full pipeline."""
    from repro.noc import NoCConfig, WormholeSim

    g = grid(8)
    src, dests = (3, 3), [(0, 0), (1, 6), (6, 1), (7, 7), (5, 5), (2, 2)]
    res = dpm_partition(g, src, dests)
    assert sum(len(p.dests) for p in res.partitions) == len(dests)
    total = {}
    for algo in ("MU", "DPM"):
        sim = WormholeSim(NoCConfig())
        sim.add_plan(plan(algo, g, src, dests), 0)
        st = sim.run(5000)
        assert st.packets_created == st.packets_finished
        total[algo] = st.flit_link_traversals
    assert total["DPM"] < total["MU"]  # the paper's whole point


def test_tpu_adaptation_schedules_deliver():
    t = Torus(16, 16)
    src, dests = (2, 3), [(2, 7), (3, 7), (14, 3), (2, 12), (9, 9)]
    p = plan_torus_multicast(t, src, dests)
    assert p.check_covers()
    sched = schedule_multicasts(t, [(src, dests)])
    have = {t.idx(src)}
    for rnd in sched.rounds:
        have |= {d for s, d in rnd if s in have}
    assert all(t.idx(d) in have for d in dests)


def test_cell_registry_covers_assignment():
    """40 assigned cells = 32 runnable + 8 documented long_500k skips."""
    runnable = cells()
    assert len(runnable) == 32
    long_archs = {a for a, s in runnable if s == "long_500k"}
    assert long_archs == {"hymba-1.5b", "mamba2-1.3b"}
