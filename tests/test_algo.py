"""Registry + cost-model tests (repro.core.algo, DESIGN.md §6).

Covers the ISSUE-4 satellites: registry edge cases (duplicate registration,
topology-kind filtering, unknown-name errors listing what exists), the
cost-model-keyed plan cache with its info/clear API, DPM-E correctness
(covering, drains in the wormhole simulator, never beats the restricted
optimum under its own objective), and the toy-algorithm end-to-end smoke the
CI registry step runs first.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    CostModel,
    MulticastPlan,
    PacketPath,
    available_algorithms,
    available_cost_models,
    brute_force_partition,
    dpm_partition,
    get_algorithm,
    get_cost_model,
    grid,
    plan,
    plan_cache_clear,
    plan_cache_info,
    register_algorithm,
    temporary_algorithm,
    torus,
    xy_route,
)
from repro.core.algo import RoutingAlgorithm, is_registered_cost_model

G8 = grid(8)
T8 = torus(8)


def _toy_mu(g, src, dests):
    """MU clone used as the registrable toy algorithm in these tests."""
    p = MulticastPlan("TOY", src, list(dests))
    for d in dests:
        p.paths.append(PacketPath(xy_route(g, src, d), [d]))
    return p


# ---------------------------------------------------------------- registry
def test_builtins_registered_with_expected_metadata():
    assert available_algorithms()[:5] == ["MU", "DP", "MP", "NMP", "DPM"]
    assert "DPM-E" in available_algorithms()
    assert available_algorithms(tag="fig") == ["MU", "MP", "NMP", "DPM"]
    assert get_algorithm("DPM").cost_sensitive
    assert not get_algorithm("MU").cost_sensitive
    assert get_algorithm("DPM-E").default_cost_model == "energy"
    for name in ("hops", "contention", "energy"):
        assert name in available_cost_models()


def test_duplicate_registration_raises():
    with temporary_algorithm(_toy_mu, name="TOY-DUP"):
        with pytest.raises(ValueError, match="already registered"):
            register_algorithm(_toy_mu, name="TOY-DUP")
    # context manager unregistered it: registering again is fine now
    with temporary_algorithm(_toy_mu, name="TOY-DUP"):
        pass


def test_duplicate_cost_model_registration_raises():
    from repro.core import register_cost_model

    with pytest.raises(ValueError, match="already registered"):
        register_cost_model(get_cost_model("hops"), name="hops")


def test_unknown_algorithm_error_lists_registered():
    with pytest.raises(KeyError, match="unknown routing algorithm 'NOPE'"):
        get_algorithm("NOPE")
    with pytest.raises(KeyError, match="MU, DP, MP, NMP, DPM, DPM-E"):
        plan("NOPE", G8, (0, 0), [(1, 1)])
    with pytest.raises(
        KeyError, match="registered: hops, contention, weighted, energy"
    ):
        get_cost_model("joules")


def test_available_algorithms_filters_by_topology_kind():
    with temporary_algorithm(_toy_mu, name="MESH-ONLY", topologies=("mesh",)):
        assert "MESH-ONLY" in available_algorithms("mesh")
        assert "MESH-ONLY" in available_algorithms(G8)
        assert "MESH-ONLY" not in available_algorithms("torus")
        assert "MESH-ONLY" not in available_algorithms(T8)
        # planning on the unsupported kind is rejected with the capability
        with pytest.raises(ValueError, match="does not support topology kind"):
            plan("MESH-ONLY", T8, (0, 0), [(1, 1)])
        assert plan("MESH-ONLY", G8, (0, 0), [(1, 1)]).check_covers()
    assert "MESH-ONLY" not in available_algorithms()


def test_class_based_registration_and_instance_passthrough():
    class Star(RoutingAlgorithm):
        name = "STAR-CLS"
        topologies = frozenset({"mesh"})

        def plan(self, topo, src, dests, *, cost_model):
            return _toy_mu(topo, src, dests)

    with temporary_algorithm(Star):
        assert get_algorithm("STAR-CLS").topologies == frozenset({"mesh"})
        assert plan("STAR-CLS", G8, (2, 2), [(5, 5), (0, 7)]).check_covers()
    # unregistered instances plan uncached but still work
    inst = Star()
    p1 = plan(inst, G8, (2, 2), [(5, 5)])
    p2 = plan(inst, G8, (2, 2), [(5, 5)])
    assert p1.check_covers() and p1 is not p2  # no cache entry for strangers


# ---------------------------------------------------------------- the cache
def test_plan_cache_keyed_on_cost_model_and_info_clear():
    plan_cache_clear()
    src, dests = (3, 3), [(0, 0), (7, 7), (1, 6), (6, 1), (5, 5)]
    a = plan("DPM", G8, src, dests)
    assert plan_cache_info().misses == 1
    assert plan("DPM", G8, src, dests) is a  # hit
    assert plan_cache_info().hits == 1
    # a second cost model MUST NOT alias the first's entry (the old bug)
    b = plan("DPM", G8, src, dests, cost_model="energy")
    assert b is not a
    assert plan_cache_info().misses == 2
    assert plan("DPM", G8, src, dests, cost_model="energy") is b
    # explicitly passing the default model lands on the default entry
    assert plan("DPM", G8, src, dests, cost_model="hops") is a
    # cost-insensitive algorithms share one entry across models
    m = plan("MU", G8, src, dests)
    assert plan("MU", G8, src, dests, cost_model="energy") is m
    plan_cache_clear()
    assert plan_cache_info().currsize == 0 and plan_cache_info().hits == 0


def test_plan_cache_unregistered_cost_model_bypasses_cache():
    class Doubled(CostModel):
        name = "doubled-hops"  # never registered

        def link_cost(self, g, u, v):
            return 2.0

    src, dests = (1, 1), [(6, 6), (0, 5)]
    before = plan_cache_info().currsize
    p1 = plan("DPM", G8, src, dests, cost_model=Doubled())
    p2 = plan("DPM", G8, src, dests, cost_model=Doubled())
    assert p1.check_covers() and p1 is not p2
    assert plan_cache_info().currsize == before  # nothing cached under a name
    assert not is_registered_cost_model(Doubled())


def test_temporary_algorithm_flushes_plan_cache_on_exit():
    src, dests = (0, 0), [(3, 3)]
    with temporary_algorithm(_toy_mu, name="EPHEMERAL"):
        plan("EPHEMERAL", G8, src, dests)
    # same name, different planner: must not serve the old cached plan
    def other(g, s, d):
        p = _toy_mu(g, s, d)
        p.algorithm = "EPHEMERAL-2"
        return p

    with temporary_algorithm(other, name="EPHEMERAL"):
        assert plan("EPHEMERAL", G8, src, dests).algorithm == "EPHEMERAL-2"


def test_failed_reregistration_does_not_rename_existing_instance():
    class Mine(RoutingAlgorithm):
        name = "MINE-RENAME"

        def plan(self, topo, src, dests, *, cost_model):
            return _toy_mu(topo, src, dests)

    inst = Mine()
    with temporary_algorithm(inst):
        with pytest.raises(ValueError, match="already registered"):
            register_algorithm(inst, name="DPM")  # clashes with a builtin
        # the failed call must not have renamed the registered instance
        assert inst.name == "MINE-RENAME"
        assert get_algorithm("MINE-RENAME") is inst
        p1 = plan("MINE-RENAME", G8, (0, 0), [(2, 2)])
        assert plan("MINE-RENAME", G8, (0, 0), [(2, 2)]) is p1  # still cached


def test_cost_model_instance_registered_under_custom_name_stays_cacheable():
    from repro.core import register_cost_model, unregister_cost_model
    from repro.core.algo import LinkContentionCost

    cm = LinkContentionCost(lam=2.0)
    register_cost_model(cm, name="contention2")
    try:
        assert cm.name == "contention2"  # synced to the registration key
        assert is_registered_cost_model(cm)
        src, dests = (2, 2), [(5, 5), (0, 7), (7, 0)]
        p1 = plan("DPM", G8, src, dests, cost_model="contention2")
        assert plan("DPM", G8, src, dests, cost_model="contention2") is p1
    finally:
        unregister_cost_model("contention2")


# ------------------------------------------------------------- cost models
def test_hop_cost_model_matches_legacy_routing_costs():
    from repro.core import dual_path_cost, multi_unicast_cost

    cm = get_cost_model("hops")
    rng = random.Random(11)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    for g in (G8, T8):
        for _ in range(50):
            picks = rng.sample(nodes, rng.randint(3, 10))
            src, dests = picks[0], picks[1:]
            assert cm.multi_unicast_cost(g, src, dests) == multi_unicast_cost(
                g, src, dests
            )
            assert cm.dual_path_cost(g, src, dests) == dual_path_cost(
                g, src, dests
            )
            assert isinstance(cm.multi_unicast_cost(g, src, dests), int)


def test_contention_model_weights_mesh_center_links():
    cm = get_cost_model("contention")
    center = cm.link_cost(G8, (3, 0), (4, 0))  # peak bisection cut
    edge = cm.link_cost(G8, (0, 0), (1, 0))
    assert center > edge > 1.0
    assert cm.link_cost(T8, (3, 0), (4, 0)) == 1.0  # torus: edge-transitive


def test_energy_model_charges_injection_per_worm():
    cm = get_cost_model("energy")
    g = G8
    assert cm.packet_overhead(g) > 0
    # two unicasts pay two injections (one per worm) on top of their routes
    mu = cm.multi_unicast_cost(g, (0, 0), [(1, 0), (2, 0)])
    routes = cm.unicast_cost(g, (0, 0), (1, 0)) + cm.unicast_cost(g, (0, 0), (2, 0))
    assert mu == pytest.approx(routes + 2 * cm.packet_overhead(g))
    # a single 2-dest chain pays the injection exactly once
    chain = cm.dual_path_cost(g, (0, 0), [(1, 0), (2, 0)])
    assert chain == pytest.approx(
        cm.route_cost(g, [(0, 0), (1, 0), (2, 0)]) + cm.packet_overhead(g)
    )


# ------------------------------------------------------------------- DPM-E
def test_dpm_e_covers_drains_and_respects_restricted_optimum():
    rng = random.Random(4)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    from repro.noc import NoCConfig, WormholeSim

    for g in (G8, T8):
        sim = WormholeSim(NoCConfig(topology=g.kind))
        t = 0
        for _ in range(20):
            picks = rng.sample(nodes, rng.randint(3, 9))
            src, dests = picks[0], picks[1:]
            p = plan("DPM-E", g, src, dests)
            assert p.check_covers(), (g.kind, src, dests)
            for path in p.paths:  # hop adjacency under the topology's links
                for a, b in zip(path.hops, path.hops[1:]):
                    assert b in g.neighbors(*a)
            # greedy never beats the exact optimum of its own objective
            r = dpm_partition(g, src, dests, cost_model="energy")
            opt, _ = brute_force_partition(g, src, dests, cost_model="energy")
            assert r.total_cost() >= opt - 1e-9
            sim.add_request("DPM-E", src, dests, t)
            t += 40
        st = sim.run(20_000)
        # deadlock-class check, operationally: every packet finishes
        assert st.packets_created == st.packets_finished


def test_dpm_e_no_worse_than_dpm_on_energy_in_aggregate():
    em = get_cost_model("energy")
    rng = random.Random(2)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    tot_dpm = tot_dpme = 0.0
    for _ in range(120):
        picks = rng.sample(nodes, rng.randint(8, 17))
        src, dests = picks[0], picks[1:]
        tot_dpm += em.plan_cost(G8, plan("DPM", G8, src, dests))
        tot_dpme += em.plan_cost(G8, plan("DPM-E", G8, src, dests))
    assert tot_dpme <= tot_dpm


# ------------------------------------------------- property-based coverage
coord8 = st.tuples(st.integers(0, 7), st.integers(0, 7))
dest_sets = st.lists(coord8, min_size=1, max_size=12, unique=True)


@given(coord8, dest_sets)
@settings(max_examples=40, deadline=None)
def test_every_registered_algorithm_covers_on_mesh_and_torus(src, dests):
    dests = [d for d in dests if d != src]
    if not dests:
        return
    for g in (G8, T8):
        for name in available_algorithms(g):
            p = plan(name, g, src, dests)
            assert p.check_covers(), (name, g.kind, src, dests)


# ------------------------------------------------------ end-to-end CI smoke
def test_registry_smoke_toy_algorithm_end_to_end():
    """The CI registry smoke: register a toy algorithm, push a 4x4 workload
    through the cached planner, the wormhole simulator, AND an xsim batch —
    zero edits to any consumer file."""
    from repro.noc import NoCConfig, WormholeSim, synthetic_workload, xsimulate

    with temporary_algorithm(_toy_mu, name="TOY"):
        cfg = NoCConfig(n=4, dest_range=(2, 4), warmup=0, drain_grace=400)
        wl = synthetic_workload(cfg, 0.04, 80, seed=5)
        # host engine via the registry
        sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
        for r in wl.requests:
            sim.add_request("TOY", r.src, r.dests, r.time)
        pst = sim.run(wl.horizon + cfg.drain_grace)
        assert pst.packets_created == pst.packets_finished
        # batched engine via the registry, toy algo next to a builtin
        res = xsimulate(cfg, [wl], ("TOY", "DPM"))
        assert res.algos == ("TOY", "DPM")
        for a in range(2):
            assert res.all_drained(0, a)
        # parity: the toy algorithm's delivery latencies agree across engines
        assert res.stats(0, 0).avg_latency == pytest.approx(
            pst.avg_latency, rel=0.10
        )
    assert "TOY" not in available_algorithms()
