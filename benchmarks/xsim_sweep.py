"""xsim vs WormholeSim: wall-clock + fig6-style batched latency curves.

Protocol (all knobs through ``NoCConfig`` — satellite of ISSUE 3):

* a saturation-regime fig6-style sweep — 10 injection rates x 4 algorithms
  (MU/MP/NMP/DPM) on the paper's 8x8 mesh at the heaviest destination range
  (10-16) — run twice: sequentially through the event-ordered Python
  ``WormholeSim`` (one ``simulate`` per point) and as batched ``xsimulate``
  dispatches (the whole grid in one vmapped/pmapped scan).
* the planner cache is pre-warmed untimed for both engines (planning is
  shared infrastructure); the xsim timing *includes* host lowering, XLA
  compilation and the device run — everything a user pays.
* cross-validation gate: on small mesh/torus workloads, per-packet delivery
  sets must be identical and average latency within 10% (the xsim fidelity
  contract, also pinned by tests/test_xsim.py).
* contention-aware DPM (ROADMAP item): the saturated tail of the same grid
  re-run with DPM planning under the "contention" cost model — central
  mesh links priced up, steering merges toward the edge — against plain
  hop-count DPM, with a gate that the two latency curves actually diverge
  at saturation (plans must differ AND latency must move; at low load the
  two are intentionally near-identical).

* a scale section (ISSUE 6 tentpole gate): 32x32 meshes (16x16 in quick
  mode) through the fused packed-plane cycle engine, batching a (fault
  rung x injection rate x algorithm x seed) grid — the fault axis runs a
  healthy mesh and a clustered *router* failure (``core.router_failure``)
  on the outer loop (fault sets change the plans, so they can't share one
  compiled batch), while rate x algo x seed ride the vmapped/pmap-sharded
  batch axis of one ``xsimulate`` call per rung. Reports sustained
  packet-hops/second against the pre-PR committed baseline (see
  ``_COMMITTED_BASELINE``) and writes the repo-root ``BENCH_xsim.json``
  perf-trajectory artifact.

The committed artifact (results/xsim_sweep.json) records curves from both
engines, the wall-clock breakdown, measured speedup, parity results, and the
host parallelism available — the batch axis shards across forced host CPU
devices, so the speedup scales with cores (this container has very few; see
the artifact's "env" block).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

CACHE = pathlib.Path(__file__).parent / "results" / "xsim_sweep.json"
BENCH = pathlib.Path(__file__).parent.parent / "BENCH_xsim.json"

# The perf gate's reference point: the last xsim_sweep.json committed before
# the fused packed-plane engine landed (slot-pool engine, this 8x8 sweep
# protocol). Its sustained wall-clock is recorded in that artifact; the hop
# total is the sweep's conserved flit_link_traversals sum, which is plan-
# determined and engine-independent (delivery-set parity pins it), so it
# reproduces exactly by re-counting the same workload grid. Measured on 2
# forced host CPU devices — note the per-core scaling when comparing.
_COMMITTED_BASELINE = {
    "hops": 4_384_342,
    "sustained_wall_s": 31.67,
    "hops_per_s": 138_438,
    "cpu_devices": 2,
}


def _force_host_devices() -> None:
    """Shard the batched scan across host cores (one forced CPU device per
    core). Only possible before the jax backend initializes, and only done
    when this suite runs — never as an import side effect, so other
    benchmark suites keep their default single-device topology."""
    if "XLA_FLAGS" in os.environ:
        return
    try:
        from jax._src import xla_bridge

        if xla_bridge._backends:  # backend already up: too late, no-op
            return
    except Exception:
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.cpu_count() or 1}"
    )

PARITY_CASES = [
    ("mesh-unicast", dict(n=4, multicast_fraction=0.0), 0.05, 100, "MU"),
    ("mesh-multicast", dict(n=5, multicast_fraction=0.5,
                            dest_range=(3, 6)), 0.04, 150, "DPM"),
    ("torus-multicast", dict(n=4, topology="torus",
                             dest_range=(2, 5)), 0.06, 150, "DPM"),
]


def _parity_case(name, cfg_kw, rate, cycles, algo):
    from repro.core import plan
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, WormholeSim, synthetic_workload, xsimulate

    cfg = NoCConfig(warmup=0, drain_grace=800, **cfg_kw)
    wl = synthetic_workload(cfg, rate, cycles, seed=2)
    res = xsimulate(cfg, [wl], (algo,))
    g = make_topology(cfg.topology, cfg.n, cfg.m)
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_plan(plan(algo, g, r.src, r.dests), r.time)
    pst = sim.run(wl.horizon + cfg.drain_grace)
    psets = {pk.pid: {g.idx(c) for c in pk.delivery_times}
             for pk in sim.packets}
    xlat = float(res.avg_latency(0, 0))
    dev = abs(xlat - pst.avg_latency) / max(1e-9, pst.avg_latency)
    return {
        "case": name,
        "delivery_sets_equal": bool(psets == res.delivered_sets(0, 0)),
        "latency_py": round(pst.avg_latency, 3),
        "latency_xsim": round(xlat, 3),
        "latency_rel_dev": round(dev, 4),
        "within_10pct": bool(dev <= 0.10),
    }


def _drop_node(wl, dead):
    """Filter a workload for a failed router: it can neither source nor
    sink packets (every incident link is down)."""
    from dataclasses import replace

    from repro.noc.traffic import Workload

    reqs = []
    for r in wl.requests:
        if r.src == dead:
            continue
        dests = [d for d in r.dests if d != dead]
        if dests:
            reqs.append(replace(r, dests=dests))
    return Workload(name=f"{wl.name}-minus-{dead}", requests=reqs,
                    horizon=wl.horizon)


def _scale_section(quick: bool):
    """32x32 (16x16 quick) batched sweep over (fault x rate x algo x seed).

    One ``xsimulate`` call per fault rung carries the full rate x algo x
    seed grid on the vmapped (and, with >1 host device, pmap-sharded)
    batch axis. Returns the artifact block + CSV rows; asserts the ISSUE 6
    perf gate (>= 5x the committed baseline's sustained packet-hops/s) in
    full mode.
    """
    import jax

    from repro.core import plan, router_failure
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, synthetic_workload, xsimulate
    from repro.noc.xsim.run import CTR

    n = 16 if quick else 32
    cycles = 300 if quick else 1000
    rates = [0.05] if quick else [0.04, 0.06]
    seeds = [0] if quick else [0, 1]
    algos = ("DPM",) if quick else ("DPM", "MP")
    flit_i = CTR.index("flit_link_traversals")
    base = make_topology("mesh", n, None)
    dead = (n // 2, n // 2)
    rungs = [("healthy", ()), ("router_failure", router_failure(base, dead))]

    per_rung = {}
    total_hops, total_sustained = 0, 0.0
    for rname, broken in rungs:
        cfg = NoCConfig(n=n, dest_range=(4, 8), warmup=100,
                        drain_grace=400, broken_links=broken)
        topo = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
        wls = []
        for rate in rates:
            for seed in seeds:
                wl = synthetic_workload(cfg, rate, cycles, seed=seed)
                wls.append(_drop_node(wl, dead) if broken else wl)
        for wl in wls:  # planner cache warm-up, untimed (shared infra)
            for req in wl.requests:
                for a in algos:
                    plan(a, topo, req.src, req.dests)
        t0 = time.monotonic()
        res = xsimulate(cfg, wls, algos)
        t_cold = time.monotonic() - t0
        t0 = time.monotonic()
        res = xsimulate(cfg, wls, algos)
        t_sus = time.monotonic() - t0
        hops = int(res.ctr[:, flit_i].sum())
        assert 0 < res.slots_hwm() <= res.slots
        total_hops += hops
        total_sustained += t_sus
        per_rung[rname] = {
            "batch_points": len(wls) * len(algos),
            "broken_links": len(broken),
            "cycles_simulated": res.cycles,
            "hops": hops,
            "cold_s": round(t_cold, 2),
            "sustained_s": round(t_sus, 2),
            "hops_per_s_sustained": int(hops / max(1e-9, t_sus)),
            "worm_pool_capacity": res.slots,
            "worm_pool_hwm": res.slots_hwm(),
            "avg_latency_rate0": {
                a: round(float(res.avg_latency(0, i)), 2)
                for i, a in enumerate(res.algos)
            },
        }
    hops_per_s = total_hops / max(1e-9, total_sustained)
    speedup = hops_per_s / _COMMITTED_BASELINE["hops_per_s"]
    devices = jax.local_device_count()
    block = {
        "mesh": f"{n}x{n}", "cycles": cycles, "rates": rates,
        "seeds": seeds, "algos": list(algos),
        "axes": "fault rung (outer) x rate x algo x seed (batched)",
        "per_rung": per_rung,
        "sustained_hops_per_s": int(hops_per_s),
        "committed_baseline": _COMMITTED_BASELINE,
        "speedup_vs_committed_sustained": round(speedup, 2),
        "scaling_note": (
            "the committed baseline ran with "
            f"{_COMMITTED_BASELINE['cpu_devices']} forced host CPU devices; "
            f"this run had {devices} (see env) — the batch axis pmap-shards "
            "across devices, so per-core the fused-engine gain is ~2x the "
            "reported ratio when devices=1. Sustained includes host "
            "lowering + the device scan; the device scan alone runs "
            "~1.6us/cycle/1024-node-mesh-instance (flat in pool size: "
            "state is router-centric, not worm-centric)"
        ),
    }
    if not quick:
        assert speedup >= 5.0, (
            f"fused-engine perf gate: {hops_per_s:,.0f} hops/s is only "
            f"{speedup:.2f}x the committed baseline "
            f"{_COMMITTED_BASELINE['hops_per_s']:,} hops/s"
        )
    rows = [
        (f"xsim_sweep/scale_{n}x{n}/{rname}", r["sustained_s"] * 1e6,
         f"points={r['batch_points']};hops={r['hops']};"
         f"hops_per_s={r['hops_per_s_sustained']};hwm={r['worm_pool_hwm']}")
        for rname, r in per_rung.items()
    ]
    rows.append((
        f"xsim_sweep/scale_{n}x{n}/gate", 0.0,
        f"sustained_hops_per_s={int(hops_per_s)};"
        f"speedup_vs_committed=x{speedup:.2f};devices={devices}",
    ))
    return block, rows


def run(quick: bool = False, algos=None):
    _force_host_devices()
    import jax

    from repro.core import plan
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, simulate, synthetic_workload, xsimulate

    from .noc_common import resolve_algos

    # registry figure set + DPM-E: the sweep doubles as the demonstration
    # that a cost-model variant rides the batched engine unmodified
    algos = tuple(
        resolve_algos(algos) + ([] if algos is not None else ["DPM-E"])
    )
    cycles = 250 if quick else 600
    rates = (
        [0.06, 0.10, 0.14]
        if quick
        else [0.05, 0.06, 0.07, 0.08, 0.09, 0.10, 0.11, 0.12, 0.13, 0.14]
    )
    cfg = NoCConfig(dest_range=(10, 16), warmup=100, drain_grace=400)
    wls = [synthetic_workload(cfg, r, cycles, seed=3) for r in rates]

    # planner cache warmup — shared infrastructure, untimed for both engines
    g = make_topology(cfg.topology, cfg.n, cfg.m)
    for wl in wls:
        for r in wl.requests:
            for a in algos:
                plan(a, g, r.src, r.dests)

    # --- sequential Python WormholeSim baseline -------------------------
    py_curves: dict[str, list] = {a: [] for a in algos}
    t0 = time.monotonic()
    for rate, wl in zip(rates, wls):
        for algo in algos:
            st = simulate(cfg, wl, algo)
            py_curves[algo].append((rate, round(st.avg_latency, 2)))
    t_py = time.monotonic() - t0

    # --- batched xsim: the whole grid through one engine ----------------
    t0 = time.monotonic()
    res = xsimulate(cfg, wls, algos)
    x_curves = {
        algo: [(rates[w], round(float(res.avg_latency(w, a)), 2))
               for w in range(len(rates))]
        for a, algo in enumerate(algos)
    }
    t_x_cold = time.monotonic() - t0
    # sustained: same shapes, XLA executable cached — the marginal cost of
    # the next sweep in a design-space-exploration campaign
    t0 = time.monotonic()
    xsimulate(cfg, wls, algos)
    t_x = time.monotonic() - t0
    from repro.noc.xsim.run import CTR

    hops_8x8 = int(res.ctr[:, CTR.index("flit_link_traversals")].sum())

    # --- contention-aware DPM at saturation (ROADMAP item) --------------
    # the heaviest rates of the same grid, DPM planned under "contention"
    # (mesh bisection links cost more) vs the plain hop objective; needs
    # the plain-DPM curve as baseline, so it only runs when DPM is in the
    # sweep set (an --algos override may exclude it)
    contention = None
    sat_rates = rates[-3:]
    sat_wls = wls[-3:]
    if "DPM" in algos:
        for wl in sat_wls:  # warm the contention plans untimed, like the rest
            for r in wl.requests:
                plan("DPM", g, r.src, r.dests, cost_model="contention")
        res_c = xsimulate(cfg, sat_wls, ("DPM",), cost_model="contention")
        dpm_plain = dict(x_curves["DPM"])
        curve_contention = [
            (sat_rates[w], round(float(res_c.avg_latency(w, 0)), 2))
            for w in range(len(sat_rates))
        ]
        plans_differ = sum(
            1
            for wl in sat_wls
            for r in wl.requests
            if [p.hops for p in plan("DPM", g, r.src, r.dests).paths]
            != [p.hops for p in
                plan("DPM", g, r.src, r.dests, cost_model="contention").paths]
        )
        rel_div = [
            abs(lat - dpm_plain[rate]) / max(1e-9, dpm_plain[rate])
            for rate, lat in curve_contention
        ]
        contention = {
            "rates": sat_rates,
            "dpm_plain": [(r, dpm_plain[r]) for r in sat_rates],
            "dpm_contention": curve_contention,
            "plans_differ": plans_differ,
            "max_rel_divergence": round(max(rel_div), 4),
            "diverges_at_saturation": bool(
                plans_differ > 0 and max(rel_div) > 0.01
            ),
        }
        assert contention["diverges_at_saturation"], (
            "contention-priced DPM is indistinguishable from plain DPM at "
            f"saturation: {contention}"
        )

    # --- scale section: fused engine at 32x32 (fault x rate x algo x seed)
    scale, scale_rows = _scale_section(quick)

    parity = [_parity_case(*case) for case in PARITY_CASES]
    speedup = t_py / max(1e-9, t_x)
    speedup_cold = t_py / max(1e-9, t_x_cold)

    data = {
        "sweep": {
            "mesh": "8x8", "dest_range": [10, 16], "cycles": cycles,
            "warmup": cfg.warmup, "drain_grace": cfg.drain_grace,
            "rates": rates, "algos": list(algos),
            "points": len(rates) * len(algos),
        },
        "wall_clock_s": {
            "python_wormhole_sequential": round(t_py, 2),
            "xsim_batched_cold": round(t_x_cold, 2),
            "xsim_batched_sustained": round(t_x, 2),
            "xsim_note": "cold includes host lowering + XLA compile + device"
                         " run; sustained reuses the cached executable (the"
                         " marginal sweep cost); planner cache pre-warmed"
                         " untimed for both engines",
        },
        "speedup": round(speedup, 2),
        "speedup_cold": round(speedup_cold, 2),
        "speedup_note": (
            "measured on this container — see env.cpu_count. The fused "
            "packed-plane engine is dense-arbitration-bound on XLA:CPU "
            "(per-cycle cost is set by the router geometry, flat in the "
            "in-flight worm pool) and shards the sweep axis across host "
            "devices via pmap, so the speedup scales with available cores "
            "while the Python baseline is inherently single-core; the "
            "Pallas chunked-kernel backend targets TPU/GPU"
        ),
        "env": {
            "cpu_count": os.cpu_count(),
            "jax_devices": jax.local_device_count(),
            "backend": jax.default_backend(),
        },
        "xsim": {"slots": res.slots, "slots_hwm": res.slots_hwm(),
                 "cycles_simulated": res.cycles,
                 "hops_8x8_sweep": hops_8x8},
        "curves": {"python": py_curves, "xsim": x_curves},
        "contention_dpm": contention,
        "scale": scale,
        "cross_validation": parity,
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))
    # repo-root perf-trajectory artifact (ISSUE 6 satellite): the headline
    # sustained-throughput numbers a future session compares against
    BENCH.write_text(json.dumps({
        "suite": "benchmarks.xsim_sweep",
        "quick": quick,
        "grid_8x8": {
            "sustained_hops_per_s": int(hops_8x8 / max(1e-9, t_x)),
            "speedup_vs_host_sim_sustained": round(speedup, 2),
            "speedup_vs_host_sim_cold": round(speedup_cold, 2),
        },
        "scale_grid": {
            "mesh": scale["mesh"],
            "sustained_hops_per_s": scale["sustained_hops_per_s"],
            "speedup_vs_committed_sustained":
                scale["speedup_vs_committed_sustained"],
            "committed_baseline": scale["committed_baseline"],
            "scaling_note": scale["scaling_note"],
        },
        "env": data["env"],
    }, indent=1))

    rows = [
        ("xsim_sweep/python_sequential", t_py * 1e6,
         f"points={len(rates) * len(algos)}"),
        ("xsim_sweep/xsim_batched", t_x * 1e6,
         f"slots={res.slots};devices={jax.local_device_count()}"),
        ("xsim_sweep/speedup", 0.0,
         f"sustained=x{speedup:.1f};cold=x{speedup_cold:.1f}"),
        *scale_rows,
    ]
    for p in parity:
        rows.append((
            f"xsim_sweep/parity/{p['case']}", 0.0,
            f"sets_equal={p['delivery_sets_equal']};"
            f"latency_dev={p['latency_rel_dev']:.4f}",
        ))
    for algo in algos:
        curve = ";".join(f"{r}:{lat}" for r, lat in x_curves[algo])
        rows.append((f"xsim_sweep/curve/{algo}", 0.0, curve))
    if contention is not None:
        rows.append((
            "xsim_sweep/contention_dpm", 0.0,
            ";".join(f"{r}:{lat}" for r, lat in curve_contention)
            + f";plans_differ={plans_differ}"
            + f";max_rel_div={contention['max_rel_divergence']}"
            + f";diverges={contention['diverges_at_saturation']}",
        ))
    return rows
