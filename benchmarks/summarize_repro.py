"""Fill EXPERIMENTS.md §Paper-repro verdicts from bench_output.txt.

Beyond the paper figures, every ``benchmarks/results/*.json`` artifact a
suite committed is auto-discovered and summarized — a new suite only has
to write its artifact; nothing here needs editing.
"""
from __future__ import annotations

import json
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]
RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def parse(path=ROOT / "bench_output.txt"):
    rows = {}
    for line in path.read_text().splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3:
            rows[parts[0]] = parts[2]
    return rows


def artifacts() -> dict[str, dict]:
    """Every committed results/*.json, keyed by suite name."""
    out = {}
    for p in sorted(RESULTS.glob("*.json")):
        try:
            out[p.stem] = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            out[p.stem] = {"_error": f"{type(e).__name__}: {e}"}
    return out


def _one_line(name: str, data: dict) -> str:
    if "_error" in data:
        return f"unreadable ({data['_error']})"
    if name == "trace_replay":
        reps = data.get("replays", {})
        wins = sum(
            1 for r in reps.values()
            if r["algos"].get("DPM", {}).get("total_cycles_host")
            == min(v["total_cycles_host"] for v in r["algos"].values())
        )
        return (
            f"{len(reps)} workload classes on {data.get('fabric', '?')}; "
            f"DPM matches or beats every baseline on {wins}/{len(reps)}"
        )
    if name == "telemetry_calibration":
        cal = data.get("calibration", {})
        e = data.get("energy_constants_pj", {})
        return (
            f"{data.get('mesh', '?')} loop "
            f"{'converged' if cal.get('converged') else 'DID NOT CONVERGE'}; "
            f"latency {cal.get('baseline_latency')} -> "
            f"{cal.get('calibrated_latency')} "
            f"({cal.get('plans_changed')} plans moved); measured "
            f"{e.get('measured_per_worm_hop')} pJ/worm-hop vs analytic "
            f"{e.get('analytic_per_worm_hop')}"
        )
    # generic fallback: top-level scalar keys tell the story
    keys = [k for k, v in data.items()
            if isinstance(v, (int, float, str)) and k != "notes"][:4]
    return ", ".join(f"{k}={data[k]}" for k in keys) or "(structured artifact)"


def main():
    rows = parse()
    # Fig 6: DPM best at each range (summary rows)
    fig6 = []
    for dr in ("2-5", "4-8", "7-10", "10-16"):
        key = f"fig6/range{dr}/summary"
        if key in rows:
            m = re.search(r"best_at_rate_([\d.]+)=(\w+)", rows[key])
            if m:
                fig6.append((dr, m.group(2), rows[key]))
    print("Fig 6 best-algorithm per range (at the highest rate all algos ran):")
    for dr, best, full in fig6:
        print(f"  range {dr}: best={best}   [{full}]")
    # Fig 7: DPM power improvement vs MU
    print("\nFig 7 power improvement vs MU at MU saturation (paper: 7/16/22/35 %):")
    for dr in ("2-5", "4-8", "7-10", "10-16"):
        for algo in ("MP", "NMP", "DPM"):
            key = f"fig7/range{dr}/{algo}_vs_MU"
            if key in rows:
                print(f"  {dr} {algo}: {rows[key]}")
    # Fig 8
    print("\nFig 8 improvements vs MP (paper: DPM up to 23 % lat / 14 % power):")
    for line, val in rows.items():
        if line.startswith("fig8/") and line.endswith("DPM_vs_MP"):
            print(f"  {line.split('/')[1]}: {val}")
    # beyond-paper: committed per-suite artifacts (auto-discovered)
    arts = artifacts()
    if arts:
        print("\nCommitted suite artifacts (benchmarks/results/*.json):")
        for name, data in arts.items():
            print(f"  {name}: {_one_line(name, data)}")


if __name__ == "__main__":
    main()
