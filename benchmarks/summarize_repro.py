"""Fill EXPERIMENTS.md §Paper-repro verdicts from bench_output.txt."""
from __future__ import annotations

import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parents[1]


def parse(path=ROOT / "bench_output.txt"):
    rows = {}
    for line in path.read_text().splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3:
            rows[parts[0]] = parts[2]
    return rows


def main():
    rows = parse()
    # Fig 6: DPM best at each range (summary rows)
    fig6 = []
    for dr in ("2-5", "4-8", "7-10", "10-16"):
        key = f"fig6/range{dr}/summary"
        if key in rows:
            m = re.search(r"best_at_rate_([\d.]+)=(\w+)", rows[key])
            if m:
                fig6.append((dr, m.group(2), rows[key]))
    print("Fig 6 best-algorithm per range (at the highest rate all algos ran):")
    for dr, best, full in fig6:
        print(f"  range {dr}: best={best}   [{full}]")
    # Fig 7: DPM power improvement vs MU
    print("\nFig 7 power improvement vs MU at MU saturation (paper: 7/16/22/35 %):")
    for dr in ("2-5", "4-8", "7-10", "10-16"):
        for algo in ("MP", "NMP", "DPM"):
            key = f"fig7/range{dr}/{algo}_vs_MU"
            if key in rows:
                print(f"  {dr} {algo}: {rows[key]}")
    # Fig 8
    print("\nFig 8 improvements vs MP (paper: DPM up to 23 % lat / 14 % power):")
    for line, val in rows.items():
        if line.startswith("fig8/") and line.endswith("DPM_vs_MP"):
            print(f"  {line.split('/')[1]}: {val}")


if __name__ == "__main__":
    main()
