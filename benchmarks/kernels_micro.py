"""Kernel microbenches (CPU wall-time of the jnp paths; the Pallas kernels
target TPU and are correctness-validated in interpret mode by the tests)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention
from repro.models.ssm import ssd_reference, ssd_scan


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(
        *args
    ).block_until_ready()
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.monotonic() - t0) / iters * 1e6


def run(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    B, S, H, KH, D = 1, 1024, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D))

    flash = jax.jit(
        lambda q, k, v: chunked_attention(q, k, v, chunk_q=256, chunk_k=256)
    )

    def naive(q, k, v):
        G = H // KH
        kk = jnp.repeat(k, G, 2)
        vv = jnp.repeat(v, G, 2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * D**-0.5
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)

    naive_j = jax.jit(naive)
    rows.append(("kernels/attn_flash_jnp_1k", _bench(flash, q, k, v), "causal GQA"))
    rows.append(("kernels/attn_naive_1k", _bench(naive_j, q, k, v), "materialized SxS"))

    P, G2, N = 64, 1, 64
    Hs = 8
    x = jax.random.normal(key, (1, S, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(key, (1, S, Hs)))
    A = -jnp.exp(jax.random.normal(key, (Hs,)))
    Bm = jax.random.normal(key, (1, S, G2, N))
    Cm = jax.random.normal(key, (1, S, G2, N))
    chunked = jax.jit(lambda *a: ssd_scan(*a, 128))
    recur = jax.jit(lambda *a: ssd_reference(*a))
    rows.append(("kernels/ssd_chunked_1k", _bench(chunked, x, dt, A, Bm, Cm), "SSD dual form"))
    rows.append(("kernels/ssd_recurrent_1k", _bench(recur, x, dt, A, Bm, Cm), "per-step scan"))
    return rows
