"""Beyond-paper analysis: partition quality vs the restricted-family optimum,
the Definition-2 source-leg ablation (DESIGN.md §2), and the cost-model axis
(DESIGN.md §6): DPM-E (Algorithm 1 under the energy objective) priced against
hop-optimizing DPM with the energy model, and both against the restricted
optimum under their own objectives."""
from __future__ import annotations

import random
import time

from repro.core import (
    brute_force_partition,
    dpm_partition,
    get_cost_model,
    grid,
    plan,
)

from .noc_common import resolve_algos


def run(quick: bool = False, algos=None):
    g = grid(8)
    # the paper set plus DPM-E — the registry's proof that a new algorithm
    # reaches the benchmarks without editing them (only --algos overrides)
    algos = resolve_algos(algos) + ([] if algos is not None else ["DPM-E"])
    energy = get_cost_model("energy")
    rng = random.Random(17)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    n_inst = 150 if quick else 400
    rows = []
    for dr in ((2, 5), (4, 8), (10, 16)):
        tot = {a: 0 for a in algos}
        tot["DPM_noleg"] = 0
        energy_pj = {a: 0.0 for a in algos}
        opt_gap = 0
        opt_gap_energy = 0.0
        opt_n = 0
        t0 = time.monotonic()
        for _ in range(n_inst):
            k = rng.randint(*dr)
            picks = rng.sample(nodes, k + 1)
            src, dests = picks[0], picks[1:]
            for a in algos:
                p = plan(a, g, src, dests)
                tot[a] += p.total_hops
                energy_pj[a] += energy.plan_cost(g, p)
            tot["DPM_noleg"] += dpm_partition(
                g, src, dests, include_source_leg=False
            ).total_cost(True)
            if k <= 8:  # brute force tractable
                r = dpm_partition(g, src, dests)
                opt, _ = brute_force_partition(g, src, dests)
                opt_gap += r.total_cost() - opt
                re = dpm_partition(g, src, dests, cost_model="energy")
                opt_e, _ = brute_force_partition(g, src, dests, cost_model="energy")
                opt_gap_energy += re.total_cost() - opt_e
                opt_n += 1
        wall = (time.monotonic() - t0) * 1e6 / n_inst
        for a, v in tot.items():
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/{a}",
                    wall,
                    f"avg_hops={v / n_inst:.2f}",
                )
            )
        for a in algos:
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/{a}_energy",
                    0.0,
                    f"avg_energy_pj={energy_pj[a] / n_inst:.0f}",
                )
            )
        if opt_n:
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/opt_gap",
                    0.0,
                    f"mean_gap_vs_restricted_optimum={opt_gap / opt_n:.3f}",
                )
            )
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/opt_gap_energy",
                    0.0,
                    f"mean_energy_gap_vs_restricted_optimum="
                    f"{opt_gap_energy / opt_n:.3f}",
                )
            )
    return rows
