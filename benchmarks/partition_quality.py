"""Beyond-paper analysis: partition quality vs the restricted-family optimum,
and the Definition-2 source-leg ablation (DESIGN.md §2)."""
from __future__ import annotations

import random
import time

from repro.core import brute_force_partition, dpm_partition, grid, plan


def run(quick: bool = False):
    g = grid(8)
    rng = random.Random(17)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    n_inst = 150 if quick else 400
    rows = []
    for dr in ((2, 5), (4, 8), (10, 16)):
        tot = {"MU": 0, "MP": 0, "NMP": 0, "DPM": 0, "DPM_noleg": 0}
        opt_gap = 0
        opt_n = 0
        t0 = time.monotonic()
        for _ in range(n_inst):
            k = rng.randint(*dr)
            picks = rng.sample(nodes, k + 1)
            src, dests = picks[0], picks[1:]
            for a in ("MU", "MP", "NMP", "DPM"):
                tot[a] += plan(a, g, src, dests).total_hops
            tot["DPM_noleg"] += dpm_partition(
                g, src, dests, include_source_leg=False
            ).total_cost(True)
            if k <= 8:  # brute force tractable
                r = dpm_partition(g, src, dests)
                opt, _ = brute_force_partition(g, src, dests)
                opt_gap += r.total_cost() - opt
                opt_n += 1
        wall = (time.monotonic() - t0) * 1e6 / n_inst
        for a, v in tot.items():
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/{a}",
                    wall,
                    f"avg_hops={v / n_inst:.2f}",
                )
            )
        if opt_n:
            rows.append(
                (
                    f"partition_quality/range{dr[0]}-{dr[1]}/opt_gap",
                    0.0,
                    f"mean_gap_vs_restricted_optimum={opt_gap / opt_n:.3f}",
                )
            )
    return rows
