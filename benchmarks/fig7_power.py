"""Fig. 7: % dynamic-power improvement of MP/NMP/DPM over MU at MU's
saturation point, per destination range.

Paper: DPM saves ~7/16/22/35 % vs MU at ranges (2-5)/(4-8)/(7-10)/(10-16).
"""
from __future__ import annotations

import time

from repro.noc import DEST_RANGES, NoCConfig, simulate, synthetic_workload

from .noc_common import resolve_algos


def _mu_saturation_rate(cfg, cycles, seed=3, factor=4.0):
    zero = None
    for rate in (0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.08, 0.10, 0.12):
        wl = synthetic_workload(cfg, rate, cycles, seed=seed)
        lat = simulate(cfg, wl, "MU").avg_latency
        zero = zero or lat
        if lat > factor * zero:
            return rate
    return 0.12


def run(quick: bool = False, algos=None):
    cycles = 700 if quick else 1200
    algos = resolve_algos(algos)
    rows = []
    for dr in DEST_RANGES:
        cfg = NoCConfig(dest_range=dr)
        sat = _mu_saturation_rate(cfg, cycles)
        wl = synthetic_workload(cfg, sat, cycles, seed=7)
        power = {}
        for algo in algos:
            t0 = time.monotonic()
            st = simulate(cfg, wl, algo)
            power[algo] = st.dyn_power(cfg.energy)
            wall = time.monotonic() - t0
            rows.append(
                (
                    f"fig7/range{dr[0]}-{dr[1]}/{algo}",
                    wall * 1e6,
                    f"dyn_power_pj_per_cycle={power[algo]:.1f}",
                )
            )
        if "MU" not in power:  # paper's baseline absent from --algos
            continue
        for algo in (a for a in algos if a != "MU"):
            impr = 100.0 * (1 - power[algo] / power["MU"])
            rows.append(
                (
                    f"fig7/range{dr[0]}-{dr[1]}/{algo}_vs_MU",
                    0.0,
                    f"power_improvement_pct={impr:.1f}",
                )
            )
    return rows
