"""Batched plan-server throughput: device-side planning vs host plan().

Protocol (ISSUE 10 tentpole gate):

* serving-scale instance streams on the 8x8 mesh — random (src, dest-set)
  requests at collective-style fanouts (8-24 destinations, the regime a
  serving fabric actually multicasts at: activation broadcast / KV-shard
  fan-out groups) — planned two ways: one ``plan()`` call per instance on
  the host, and in one ``BatchPlanner.plan_many`` bulk dispatch (chunked
  jitted ``dpm_plan_exact`` batches + host decode of arena misses).
* every timing is arena-cold / plan-cache-cold per trial (caches cleared),
  min of N trials (this container's wall clock is noisy); jit compilation
  is warmed untimed — shared infrastructure, same treatment as the planner
  cache warm-up in ``xsim_sweep``.
* **bit-identity gate**: every batched plan on every benchmarked instance
  is compared against host ``plan()`` — one mismatch fails the suite.
* **perf gate** (full mode): batched planning >= 10x host plans/sec at
  batch >= 1024, cold cache, at the headline fanout.
* a fanout sweep shows where the gain comes from: host cost grows with the
  destination count k, the device merge is k-independent (fixed candidate
  tensors), so the speedup rises with fanout.
* a cache-hit sweep re-plans a 1024-instance batch with a fraction of its
  keys pre-warmed into the arena — the serving steady state where most
  requests are hits and only the tail dispatches to the device.
* a ``PlanServer`` section runs the same stream through the deadline-
  batched streaming front-end (futures + background worker) to price the
  queue/thread overhead over direct ``plan_many``.

Writes ``results/planserve.json`` and the repo-root perf-trajectory
artifact ``BENCH_planserve.json``.
"""
from __future__ import annotations

import json
import os
import pathlib
import random
import time

CACHE = pathlib.Path(__file__).parent / "results" / "planserve.json"
BENCH = pathlib.Path(__file__).parent.parent / "BENCH_planserve.json"

MESH_N = 8
HEADLINE_FANOUT = (8, 24)
GATE_BATCH = 1024
GATE_SPEEDUP = 10.0


def _instances(g, count, seed, kmin, kmax):
    nodes = g.nodes()
    rng = random.Random(seed)
    out, seen = [], set()
    while len(out) < count:
        src = rng.choice(nodes)
        k = rng.randint(kmin, min(kmax, len(nodes) - 1))
        dests = tuple(sorted(rng.sample([x for x in nodes if x != src], k)))
        if (src, dests) in seen:
            continue
        seen.add((src, dests))
        out.append((src, list(dests)))
    return out


def _host_rate(g, reqs, trials):
    from repro.core import plan, plan_cache_clear

    best = float("inf")
    for _ in range(trials):
        plan_cache_clear()
        t0 = time.monotonic()
        for src, dests in reqs:
            plan("DPM", g, src, dests)
        best = min(best, time.monotonic() - t0)
    return len(reqs) / best, best


def _batched_rate(bp, reqs, trials):
    best = float("inf")
    for _ in range(trials):
        bp.clear()
        t0 = time.monotonic()
        plans = bp.plan_many(reqs)
        best = min(best, time.monotonic() - t0)
    return len(reqs) / best, best, plans


def _assert_bit_identical(g, reqs, plans):
    from repro.core import plan

    bad = sum(
        1 for (src, dests), p in zip(reqs, plans)
        if p != plan("DPM", g, src, dests)
    )
    assert bad == 0, f"{bad}/{len(reqs)} batched plans differ from plan()"
    return len(reqs)


def run(quick: bool = False):
    import jax

    from repro.core import BatchPlanner, grid, plan_cache_clear
    from repro.serve import PlanServer

    g = grid(MESH_N)
    # min-of-trials: this container's wall clock jitters up to ~2x, and the
    # gate compares two independent minima — full mode takes 5 trials so
    # both sides get a clean (least-interference) sample
    trials = 2 if quick else 5
    batch_sizes = [1, 64, GATE_BATCH] if quick else [1, 16, 64, 256,
                                                     GATE_BATCH, 4096]
    fanouts = [HEADLINE_FANOUT] if quick else [(2, 12), HEADLINE_FANOUT,
                                               (16, 32)]
    hit_fracs = [0.0, 0.9] if quick else [0.0, 0.5, 0.9, 0.99]

    bp = BatchPlanner(g, "DPM")
    assert bp.support.ok, bp.support.reason
    # warm every jit specialization the sweep will hit (pow2 pads + the
    # DISPATCH_CHUNK shape), untimed — compile cost is not planning cost
    for b in batch_sizes:
        bp.clear()
        bp.plan_many(_instances(g, min(b, 513), seed=999 + b,
                                kmin=HEADLINE_FANOUT[0],
                                kmax=HEADLINE_FANOUT[1]))

    rows, verified = [], 0

    # --- batch-size sweep at the headline fanout ------------------------
    sweep = []
    for b in batch_sizes:
        reqs = _instances(g, b, seed=b, kmin=HEADLINE_FANOUT[0],
                          kmax=HEADLINE_FANOUT[1])
        h_rate, h_s = _host_rate(g, reqs, trials)
        b_rate, b_s, plans = _batched_rate(bp, reqs, trials)
        verified += _assert_bit_identical(g, reqs, plans)
        speedup = b_rate / h_rate
        sweep.append({
            "batch": b,
            "host_plans_per_s": int(h_rate),
            "batched_plans_per_s": int(b_rate),
            "host_s": round(h_s, 4),
            "batched_s": round(b_s, 4),
            "speedup": round(speedup, 2),
        })
        rows.append((f"planserve/batch_{b}", b_s * 1e6 / b,
                     f"plans_per_s={int(b_rate)};host={int(h_rate)};"
                     f"speedup=x{speedup:.2f}"))
    headline = next(s for s in sweep if s["batch"] == GATE_BATCH)

    # --- fanout sweep at the gate batch size ----------------------------
    fan = []
    for kmin, kmax in fanouts:
        reqs = _instances(g, GATE_BATCH, seed=10 * kmin + kmax,
                          kmin=kmin, kmax=kmax)
        h_rate, _ = _host_rate(g, reqs, trials)
        b_rate, _, plans = _batched_rate(bp, reqs, trials)
        verified += _assert_bit_identical(g, reqs, plans)
        fan.append({
            "fanout": [kmin, kmax],
            "host_plans_per_s": int(h_rate),
            "batched_plans_per_s": int(b_rate),
            "speedup": round(b_rate / h_rate, 2),
        })
        rows.append((f"planserve/fanout_{kmin}-{kmax}", 0.0,
                     f"speedup=x{b_rate / h_rate:.2f};"
                     f"batched={int(b_rate)};host={int(h_rate)}"))

    # --- cache-hit sweep: serving steady state --------------------------
    hits = []
    reqs = _instances(g, GATE_BATCH, seed=77, kmin=HEADLINE_FANOUT[0],
                      kmax=HEADLINE_FANOUT[1])
    for frac in hit_fracs:
        warm = reqs[: int(len(reqs) * frac)]
        best = float("inf")
        for _ in range(trials):
            bp.clear()
            if warm:
                bp.plan_many(warm)
            t0 = time.monotonic()
            bp.plan_many(reqs)
            best = min(best, time.monotonic() - t0)
        rate = len(reqs) / best
        hits.append({
            "hit_fraction": frac,
            "plans_per_s": int(rate),
            "batch_s": round(best, 4),
        })
        rows.append((f"planserve/hits_{int(frac * 100)}pct",
                     best * 1e6 / len(reqs), f"plans_per_s={int(rate)}"))

    # --- PlanServer streaming front-end ---------------------------------
    plan_cache_clear()
    best = float("inf")
    n_stream = 256 if quick else GATE_BATCH
    stream = _instances(g, n_stream, seed=5, kmin=HEADLINE_FANOUT[0],
                        kmax=HEADLINE_FANOUT[1])
    with PlanServer(g, "DPM", max_wait_s=0.002, planner=bp) as ps:
        for _ in range(trials):
            bp.clear()
            t0 = time.monotonic()
            futs = [ps.submit(src, dests) for src, dests in stream]
            for f in futs:
                f.result(timeout=300)
            best = min(best, time.monotonic() - t0)
    server = {
        "requests": n_stream,
        "plans_per_s": int(n_stream / best),
        "batches": ps.stats["batches"],
        "note": "futures + deadline batching over the same arena; the "
                "delta vs the direct plan_many rate is the queue/thread "
                "overhead",
    }
    rows.append(("planserve/server_stream", best * 1e6 / n_stream,
                 f"plans_per_s={server['plans_per_s']};"
                 f"batches={ps.stats['batches']}"))

    speedup = headline["speedup"]
    if not quick:
        assert speedup >= GATE_SPEEDUP, (
            f"batched-planning perf gate: x{speedup:.2f} at batch "
            f"{GATE_BATCH} (need >= x{GATE_SPEEDUP:.0f})"
        )
    rows.append(("planserve/gate", 0.0,
                 f"speedup_at_{GATE_BATCH}=x{speedup:.2f};"
                 f"bit_identical={verified};quick={quick}"))

    env = {
        "cpu_count": os.cpu_count(),
        "jax_devices": jax.local_device_count(),
        "backend": jax.default_backend(),
    }
    data = {
        "mesh": f"{MESH_N}x{MESH_N}",
        "algo": "DPM",
        "headline_fanout": list(HEADLINE_FANOUT),
        "trials": trials,
        "methodology": "min-of-trials wall clock; plan cache and arena "
                       "cleared per trial (cold); jit warmed untimed; "
                       "every batched plan compared to host plan() for "
                       "bit-identity",
        "batch_sweep": sweep,
        "fanout_sweep": fan,
        "cache_hit_sweep": hits,
        "plan_server": server,
        "bit_identical_instances": verified,
        "speedup_note": (
            "host plan() cost grows with destination count k while the "
            "device merge is k-independent (fixed candidate tensors), so "
            "the speedup rises with fanout; measured on this container — "
            "see env.cpu_count (decode and device compute cannot overlap "
            "on one core)"
        ),
        "env": env,
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))
    BENCH.write_text(json.dumps({
        "suite": "benchmarks.planserve",
        "quick": quick,
        "headline": {
            "batch": GATE_BATCH,
            "fanout": list(HEADLINE_FANOUT),
            "host_plans_per_s": headline["host_plans_per_s"],
            "batched_plans_per_s": headline["batched_plans_per_s"],
            "speedup_cold_cache": speedup,
        },
        "gate": {"min_speedup": GATE_SPEEDUP,
                 "passed": bool(speedup >= GATE_SPEEDUP)},
        "bit_identical_instances": verified,
        "env": env,
    }, indent=1))
    return rows
