"""Beyond-paper: alpha-beta cost of DPM vs ring scheduling for the two
collectives the distribution layer actually issues — the data-parallel
parameter broadcast (repro.dist.multicast.dp_broadcast_schedule) and the
expert-parallel dispatch all-to-all (repro.dist.ep) — at n in {8, 16, 64}
ranks.

Broadcast moves one 64 MiB payload; the EP dispatch moves one (src, dst)
chunk per rank pair, sized so the whole token buffer is 64 MiB (chunk =
total / n), priced per-request via Schedule.cost(req_payload_bytes=...).
Results also append to benchmarks/results/dist_collectives.json so the
numbers sit alongside the torus planner suite's artifacts.
"""
from __future__ import annotations

import json
import pathlib
import time

from repro.dist.multicast import (
    alltoall_schedule,
    dp_broadcast_schedule,
    ring_alltoall_schedule,
    ring_broadcast_schedule,
)

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
PAYLOAD = 64 * 2**20


def run(quick: bool = False):
    rows = []
    results: dict[str, dict] = {}
    sizes = (8, 16) if quick else (8, 16, 64)
    for n in sizes:
        t0 = time.monotonic()
        cases = {
            "bcast_dpm": dp_broadcast_schedule(n, "DPM").cost(PAYLOAD),
            "bcast_mu": dp_broadcast_schedule(n, "MU").cost(PAYLOAD),
            "bcast_ring": ring_broadcast_schedule(n).cost(PAYLOAD),
        }
        chunk = PAYLOAD // n
        a_dpm = alltoall_schedule(n, "DPM")
        a_ring = ring_alltoall_schedule(n)
        req = {r: chunk for rr in a_dpm.round_reqs for r in rr}
        cases["ep_dispatch_dpm"] = a_dpm.cost(chunk, req_payload_bytes=req)
        cases["ep_dispatch_ring"] = a_ring.cost(chunk, req_payload_bytes=req)
        plan_us = (time.monotonic() - t0) * 1e6
        results[str(n)] = cases
        for name, c in cases.items():
            rows.append(
                (
                    f"dist_collectives/{name}/n{n}",
                    c["time_us"],
                    f"rounds={c['rounds']};link_MiB={c['link_bytes'] / 2**20:.0f}",
                )
            )
        rows.append((f"dist_collectives/plan/n{n}", plan_us, "planning wall"))
        for kind in ("bcast", "ep_dispatch"):
            dpm = cases[f"{kind}_dpm"]["time_us"]
            ring = cases[f"{kind}_ring"]["time_us"]
            rows.append(
                (
                    f"dist_collectives/{kind}_speedup/n{n}",
                    0.0,
                    f"ring_over_dpm={ring / max(dpm, 1e-9):.3f}",
                )
            )

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "dist_collectives.json"
    merged = {}
    if out.exists():
        merged = json.loads(out.read_text())
    merged.update(results)
    out.write_text(json.dumps(merged, indent=1, sort_keys=True))
    return rows
