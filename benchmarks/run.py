"""Benchmark harness — one module per paper table/figure + beyond-paper.

Prints ``name,us_per_call,derived`` CSV rows. ``--quick`` trims sweep sizes.
Roofline numbers come from the dry-run artifacts (benchmarks/dryrun_results,
summarized by benchmarks/roofline_table.py), not from wall-time here.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated module names "
        "(fig6,fig7,fig8,partition,tpu,torus,kernels,dist,xsim,fault,trace,"
        "telemetry,topo3d,planserve)",
    )
    ap.add_argument(
        "--algos",
        default=None,
        help="comma-separated routing algorithms (validated against the "
        "repro.core.algo registry; default: each suite's registry query)",
    )
    args = ap.parse_args()

    algos = None
    if args.algos:
        from repro.core.algo import get_algorithm

        # unknown names raise here, listing what is registered
        algos = [get_algorithm(a.strip()).name for a in args.algos.split(",")]

    from . import (
        dist_collectives,
        fault_resilience,
        fig6_latency,
        fig7_power,
        fig8_traces,
        kernels_micro,
        partition_quality,
        planserve,
        telemetry_calibration,
        topo3d_sweep,
        torus_planner,
        tpu_multicast,
        trace_replay,
        xsim_sweep,
    )

    suites = {
        "fig6": fig6_latency.run,
        "fig7": fig7_power.run,
        "fig8": fig8_traces.run,
        "partition": partition_quality.run,
        "tpu": tpu_multicast.run,
        "torus": torus_planner.run,
        "kernels": kernels_micro.run,
        "dist": dist_collectives.run,
        "xsim": xsim_sweep.run,
        "fault": fault_resilience.run,
        "trace": trace_replay.run,
        "telemetry": telemetry_calibration.run,
        "topo3d": topo3d_sweep.run,
        "planserve": planserve.run,
    }
    only = set(args.only.split(",")) if args.only else set(suites)
    unknown = only - set(suites)
    if unknown:
        # a typo'd --only used to run nothing silently; fail loudly instead
        ap.error(
            f"unknown suite(s) {sorted(unknown)}; available: "
            f"{','.join(suites)}"
        )
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if name not in only:
            continue
        kwargs = {"quick": args.quick}
        if algos is not None and "algos" in inspect.signature(fn).parameters:
            kwargs["algos"] = algos
        t0 = time.monotonic()
        try:
            rows = fn(**kwargs)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
        print(
            f"{name}/_suite_wall,{(time.monotonic() - t0) * 1e6:.0f},ok",
            flush=True,
        )


if __name__ == "__main__":
    main()
