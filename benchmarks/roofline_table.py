"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh pod] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "dryrun_results"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "hymba-1.5b", "deepseek-v2-236b", "moonshot-v1-16b-a3b", "smollm-135m",
    "stablelm-1.6b", "starcoder2-7b", "qwen1.5-32b", "mamba2-1.3b",
    "musicgen-medium", "qwen2-vl-72b",
]


def load(mesh: str, variant: str = "baseline") -> dict:
    out = {}
    for f in RESULTS.glob(f"*__{mesh}__{variant}.json"):
        d = json.loads(f.read_text())
        out[(d["arch"], d["shape"])] = d
    return out


def fmt_row(d: dict) -> str:
    r = d["roofline"]
    dom = {"compute_s": "compute", "memory_s": "memory",
           "collective_s": "collective"}[r["dominant"]]
    return (
        f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3f} | "
        f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {dom} | "
        f"{d['useful_flops_ratio']:.2f} | "
        f"{d['model_flops_per_chip'] / max(d['hlo_flops_per_chip'],1e-9) * r['compute_s'] / max(max(r.values() if isinstance(r, dict) and False else [r['compute_s'], r['memory_s'], r['collective_s']]), 1e-12):.3f} |"
    )


def roofline_fraction(d: dict) -> float:
    """useful-FLOPs time / step lower bound: the score §Perf drives up."""
    r = d["roofline"]
    useful_time = d["model_flops_per_chip"] / 197e12
    bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return useful_time / bound if bound else 0.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()
    data = load(args.mesh, args.variant)
    print(
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful_flops | roofline_frac |"
    )
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = data.get((arch, shape))
            if d is None:
                continue
            r = d["roofline"]
            dom = {"compute_s": "compute", "memory_s": "memory",
                   "collective_s": "collective"}[r["dominant"]]
            print(
                f"| {arch} | {shape} | {r['compute_s']:.3f} | "
                f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {dom} | "
                f"{d['useful_flops_ratio']:.3f} | {roofline_fraction(d):.3f} |"
            )


if __name__ == "__main__":
    main()
