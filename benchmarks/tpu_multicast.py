"""Beyond-paper: DPM multicast scheduling on the TPU pod torus (DESIGN.md §3).

Compares ppermute schedules (rounds, alpha-beta time, total link-bytes) for
DPM vs direct-send (MU) vs static multipath (MP) on the 16x16 single-pod
torus, for the collective patterns the framework actually issues:
  * parameter broadcast to a DP column (elastic re-shard / restore)
  * dense 4x4-cluster broadcast (pod-slice rollout)
  * sparse MoE-style dispatch (one source -> k random expert shards)
"""
from __future__ import annotations

import random
import time

from repro.dist.multicast import Torus, dp_broadcast_schedule, schedule_multicasts

from .noc_common import resolve_algos

MB = 2**20


def run(quick: bool = False, algos=None):
    rows = []
    # default: direct-send vs static multipath vs DPM (the collective-
    # relevant subset of the registry); --algos overrides everywhere
    algos = ["MU", "MP", "DPM"] if algos is None else resolve_algos(algos, "torus")
    t = Torus(16, 16)
    cases = {
        "dp_column_bcast": [((0, 0), [(0, y) for y in range(1, 16)])],
        "cluster4x4_bcast": [
            ((0, 0), [(x, y) for x in range(4) for y in range(4) if (x, y) != (0, 0)])
        ],
    }
    rng = random.Random(5)
    moe = []
    for _ in range(4 if quick else 16):  # 16 sources dispatch to 6 shards
        src = (rng.randrange(16), rng.randrange(16))
        dests = []
        while len(dests) < 6:
            d = (rng.randrange(16), rng.randrange(16))
            if d != src and d not in dests:
                dests.append(d)
        moe.append((src, dests))
    cases["moe_top6_dispatch"] = moe

    payloads = {"dp_column_bcast": 64 * MB, "cluster4x4_bcast": 16 * MB,
                "moe_top6_dispatch": 4 * MB}
    for case, reqs in cases.items():
        for algo in algos:
            t0 = time.monotonic()
            sched = schedule_multicasts(t, reqs, algo)
            cost = sched.cost(payloads[case])
            rows.append(
                (
                    f"tpu_multicast/{case}/{algo}",
                    (time.monotonic() - t0) * 1e6,
                    f"rounds={cost['rounds']};time_us={cost['time_us']:.0f};"
                    f"link_MB={cost['link_bytes'] / MB:.0f}",
                )
            )
    # 1-D data-axis broadcast (ring) across schedulers
    for algo in (a for a in algos if a != "MP"):  # MP degenerates on a ring
        sched = dp_broadcast_schedule(16, algo)
        cost = sched.cost(128 * MB)
        rows.append(
            (
                f"tpu_multicast/dp_ring16/{algo}",
                0.0,
                f"rounds={cost['rounds']};time_us={cost['time_us']:.0f};"
                f"link_MB={cost['link_bytes'] / MB:.0f}",
            )
        )
    return rows
