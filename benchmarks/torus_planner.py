"""Beyond-paper: the topology abstraction's payoff, mesh vs torus.

Plans identical multicast instance sets with every planner on MeshGrid(8,8)
and Torus(8,8) and reports total hop counts plus planning latency; then runs
the wormhole simulator on torus links for the flagship wrapped instance.
Derived column: torus/mesh hop ratio (lower = wraparound exploited better).
"""
from __future__ import annotations

import random
import time

from repro.core import available_algorithms, grid, plan, torus
from repro.noc import NoCConfig, WormholeSim

from .noc_common import resolve_algos


def _instances(count: int, seed: int = 0):
    rng = random.Random(seed)
    nodes = [(x, y) for x in range(8) for y in range(8)]
    out = []
    for _ in range(count):
        picks = rng.sample(nodes, rng.randint(4, 13))
        out.append((picks[0], picks[1:]))
    return out


def run(quick: bool = False, algos=None):
    rows = []
    insts = _instances(40 if quick else 200, seed=17)
    g, t = grid(8), torus(8)
    # every registered algorithm that can route on both geometries
    if algos is None:
        algos = [a for a in available_algorithms("torus")
                 if a in available_algorithms("mesh")]
    else:
        algos = resolve_algos(algos, "torus")
    for algo in algos:
        hops = {}
        for topo_name, topo in (("mesh", g), ("torus", t)):
            t0 = time.monotonic()
            hops[topo_name] = sum(
                plan(algo, topo, s, d).total_hops for s, d in insts
            )
            us = (time.monotonic() - t0) * 1e6 / len(insts)
            rows.append(
                (
                    f"torus_planner/{algo}/{topo_name}",
                    us,
                    f"total_hops={hops[topo_name]}",
                )
            )
        rows.append(
            (
                f"torus_planner/{algo}/ratio",
                0.0,
                f"torus_over_mesh={hops['torus'] / max(1, hops['mesh']):.3f}",
            )
        )

    # wormhole simulation on torus links, wrapped destination set
    cfg = NoCConfig(topology="torus")
    src, dests = (0, 0), [(7, 7), (7, 0), (0, 7), (6, 6), (1, 7)]
    for algo in ("MU", "DPM"):
        sim = WormholeSim(cfg)
        sim.add_plan(plan(algo, t, src, dests), 0)
        t0 = time.monotonic()
        st = sim.run(5000)
        rows.append(
            (
                f"torus_planner/sim_{algo}",
                (time.monotonic() - t0) * 1e6,
                f"flit_hops={st.flit_link_traversals};lat={st.avg_latency:.1f}",
            )
        )
    return rows
