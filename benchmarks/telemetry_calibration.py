"""Closed-loop cost calibration vs the analytic contention model.

The analytic ``LinkContentionCost`` argues from uniform-traffic bisection
load; ``calibrate_cost_model`` (DESIGN.md §10) instead *measures* per-link
utilization with the xsim telemetry planes and fits weights from it,
iterating measure -> fit -> replan to a fixed point. This suite pits the
two on a saturated 16x16 DPM sweep:

* one calibration scenario (moderately saturated multicast mix) closes the
  loop and gates on the contract: the loop converges to an exact fixed
  point, the calibrated model moves at least one plan, and it never
  increases measured average latency on the scenario it was fitted to;
* the fitted model then prices a small rate sweep head-to-head against
  hop counting (DPM's default objective) and the analytic contention
  model — same workloads, same engine, only the objective differs;
* the measured ``EnergyCost`` constants are reported next to the analytic
  ones (the analytic model cannot see ejection reads, lost arbitrations
  or relay re-injections, so the measured pJ/worm-hop runs higher).

The committed artifact (results/telemetry_calibration.json) records the
iteration trajectory, the sweep and the energy-constant comparison.
"""
from __future__ import annotations

import json
import pathlib

CACHE = pathlib.Path(__file__).parent / "results" / "telemetry_calibration.json"
MODEL_NAME = "calibrated-bench"


def run(quick: bool = False):
    from repro.core.algo import EnergyCost, unregister_cost_model
    from repro.noc import (
        NoCConfig,
        calibrate_cost_model,
        synthetic_workload,
        xsimulate,
    )

    n = 8 if quick else 16
    cycles = 120 if quick else 200
    cal_rate = 0.05 if quick else 0.03
    sweep_rates = [0.02, cal_rate] if quick else [0.015, 0.025, cal_rate]
    max_iters = 4 if quick else 8

    cfg = NoCConfig(n=n, warmup=0, drain_grace=4000,
                    multicast_fraction=0.4, dest_range=(3, 6))
    wl = synthetic_workload(cfg, cal_rate, cycles, seed=5)

    try:
        res = calibrate_cost_model(
            cfg, wl, "DPM", name=MODEL_NAME, max_iters=max_iters
        )

        # head-to-head rate sweep: same workloads, only the objective moves
        def measure(rate, cost_model):
            w = synthetic_workload(cfg, rate, cycles, seed=5)
            r = xsimulate(cfg, [w], ("DPM",), cost_model=cost_model)
            return {
                "avg_latency": round(float(r.avg_latency(0, 0)), 3),
                "max_link_flits": int(r.link_utilization(0, 0).max(initial=0)),
            }

        sweep = []
        for rate in sweep_rates:
            sweep.append({
                "rate": rate,
                "hops": measure(rate, None),
                "contention": measure(rate, "contention"),
                "calibrated": measure(rate, MODEL_NAME),
            })
    finally:
        unregister_cost_model(MODEL_NAME)

    analytic = EnergyCost(cfg.energy, cfg.flits_per_packet)
    data = {
        "mesh": f"{n}x{n}",
        "cycles": cycles,
        "calibration_rate": cal_rate,
        "calibration": res.to_dict(),
        "sweep": sweep,
        "energy_constants_pj": {
            "analytic_per_worm_hop": round(analytic._per_hop, 3),
            "measured_per_worm_hop": round(res.energy._per_hop, 3),
            "analytic_per_worm": round(analytic._per_packet, 3),
            "measured_per_worm": round(res.energy._per_packet, 3),
        },
        "notes": (
            "calibrated weights fitted from xsim per-link telemetry planes "
            "via calibrate_cost_model's measure->fit->replan loop; the "
            "sweep reruns the same workloads under each objective"
        ),
    }
    if not quick:
        CACHE.parent.mkdir(parents=True, exist_ok=True)
        CACHE.write_text(json.dumps(data, indent=1) + "\n")

    # the calibration contract (the acceptance gates, enforced every run)
    assert res.converged, "calibration loop did not reach a fixed point"
    assert res.plans_changed >= 1, "calibrated model moved no plan"
    assert res.calibrated_latency <= res.baseline_latency, (
        "calibration regressed its own scenario"
    )

    cal_pt = sweep[-1]
    rows = [
        (
            "telemetry_calibration/loop", 0.0,
            f"converged_iter={res.best_iter};"
            f"iters={len(res.iterations) - 1};"
            f"plans_changed={res.plans_changed}",
        ),
        (
            "telemetry_calibration/latency", 0.0,
            f"baseline={res.baseline_latency:.3f};"
            f"calibrated={res.calibrated_latency:.3f};"
            f"contention={cal_pt['contention']['avg_latency']}",
        ),
        (
            "telemetry_calibration/energy", 0.0,
            f"per_hop_analytic={analytic._per_hop:.1f}pJ;"
            f"per_hop_measured={res.energy._per_hop:.1f}pJ",
        ),
    ]
    for pt in sweep:
        rows.append((
            f"telemetry_calibration/rate{pt['rate']}", 0.0,
            ";".join(
                f"{k}={pt[k]['avg_latency']}"
                for k in ("hops", "contention", "calibrated")
            ),
        ))
    return rows
