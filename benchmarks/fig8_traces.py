"""Fig. 8: PARSEC-like trace workloads — latency & power improvement vs MP.

Netrace traces are unavailable offline; repro.noc.traffic synthesizes
per-benchmark workloads matched to published characteristics (DESIGN.md §2).
Paper: DPM up to ~23 % latency / ~14 % power improvement vs MP
(fluidanimate); NMP ~5 % on canneal/swaptions.
"""
from __future__ import annotations

import time

from repro.noc import PARSEC_PROFILES, NoCConfig, parsec_workload, simulate


def run(quick: bool = False):
    cycles = 800 if quick else 2000
    base_rate = 0.085
    rows = []
    for bench in PARSEC_PROFILES:
        # measurement window comes from NoCConfig (shared with noc.xsim)
        cfg = NoCConfig()
        wl = parsec_workload(cfg, bench, cycles, base_rate=base_rate, seed=5)
        lat = {}
        pwr = {}
        for algo in ("MP", "NMP", "DPM"):
            t0 = time.monotonic()
            st = simulate(cfg, wl, algo)
            lat[algo], pwr[algo] = st.avg_latency, st.dyn_power(cfg.energy)
            rows.append(
                (
                    f"fig8/{bench}/{algo}",
                    (time.monotonic() - t0) * 1e6,
                    f"latency={lat[algo]:.2f};power={pwr[algo]:.1f}",
                )
            )
        for algo in ("NMP", "DPM"):
            rows.append(
                (
                    f"fig8/{bench}/{algo}_vs_MP",
                    0.0,
                    f"latency_improvement_pct="
                    f"{100*(1-lat[algo]/lat['MP']):.1f};"
                    f"power_improvement_pct={100*(1-pwr[algo]/pwr['MP']):.1f}",
                )
            )
    return rows
