"""Fig. 8: PARSEC-like trace workloads — latency & power improvement vs MP.

Netrace traces are unavailable offline; repro.noc.traffic synthesizes
per-benchmark workloads matched to published characteristics (DESIGN.md §2).
Paper: DPM up to ~23 % latency / ~14 % power improvement vs MP
(fluidanimate); NMP ~5 % on canneal/swaptions.
"""
from __future__ import annotations

import time

from repro.noc import PARSEC_PROFILES, NoCConfig, parsec_workload, simulate

from .noc_common import resolve_algos


def run(quick: bool = False, algos=None):
    cycles = 800 if quick else 2000
    base_rate = 0.085
    # the paper's fig8 compares against MP, not MU (MU saturates at this
    # trace load) — default to the registry figure set minus MU
    if algos is None:
        algos = [a for a in resolve_algos(None) if a != "MU"]
    else:
        algos = resolve_algos(algos)
    rows = []
    for bench in PARSEC_PROFILES:
        # measurement window comes from NoCConfig (shared with noc.xsim)
        cfg = NoCConfig()
        wl = parsec_workload(cfg, bench, cycles, base_rate=base_rate, seed=5)
        lat = {}
        pwr = {}
        for algo in algos:
            t0 = time.monotonic()
            st = simulate(cfg, wl, algo)
            lat[algo], pwr[algo] = st.avg_latency, st.dyn_power(cfg.energy)
            rows.append(
                (
                    f"fig8/{bench}/{algo}",
                    (time.monotonic() - t0) * 1e6,
                    f"latency={lat[algo]:.2f};power={pwr[algo]:.1f}",
                )
            )
        if "MP" not in lat:  # comparison baseline absent from --algos
            continue
        for algo in (a for a in algos if a != "MP"):
            rows.append(
                (
                    f"fig8/{bench}/{algo}_vs_MP",
                    0.0,
                    f"latency_improvement_pct="
                    f"{100*(1-lat[algo]/lat['MP']):.1f};"
                    f"power_improvement_pct={100*(1-pwr[algo]/pwr['MP']):.1f}",
                )
            )
    return rows
