"""DPM resilience on degraded meshes: latency/energy vs broken-link count.

The route-provider layer (DESIGN.md §7) lets every planner detour around
broken links; this suite quantifies what that graceful degradation costs.
Protocol:

* paper 8x8 mesh, fixed synthetic workload (moderate load, default
  multicast mix), one fault ladder 0 -> max broken links;
* fault sets are nested (each rung adds links to the previous rung's set)
  and sampled with a fixed seed, rejecting any link whose removal would
  disconnect the mesh — so every destination stays reachable and the curve
  isolates *detour* cost from *partition loss*;
* each rung replans every request on the degraded topology (the plan cache
  keys on the fault set) and runs the cycle-accurate ``WormholeSim``;
  per-rung rows report average latency, dynamic energy, planned hop
  totals, and how many plans actually changed vs the healthy mesh;
* a clustered-fault rung on top of the ladder: one full *router* failure
  (``core.router_failure`` — every link incident to the node breaks at
  once), with the dead node filtered out of sources/destinations; the row
  quantifies detouring around a region vs the same number of scattered
  link faults.

The committed artifact (results/fault_resilience.json) records the ladder;
the CSV rows gate on the structural invariants (all packets drain, no
broken-link traversal — the simulator would raise — and plans adapting as
faults accumulate).
"""
from __future__ import annotations

import json
import pathlib
import random

CACHE = pathlib.Path(__file__).parent / "results" / "fault_resilience.json"


def _connected_fault_ladder(g, counts, seed=7):
    """Nested fault sets, each leaving the mesh connected."""
    from repro.core import faulty

    rng = random.Random(seed)
    links = sorted(
        {tuple(sorted((u, v)))
         for y in range(g.rows) for x in range(g.n)
         for u in [(x, y)] for v in g.neighbors(x, y)}
    )
    chosen: list = []
    ladder = {}
    for target in sorted(counts):
        while len(chosen) < target:
            cand = rng.choice(links)
            if cand in chosen:
                continue
            topo = faulty(g, chosen + [cand])
            try:  # keep the degraded mesh connected (corner-to-corner probe
                # is not enough: check every node from one BFS root)
                for yy in range(g.rows):
                    for xx in range(g.n):
                        topo.distance((0, 0), (xx, yy))
            except Exception:
                continue
            chosen.append(cand)
        ladder[target] = tuple(chosen)
    return ladder


def run(quick: bool = False, algos=None):
    from repro.core import grid, plan
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, simulate, synthetic_workload

    from .noc_common import resolve_algos

    algos = resolve_algos(algos) if algos is not None else ["DPM", "MU"]
    counts = [0, 2, 4] if quick else [0, 2, 4, 8, 12]
    cycles = 200 if quick else 500
    rate = 0.05
    g = grid(8)
    ladder = _connected_fault_ladder(g, [c for c in counts if c], seed=7)
    ladder[0] = ()

    # deep drain window: heavy fault rungs run close to saturation on
    # the detour bottlenecks; the sim stops early once drained, so the
    # large grace only costs wall-clock where congestion really backs up
    base_cfg = NoCConfig(warmup=50, drain_grace=4000)
    wl = synthetic_workload(base_cfg, rate, cycles, seed=4)
    healthy_plans = {
        a: [plan(a, g, r.src, r.dests) for r in wl.requests] for a in algos
    }

    curve: dict[str, list[dict]] = {a: [] for a in algos}
    for k in counts:
        cfg = NoCConfig(warmup=50, drain_grace=4000, broken_links=ladder[k])
        topo = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
        for a in algos:
            st = simulate(cfg, wl, a)
            plans = [plan(a, topo, r.src, r.dests) for r in wl.requests]
            changed = sum(
                1 for p, hp in zip(plans, healthy_plans[a])
                if [q.hops for q in p.paths] != [q.hops for q in hp.paths]
            )
            curve[a].append({
                "broken_links": k,
                "avg_latency": round(st.avg_latency, 3),
                "dyn_energy_pj": round(st.dyn_energy_pj(cfg.energy), 1),
                "planned_hops": sum(p.total_hops for p in plans),
                "plans_changed_vs_healthy": changed,
                "drained": st.packets_finished == st.packets_created,
            })

    # --- clustered fault region: one failed router (core.router_failure) --
    # the dead node loses every incident link at once; traffic to/from it
    # is filtered (unreachable by construction), everything else detours
    from dataclasses import replace as _replace

    from repro.core import router_failure
    from repro.noc.traffic import Workload

    dead = (4, 3)  # interior router: 4 incident links, worst detour case
    cluster = router_failure(g, dead)
    reqs = []
    for r in wl.requests:
        if r.src == dead:
            continue
        dests = [d for d in r.dests if d != dead]
        if dests:
            reqs.append(_replace(r, dests=dests))
    wl_c = Workload(name=f"{wl.name}-minus-{dead}", requests=reqs,
                    horizon=wl.horizon)
    router_fault: dict[str, dict] = {}
    for a in algos:
        cfg_h = NoCConfig(warmup=50, drain_grace=4000)
        cfg_c = NoCConfig(warmup=50, drain_grace=4000, broken_links=cluster)
        topo_c = make_topology(cfg_c.topology, cfg_c.n, cfg_c.m,
                               cfg_c.broken_links)
        st_h = simulate(cfg_h, wl_c, a)
        st_c = simulate(cfg_c, wl_c, a)
        plans_c = [plan(a, topo_c, r.src, r.dests) for r in wl_c.requests]
        plans_h = [plan(a, g, r.src, r.dests) for r in wl_c.requests]
        changed = sum(
            1 for p, hp in zip(plans_c, plans_h)
            if [q.hops for q in p.paths] != [q.hops for q in hp.paths]
        )
        router_fault[a] = {
            "dead_router": list(dead),
            "broken_links": len(cluster),
            "avg_latency_healthy": round(st_h.avg_latency, 3),
            "avg_latency_cluster": round(st_c.avg_latency, 3),
            "planned_hops_healthy": sum(p.total_hops for p in plans_h),
            "planned_hops_cluster": sum(p.total_hops for p in plans_c),
            "plans_changed": changed,
            "drained": st_c.packets_finished == st_c.packets_created,
        }

    data = {
        "mesh": "8x8", "rate": rate, "cycles": cycles,
        "counts": counts, "algos": algos,
        "fault_ladder": {str(k): [list(map(list, l)) for l in ladder[k]]
                         for k in counts},
        "curve": curve,
        "router_fault": router_fault,
        "notes": (
            "nested connected fault sets; every request replanned on the "
            "degraded topology via the route-provider layer; the simulator "
            "refuses any plan that would cross a broken link, so a "
            "completed run doubles as the no-traversal gate"
        ),
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))

    rows = []
    for a in algos:
        pts = curve[a]
        assert all(p["drained"] for p in pts), f"{a}: packets lost under faults"
        # plans must adapt once faults accumulate (detours change routes)
        assert pts[-1]["plans_changed_vs_healthy"] > 0 or counts[-1] == 0
        rows.append((
            f"fault_resilience/{a}", 0.0,
            ";".join(f"{p['broken_links']}:{p['avg_latency']}" for p in pts),
        ))
        base = pts[0]
        worst = pts[-1]
        rows.append((
            f"fault_resilience/{a}/degradation", 0.0,
            f"latency_x{worst['avg_latency'] / max(1e-9, base['avg_latency']):.3f};"
            f"energy_x{worst['dyn_energy_pj'] / max(1e-9, base['dyn_energy_pj']):.3f};"
            f"plans_changed={worst['plans_changed_vs_healthy']}",
        ))
    for a, rf in router_fault.items():
        assert rf["drained"], f"{a}: packets lost around failed router"
        assert rf["plans_changed"] > 0, f"{a}: no plan adapted to the cluster"
        rows.append((
            f"fault_resilience/{a}/router_failure", 0.0,
            f"dead={rf['dead_router']};links={rf['broken_links']};"
            f"latency_x{rf['avg_latency_cluster'] / max(1e-9, rf['avg_latency_healthy']):.3f};"
            f"plans_changed={rf['plans_changed']}",
        ))
    return rows
