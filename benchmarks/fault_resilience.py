"""DPM resilience on degraded meshes: latency/energy vs broken-link count.

The route-provider layer (DESIGN.md §7) lets every planner detour around
broken links; this suite quantifies what that graceful degradation costs.
Protocol:

* paper 8x8 mesh, fixed synthetic workload (moderate load, default
  multicast mix), one fault ladder 0 -> max broken links;
* fault sets are nested (each rung adds links to the previous rung's set)
  and sampled with a fixed seed, rejecting any link whose removal would
  disconnect the mesh — so every destination stays reachable and the curve
  isolates *detour* cost from *partition loss*;
* each rung replans every request on the degraded topology (the plan cache
  keys on the fault set) and runs the cycle-accurate ``WormholeSim``;
  per-rung rows report average latency, dynamic energy, planned hop
  totals, and how many plans actually changed vs the healthy mesh.

The committed artifact (results/fault_resilience.json) records the ladder;
the CSV rows gate on the structural invariants (all packets drain, no
broken-link traversal — the simulator would raise — and plans adapting as
faults accumulate).
"""
from __future__ import annotations

import json
import pathlib
import random

CACHE = pathlib.Path(__file__).parent / "results" / "fault_resilience.json"


def _connected_fault_ladder(g, counts, seed=7):
    """Nested fault sets, each leaving the mesh connected."""
    from repro.core import faulty

    rng = random.Random(seed)
    links = sorted(
        {tuple(sorted((u, v)))
         for y in range(g.rows) for x in range(g.n)
         for u in [(x, y)] for v in g.neighbors(x, y)}
    )
    chosen: list = []
    ladder = {}
    for target in sorted(counts):
        while len(chosen) < target:
            cand = rng.choice(links)
            if cand in chosen:
                continue
            topo = faulty(g, chosen + [cand])
            try:  # keep the degraded mesh connected (corner-to-corner probe
                # is not enough: check every node from one BFS root)
                for yy in range(g.rows):
                    for xx in range(g.n):
                        topo.distance((0, 0), (xx, yy))
            except Exception:
                continue
            chosen.append(cand)
        ladder[target] = tuple(chosen)
    return ladder


def run(quick: bool = False, algos=None):
    from repro.core import grid, plan
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, simulate, synthetic_workload

    from .noc_common import resolve_algos

    algos = resolve_algos(algos) if algos is not None else ["DPM", "MU"]
    counts = [0, 2, 4] if quick else [0, 2, 4, 8, 12]
    cycles = 200 if quick else 500
    rate = 0.05
    g = grid(8)
    ladder = _connected_fault_ladder(g, [c for c in counts if c], seed=7)
    ladder[0] = ()

    # deep drain window: heavy fault rungs run close to saturation on
    # the detour bottlenecks; the sim stops early once drained, so the
    # large grace only costs wall-clock where congestion really backs up
    base_cfg = NoCConfig(warmup=50, drain_grace=4000)
    wl = synthetic_workload(base_cfg, rate, cycles, seed=4)
    healthy_plans = {
        a: [plan(a, g, r.src, r.dests) for r in wl.requests] for a in algos
    }

    curve: dict[str, list[dict]] = {a: [] for a in algos}
    for k in counts:
        cfg = NoCConfig(warmup=50, drain_grace=4000, broken_links=ladder[k])
        topo = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
        for a in algos:
            st = simulate(cfg, wl, a)
            plans = [plan(a, topo, r.src, r.dests) for r in wl.requests]
            changed = sum(
                1 for p, hp in zip(plans, healthy_plans[a])
                if [q.hops for q in p.paths] != [q.hops for q in hp.paths]
            )
            curve[a].append({
                "broken_links": k,
                "avg_latency": round(st.avg_latency, 3),
                "dyn_energy_pj": round(st.dyn_energy_pj(cfg.energy), 1),
                "planned_hops": sum(p.total_hops for p in plans),
                "plans_changed_vs_healthy": changed,
                "drained": st.packets_finished == st.packets_created,
            })

    data = {
        "mesh": "8x8", "rate": rate, "cycles": cycles,
        "counts": counts, "algos": algos,
        "fault_ladder": {str(k): [list(map(list, l)) for l in ladder[k]]
                         for k in counts},
        "curve": curve,
        "notes": (
            "nested connected fault sets; every request replanned on the "
            "degraded topology via the route-provider layer; the simulator "
            "refuses any plan that would cross a broken link, so a "
            "completed run doubles as the no-traversal gate"
        ),
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))

    rows = []
    for a in algos:
        pts = curve[a]
        assert all(p["drained"] for p in pts), f"{a}: packets lost under faults"
        # plans must adapt once faults accumulate (detours change routes)
        assert pts[-1]["plans_changed_vs_healthy"] > 0 or counts[-1] == 0
        rows.append((
            f"fault_resilience/{a}", 0.0,
            ";".join(f"{p['broken_links']}:{p['avg_latency']}" for p in pts),
        ))
        base = pts[0]
        worst = pts[-1]
        rows.append((
            f"fault_resilience/{a}/degradation", 0.0,
            f"latency_x{worst['avg_latency'] / max(1e-9, base['avg_latency']):.3f};"
            f"energy_x{worst['dyn_energy_pj'] / max(1e-9, base['dyn_energy_pj']):.3f};"
            f"plans_changed={worst['plans_changed_vs_healthy']}",
        ))
    return rows
