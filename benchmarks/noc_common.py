"""Shared helpers for the NoC paper-figure benchmarks.

Algorithm sets resolve through the routing-algorithm registry
(``repro.core.algo``) — the paper's fig6/7 comparison set is every
registered algorithm carrying the "fig" tag (MU/MP/NMP/DPM out of the box),
so a newly registered algorithm joins the sweeps without editing any
benchmark, and ``benchmarks/run.py --algos`` overrides the set everywhere.
"""
from __future__ import annotations

import time

from repro.core.algo import available_algorithms, get_algorithm
from repro.noc import NoCConfig, simulate, synthetic_workload


def fig_algos(topology: str = "mesh") -> list[str]:
    """The paper-figure comparison set, resolved from the registry."""
    return available_algorithms(topology, tag="fig")


def resolve_algos(algos, topology: str = "mesh") -> list[str]:
    """Normalize a caller-supplied algorithm list (names validated via the
    registry, unknown names raise listing what exists) or fall back to the
    paper's figure set."""
    if algos is None:
        return fig_algos(topology)
    return [get_algorithm(a).name for a in algos]


def sweep_rates(quick: bool) -> list[float]:
    if quick:
        return [0.01, 0.03, 0.05, 0.07, 0.09]
    return [0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.07, 0.08, 0.10, 0.12]


def run_curve(
    dest_range: tuple[int, int],
    rates: list[float],
    cycles: int,
    seed: int = 3,
    saturation_factor: float = 4.0,
    algos: list[str] | None = None,
):
    """(rate -> {algo: (latency, power_pj_per_cycle)}) + saturation rates.

    The measurement window (warmup / drain_grace) rides on ``NoCConfig``
    defaults — the single source of truth shared with ``noc.xsim``.
    """
    cfg = NoCConfig(dest_range=dest_range)
    algos = resolve_algos(algos, cfg.topology)
    out: dict[float, dict[str, tuple[float, float]]] = {}
    saturated: dict[str, float | None] = {a: None for a in algos}
    zero_load: dict[str, float] = {}
    live = set(algos)
    for rate in rates:
        wl = synthetic_workload(cfg, rate, cycles, seed=seed)
        row = {}
        for algo in [a for a in algos if a in live]:
            t0 = time.monotonic()
            st = simulate(cfg, wl, algo)
            lat = st.avg_latency
            row[algo] = (lat, st.dyn_power(cfg.energy), time.monotonic() - t0)
            if algo not in zero_load:
                zero_load[algo] = lat
            if (
                saturated[algo] is None
                and lat > saturation_factor * zero_load[algo]
            ):
                saturated[algo] = rate
                live.discard(algo)  # beyond saturation: stop wasting time
        out[rate] = row
    return out, saturated, zero_load
