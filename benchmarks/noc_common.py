"""Shared helpers for the NoC paper-figure benchmarks."""
from __future__ import annotations

import time

from repro.noc import NoCConfig, simulate, synthetic_workload

ALGOS = ["MU", "MP", "NMP", "DPM"]


def sweep_rates(quick: bool) -> list[float]:
    if quick:
        return [0.01, 0.03, 0.05, 0.07, 0.09]
    return [0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.07, 0.08, 0.10, 0.12]


def run_curve(
    dest_range: tuple[int, int],
    rates: list[float],
    cycles: int,
    seed: int = 3,
    saturation_factor: float = 4.0,
):
    """(rate -> {algo: (latency, power_pj_per_cycle)}) + saturation rates.

    The measurement window (warmup / drain_grace) rides on ``NoCConfig``
    defaults — the single source of truth shared with ``noc.xsim``.
    """
    cfg = NoCConfig(dest_range=dest_range)
    out: dict[float, dict[str, tuple[float, float]]] = {}
    saturated: dict[str, float | None] = {a: None for a in ALGOS}
    zero_load: dict[str, float] = {}
    live = set(ALGOS)
    for rate in rates:
        wl = synthetic_workload(cfg, rate, cycles, seed=seed)
        row = {}
        for algo in list(live):
            t0 = time.monotonic()
            st = simulate(cfg, wl, algo)
            lat = st.avg_latency
            row[algo] = (lat, st.dyn_power(cfg.energy), time.monotonic() - t0)
            if algo not in zero_load:
                zero_load[algo] = lat
            if (
                saturated[algo] is None
                and lat > saturation_factor * zero_load[algo]
            ):
                saturated[algo] = rate
                live.discard(algo)  # beyond saturation: stop wasting time
        out[rate] = row
    return out, saturated, zero_load
