"""Fig. 6: average packet latency vs injection rate, 4 destination ranges.

Paper claims reproduced: DPM has the lowest latency at every range and
saturates latest; MU saturates earliest at large ranges.
"""
from __future__ import annotations

import json
import pathlib

from repro.noc import DEST_RANGES

from .noc_common import resolve_algos, run_curve, sweep_rates

CACHE = pathlib.Path(__file__).parent / "results" / "fig6.json"


def run(quick: bool = False, cycles: int | None = None, algos=None):
    cycles = cycles or (800 if quick else 1500)
    rates = sweep_rates(quick)
    algos = resolve_algos(algos)
    rows = []
    data = {}
    for dr in DEST_RANGES:
        # measurement window comes from NoCConfig defaults (DESIGN.md §5)
        curves, saturated, zero = run_curve(dr, rates, cycles, algos=algos)
        data[str(dr)] = {
            "curves": {
                str(r): {a: v[:2] for a, v in row.items()}
                for r, row in curves.items()
            },
            "saturated": saturated,
        }
        for rate, row in curves.items():
            for algo, (lat, power, wall) in row.items():
                rows.append(
                    (
                        f"fig6/range{dr[0]}-{dr[1]}/rate{rate}/{algo}",
                        wall * 1e6,
                        f"avg_latency={lat:.2f}",
                    )
                )
        # per-range summary: DPM best latency at the last rate all algos live
        common = [
            r for r, row in curves.items() if len(row) == len(algos)
        ]
        if common:
            r = common[-1]
            best = min(curves[r], key=lambda a: curves[r][a][0])
            rows.append(
                (
                    f"fig6/range{dr[0]}-{dr[1]}/summary",
                    0.0,
                    f"best_at_rate_{r}={best};"
                    + ";".join(
                        f"{a}={curves[r][a][0]:.1f}" for a in curves[r]
                    ),
                )
            )
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))
    return rows
