"""ML-workload trace replay: DPM vs baselines on captured traffic.

The paper's figures sweep *synthetic* traffic; this suite replays the
``repro.noc.trace`` workload classes — real communication shapes captured
from the repo's own code paths — through both simulators and compares:

* **schedule level**: the EP all-to-all lowered from the DPM-planned
  ``alltoall_schedule`` vs the classic ``ring_alltoall_schedule`` shift
  (same chunks, different round structure), replayed on the same fabric;
* **routing level**: every workload class replayed under each registered
  routing algorithm (DPM/MU/MP/NMP out of the box) — the NoC-level
  comparison the paper makes, now on ML traffic instead of uniform random;
* **fault level**: the collective workloads replayed on a degraded mesh
  (``broken_links``), pricing the route-provider detours on real traffic.

Workload classes: collective phases (EP all-to-all, ZeRO-1 gather, int8
compressed all-reduce), coherence-invalidation bursts, Poisson serving
arrivals, and an HLO-profile mix from a ``repro.configs`` model.

Every replay cross-validates host vs xsim (identical per-packet delivery
sets — the CSV rows gate on it), and the artifact
(results/trace_replay.json) records per-phase and end-to-end cycles for
``summarize_repro.py``.
"""
from __future__ import annotations

import json
import pathlib

CACHE = pathlib.Path(__file__).parent / "results" / "trace_replay.json"

# nested fault rungs (1 then 2 broken links): detourable, never
# disconnecting the 4x4
FAULTS_4X4 = ((((1, 1), (1, 2)),), (((1, 1), (1, 2)), ((3, 0), (3, 1))))


def _traces(quick: bool):
    from repro.noc.trace import (
        coherence_trace,
        compressed_allreduce_trace,
        ep_dispatch_trace,
        model_collective_mix,
        serving_trace,
        zero1_gather_trace,
    )

    n = 16  # ranks on the 4x4 fabric
    traces = [
        ep_dispatch_trace(n, chunk_bytes=96),
        zero1_gather_trace(n, param_bytes=4096),
        compressed_allreduce_trace(n, grad_bytes=65536),
        coherence_trace(n, num_bursts=2 if quick else 4, lines_per_burst=3,
                        sharers=3, seed=1),
        serving_trace(n, num_requests=8 if quick else 16, rate=0.02, seed=2),
    ]
    if not quick:
        traces.append(model_collective_mix("smollm-135m", n, scale_to=256))
    return traces


def run(quick: bool = False, algos=None):
    from repro.dist.multicast import ring_alltoall_schedule
    from repro.noc import NoCConfig
    from repro.noc.trace import Trace, cross_validate, from_schedule

    from .noc_common import resolve_algos

    algos = resolve_algos(algos)
    cfg = NoCConfig(n=4, topology="mesh")
    traces = _traces(quick)

    # -- routing level: every class x every algorithm, both engines -------
    replays: dict[str, dict] = {}
    for tr in traces:
        per_algo = {}
        for a in algos:
            h, x = cross_validate(tr, cfg, a)  # raises on delivery divergence
            per_algo[a] = {
                "total_cycles_host": h.total_cycles,
                "total_cycles_xsim": x.total_cycles,
                "phase_cycles": h.phase_cycles,
            }
        replays[tr.name] = {
            "kind": tr.meta.get("kind", "?"),
            "phases": len(tr.phases),
            "events": tr.num_events,
            "algos": per_algo,
            "json_bytes": len(tr.to_json()),
        }
        # the artifact's traces must round-trip (the capture contract)
        assert Trace.from_json(tr.to_json()) == tr

    # -- schedule level: DPM-planned a2a rounds vs the ring shift ---------
    ep = traces[0]
    ring = from_schedule(
        ring_alltoall_schedule(16), "ep_alltoall.n16.ring",
        ep.meta["chunk_bytes"], phase_prefix="shift.r",
    )
    ring2 = Trace(ring.name, ring.num_ranks, ring.phases + ring.phases,
                  {"kind": "ep_alltoall_ring"})  # dispatch + combine
    hr, xr = cross_validate(ring2, cfg, "DPM")
    sched_cmp = {
        "dpm_schedule_cycles": replays[ep.name]["algos"]["DPM"][
            "total_cycles_host"],
        "ring_schedule_cycles": hr.total_cycles,
        "ring_schedule_cycles_xsim": xr.total_cycles,
        "dpm_rounds": len(ep.phases),
        "ring_rounds": len(ring2.phases),
    }

    # -- fault level: collectives on a degraded fabric --------------------
    fault_rows: dict[str, list[dict]] = {}
    for tr in traces[:2]:  # EP a2a + zero1 gather
        ladder = []
        for links in FAULTS_4X4:
            dcfg = NoCConfig(n=4, topology="mesh", broken_links=links)
            h, x = cross_validate(tr, dcfg, "DPM")
            ladder.append({
                "broken_links": len(links),
                "total_cycles_host": h.total_cycles,
                "total_cycles_xsim": x.total_cycles,
            })
        fault_rows[tr.name] = ladder

    data = {
        "fabric": "4x4 mesh", "num_ranks": 16, "algos": algos,
        "replays": replays,
        "schedule_comparison": sched_cmp,
        "fault_ladder": fault_rows,
        "notes": (
            "every row cross-validated host vs xsim: identical per-packet "
            "delivery sets per phase, end-to-end completion within 10%; "
            "phases replay under barrier semantics (phase k+1 injects only "
            "after phase k drains)"
        ),
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))

    rows = []
    for name, rec in replays.items():
        per = rec["algos"]
        lo = min(v["total_cycles_host"] for v in per.values())
        best = "|".join(a for a in algos if per[a]["total_cycles_host"] == lo)
        rows.append((
            f"trace_replay/{name}", 0.0,
            ";".join(f"{a}:{per[a]['total_cycles_host']}" for a in algos)
            + f";best={best}",
        ))
    rows.append((
        "trace_replay/ep_schedule_vs_ring", 0.0,
        f"dpm={sched_cmp['dpm_schedule_cycles']};"
        f"ring={sched_cmp['ring_schedule_cycles']};"
        f"rounds={sched_cmp['dpm_rounds']}v{sched_cmp['ring_rounds']}",
    ))
    for name, ladder in fault_rows.items():
        healthy = replays[name]["algos"]["DPM"]["total_cycles_host"]
        worst = ladder[-1]["total_cycles_host"]
        rows.append((
            f"trace_replay/{name}/faults", 0.0,
            ";".join(f"{p['broken_links']}:{p['total_cycles_host']}"
                     for p in ladder)
            + f";degradation_x{worst / max(1, healthy):.3f}",
        ))
    return rows
