"""3-D and chiplet-package fabrics: DPM vs MU/MP/NMP beyond the paper's 2-D mesh.

Protocol (ISSUE 9 tentpole gate):

* three fabrics — a 4x4x4 3-D mesh, a 4x4x4 3-D torus (both 3x3x3 in
  ``--quick``), and a 2x2-chiplet x 4x4-router interposer package — each
  driven through the batched ``xsimulate`` engine with uniform and hotspot
  synthetic workloads across MU/MP/NMP/DPM; rows gate on every cell
  draining and on DPM beating MU's flit-traversal bill (the paper's
  headline claim, re-checked off-plane);
* weighted heterogeneous links: DPM planned under the ``weighted`` cost
  model on a z_weight=4.0 mesh (TSV pillars priced 4x) and a noi_weight=6.0
  package, against hop-count DPM on the same fabric — the artifact
  quantifies how many instances change merge choices and the total
  weighted-cost saving (gated > 0: the lever must actually steer merges);
* EP-dispatch trace replay: ``ep_dispatch_trace`` (dispatch + combine
  all-to-all rounds of ``dist.ep``) embedded in snake-label order and
  replayed phase-barriered through xsim on the 3-D torus and the package;
* cross-validation: host ``WormholeSim`` vs ``xsimulate`` per-packet
  delivery sets must be identical on a small instance of each new kind
  (the fidelity contract extended off-plane, also pinned by
  tests/test_topo3d.py).

The committed artifact (results/topo3d_sweep.json) records the latency
grid, the weighted-planning deltas, trace cycle totals, and parity results.
"""
from __future__ import annotations

import json
import pathlib
import random

CACHE = pathlib.Path(__file__).parent / "results" / "topo3d_sweep.json"


def _hotspot_workload(cfg, rate, cycles, seed, hot_frac=0.35, region_size=8):
    """Uniform sources, but ``hot_frac`` of the multicasts draw their whole
    destination set from the ``region_size`` nodes around the fabric center
    — the concentrated-reply pattern (parameter-server reads, EP combine
    toward a dense expert) that stresses one chiplet / one z-column."""
    from repro.noc.traffic import Request, Workload

    g = cfg.make_topology()
    nodes = g.nodes()
    rng = random.Random(seed)
    hot = g.from_idx(g.num_nodes // 2)
    region = sorted(nodes, key=lambda c: (g.distance(hot, c), g.idx(c)))
    region = region[:region_size]
    lo, hi = cfg.dest_range
    reqs = []
    for t in range(cycles):
        for src in nodes:
            if rng.random() >= rate:
                continue
            pool = region if rng.random() < hot_frac else nodes
            cand = [d for d in pool if d != src]
            k = min(rng.randint(lo, hi), len(cand))
            reqs.append(Request(t, src, rng.sample(cand, k)))
    return Workload(f"hotspot-{rate:.4f}", reqs, cycles)


def _fabrics(quick):
    d = 3 if quick else 4
    return [
        (f"mesh3d-{d}x{d}x{d}",
         dict(n=d, m=d, topology="mesh3d", topology_params=(d,)), 0.02),
        (f"torus3d-{d}x{d}x{d}",
         dict(n=d, m=d, topology="torus3d", topology_params=(d,)), 0.02),
        ("chiplet-2x2x4x4",
         dict(n=8, m=8, topology="chiplet", topology_params=(2, 2)), 0.012),
    ]


def _weighted_cost(g, p):
    """Price a plan under the fabric's heterogeneous link weights."""
    return sum(
        g.link_weight(u, v)
        for path in p.paths
        for u, v in zip(path.hops, path.hops[1:])
    )


def _weighted_planning(name, g, instances):
    from repro.core import plan

    diffs, saved, cost_w, cost_u = 0, 0.0, 0.0, 0.0
    for src, dests in instances:
        p_u = plan("DPM", g, src, dests)  # hop-count objective
        p_w = plan("DPM", g, src, dests, cost_model="weighted")
        cu, cw = _weighted_cost(g, p_u), _weighted_cost(g, p_w)
        cost_u += cu
        cost_w += cw
        hops_u = sorted(tuple(q.hops) for q in p_u.paths)
        hops_w = sorted(tuple(q.hops) for q in p_w.paths)
        if hops_u != hops_w:
            diffs += 1
            saved += cu - cw
    return {
        "fabric": name,
        "instances": len(instances),
        "plans_changed": diffs,
        "weighted_cost_hopmodel": round(cost_u, 1),
        "weighted_cost_weightedmodel": round(cost_w, 1),
        "weighted_cost_saved": round(cost_u - cost_w, 1),
    }


def _instances(g, count, kmax, seed):
    rng = random.Random(seed)
    nodes = g.nodes()
    out = []
    for _ in range(count):
        picks = rng.sample(nodes, rng.randint(3, kmax + 1))
        out.append((picks[0], picks[1:]))
    return out


def _parity_case(name, cfg_kw, rate, cycles, algo):
    from repro.core import plan
    from repro.noc import NoCConfig, WormholeSim, synthetic_workload, xsimulate

    cfg = NoCConfig(warmup=0, drain_grace=1200, **cfg_kw)
    wl = synthetic_workload(cfg, rate, cycles, seed=3)
    res = xsimulate(cfg, [wl], (algo,))
    g = cfg.make_topology()
    sim = WormholeSim(cfg, measure_window=(0, wl.horizon))
    for r in wl.requests:
        sim.add_plan(plan(algo, g, r.src, r.dests), r.time)
    pst = sim.run(wl.horizon + cfg.drain_grace)
    psets = {pk.pid: {g.idx(c) for c in pk.delivery_times}
             for pk in sim.packets}
    xlat = float(res.avg_latency(0, 0))
    dev = abs(xlat - pst.avg_latency) / max(1e-9, pst.avg_latency)
    return {
        "case": name,
        "algo": algo,
        "delivery_sets_equal": bool(psets == res.delivered_sets(0, 0)),
        "drained": bool(res.all_drained(0, 0)
                        and pst.packets_finished == pst.packets_created),
        "latency_host": round(pst.avg_latency, 3),
        "latency_xsim": round(xlat, 3),
        "latency_rel_dev": round(dev, 4),
    }


def run(quick: bool = False, algos=None):
    from repro.core.topology import make_topology
    from repro.noc import NoCConfig, synthetic_workload, xsimulate
    from repro.noc.trace import ep_dispatch_trace, replay_xsim

    from .noc_common import resolve_algos

    algos = resolve_algos(algos) if algos is not None else [
        "MU", "MP", "NMP", "DPM"
    ]
    cycles = 100 if quick else 160
    grace = 1600

    # ---------------- latency grid: fabric x workload shape x algorithm ---
    grid_rows = []
    for name, kw, rate in _fabrics(quick):
        cfg = NoCConfig(
            warmup=0, drain_grace=grace, multicast_fraction=0.5,
            dest_range=(3, 6), **kw,
        )
        wls = [
            synthetic_workload(cfg, rate, cycles, seed=1),
            _hotspot_workload(cfg, rate, cycles, seed=2),
        ]
        res = xsimulate(cfg, wls, tuple(algos))
        for w, shape in enumerate(("uniform", "hotspot")):
            cell = {"fabric": name, "workload": shape, "rate": rate}
            for a, algo in enumerate(algos):
                cell[algo] = {
                    "avg_latency": round(float(res.avg_latency(w, a)), 3),
                    "flit_traversals":
                        int(res.stats(w, a).flit_link_traversals),
                    "drained": bool(res.all_drained(w, a)),
                }
            grid_rows.append(cell)

    # ---------------- weighted heterogeneous links ------------------------
    n_inst = 24 if quick else 60
    d = 3 if quick else 4
    weighted = [
        _weighted_planning(
            f"mesh3d-{d}x{d}x{d}-zw4",
            make_topology("mesh3d", d, d, params=(d, 4.0)),
            _instances(make_topology("mesh3d", d, d, params=(d, 4.0)),
                       n_inst, 10, seed=5),
        ),
        _weighted_planning(
            "chiplet-2x2x4x4-noi6",
            make_topology("chiplet", 8, 8, params=(2, 2, 6.0)),
            _instances(make_topology("chiplet", 8, 8, params=(2, 2, 6.0)),
                       n_inst, 10, seed=6),
        ),
    ]

    # ---------------- EP-dispatch trace replay ----------------------------
    traces = []
    trace_fabrics = [_fabrics(quick)[1]] if quick else _fabrics(quick)[1:]
    for name, kw, _rate in trace_fabrics:
        cfg = NoCConfig(warmup=0, drain_grace=grace, **kw)
        nn = cfg.make_topology().num_nodes
        tr = ep_dispatch_trace(nn, chunk_bytes=256, algo="DPM")
        for algo in ("MU", "DPM"):
            rr = replay_xsim(tr, cfg, algo)
            traces.append({
                "fabric": name,
                "trace": tr.name,
                "algo": algo,
                "phases": len(rr.phase_cycles),
                "total_cycles": int(sum(rr.phase_cycles)),
            })

    # ---------------- host-vs-xsim parity (fidelity gate) -----------------
    parity = [
        _parity_case(
            "mesh3d-3x3x3",
            dict(n=3, m=3, topology="mesh3d", topology_params=(3,),
                 dest_range=(2, 5)), 0.03, 80, "DPM"),
        _parity_case(
            "mesh3d-3x3x3-zw2",
            dict(n=3, m=3, topology="mesh3d", topology_params=(3, 2.0),
                 dest_range=(2, 5)), 0.03, 80, "DPM"),
        _parity_case(
            "chiplet-2x2x4x4",
            dict(n=8, m=8, topology="chiplet", topology_params=(2, 2),
                 dest_range=(2, 5)), 0.02, 80, "DPM"),
    ]

    data = {
        "quick": quick,
        "algos": algos,
        "cycles": cycles,
        "latency_grid": grid_rows,
        "weighted_planning": weighted,
        "ep_dispatch_traces": traces,
        "parity": parity,
        "notes": (
            "xsim batched engine on registered 3-D/chiplet topologies; "
            "weighted rows compare DPM merge choices under the 'weighted' "
            "cost model vs hop count on the same heterogeneous fabric"
        ),
    }
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    CACHE.write_text(json.dumps(data, indent=1))

    rows = []
    for cell in grid_rows:
        assert all(cell[a]["drained"] for a in algos), cell["fabric"]
        if "MU" in algos and "DPM" in algos:
            assert (cell["DPM"]["flit_traversals"]
                    < cell["MU"]["flit_traversals"]), cell
        rows.append((
            f"topo3d/{cell['fabric']}/{cell['workload']}", 0.0,
            ";".join(f"{a}:{cell[a]['avg_latency']}" for a in algos),
        ))
    for wrow in weighted:
        assert wrow["plans_changed"] > 0, wrow
        assert wrow["weighted_cost_saved"] > 0, wrow
        rows.append((
            f"topo3d/weighted/{wrow['fabric']}", 0.0,
            f"changed={wrow['plans_changed']}/{wrow['instances']};"
            f"saved={wrow['weighted_cost_saved']}",
        ))
    for t in traces:
        rows.append((
            f"topo3d/trace/{t['fabric']}/{t['algo']}", 0.0,
            f"phases={t['phases']};cycles={t['total_cycles']}",
        ))
    for p in parity:
        assert p["delivery_sets_equal"] and p["drained"], p
        rows.append((
            f"topo3d/parity/{p['case']}", 0.0,
            f"sets_equal={p['delivery_sets_equal']};"
            f"dev={p['latency_rel_dev']}",
        ))
    return rows
