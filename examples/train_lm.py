"""End-to-end training driver: train smollm-135m (the real 135M config) on
the synthetic Markov corpus for a few hundred steps with checkpointing and
auto-resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 40 --quick  # CI-sized

Interrupt it and run again: it resumes from the last committed checkpoint
and replays the exact data stream (bitwise-deterministic restart).
"""
import argparse

from repro.configs import get_arch
from repro.models import RunConfig
from repro.train import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--quick", action="store_true",
                    help="reduced (smoke) config instead of the full 135M")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_arch("smollm-135m", smoke=args.quick)
    run = RunConfig(
        remat="none",
        attn_chunk_q=min(128, args.seq),
        attn_chunk_k=min(128, args.seq),
        learning_rate=1e-3,
        vocab_round=128,
    )
    res = train(
        cfg,
        run,
        LoopConfig(
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=max(20, args.steps // 5),
            log_every=10,
        ),
    )
    print(
        f"\nfinal: loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
        f"{len(res.losses)} steps ({res.wall_s:.0f}s)"
        + (f", resumed from step {res.resumed_from}" if res.resumed_from else "")
    )
    assert res.losses[-1] < res.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
