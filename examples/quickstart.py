"""Quickstart: the paper's DPM algorithm in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Partitions a multicast destination set with Algorithm 1 (vs every
   algorithm in the routing registry, including energy-aware DPM-E).
2. Runs the flit-level wormhole simulator on the resulting plans.
3. Registers a third-party routing algorithm — one decorator, zero edits
   anywhere else — and plans/simulates through it.
4. Plans the same multicast on a 16x16 TPU-pod torus as ppermute rounds.
5. Resolves model sharding rules and the DPM-planned EP dispatch schedule.
"""
import random

from repro.core import available_algorithms, dpm_partition, grid, plan
from repro.core.algo import register_algorithm
from repro.core.routing import greedy_tour
from repro.dist.multicast import Torus, schedule_multicasts
from repro.noc import NoCConfig, WormholeSim

g = grid(8)
rng = random.Random(0)
nodes = [(x, y) for x in range(8) for y in range(8)]
picks = rng.sample(nodes, 11)
src, dests = picks[0], picks[1:]
print(f"source {src}, {len(dests)} destinations: {dests}\n")

# --- 1. Algorithm 1 --------------------------------------------------------
res = dpm_partition(g, src, dests)
print("DPM partitions (Algorithm 1):")
for p in res.partitions:
    print(
        f"  P{''.join(map(str, p.ids))}: {len(p.dests)} dests, "
        f"rep={p.rep} mode={p.mode} C_t={p.cost_mu} C_p={p.cost_dp}"
    )
print(f"  merge iterations: {res.iterations}\n")

print("total hop count by registered algorithm:")
for algo in available_algorithms(g):
    print(f"  {algo:5s} {plan(algo, g, src, dests).total_hops}")

# --- 2. cycle-level simulation --------------------------------------------
print("\nwormhole latency (single multicast, unloaded 8x8 mesh):")
for algo in available_algorithms(g):
    sim = WormholeSim(NoCConfig())
    sim.add_request(algo, src, dests, 0)
    st = sim.run(5000)
    print(f"  {algo:5s} avg per-dest latency {st.avg_latency:.1f} cycles")


# --- 3. third-party registration ------------------------------------------
# One decorator publishes an algorithm to every consumer: both simulators,
# the dist schedulers, and the figure benchmarks (via --algos or the
# registry default sets). No noc/, dist/, or benchmarks/ file changes.
@register_algorithm(name="TOUR", topologies=("mesh", "torus"))
def plan_tour(g, src, dests):
    """Single nearest-destination-first tour (one worm serves everyone)."""
    from repro.core import MulticastPlan, PacketPath

    path = greedy_tour(g, src, list(dests))
    deliveries = list(dict.fromkeys(d for d in path if d in set(dests)))
    p = MulticastPlan("TOUR", src, list(dests))
    p.paths.append(PacketPath(path, deliveries))
    return p


print(f"\nregistered TOUR -> {available_algorithms(g)}")
print(f"  TOUR  {plan('TOUR', g, src, dests).total_hops} hops, "
      f"covers={plan('TOUR', g, src, dests).check_covers()}")

# --- 4. the TPU adaptation -------------------------------------------------
t = Torus(16, 16)
reqs = [((0, 0), [(x, y) for x in range(4) for y in range(4) if (x, y) != (0, 0)])]
print("\nTPU 16x16 torus: broadcast to a 4x4 pod slice (64 MiB payload):")
for algo in ("MU", "DPM"):
    sched = schedule_multicasts(t, reqs, algo)
    c = sched.cost(64 * 2**20)
    print(
        f"  {algo:4s} {c['rounds']:3d} ppermute rounds, "
        f"~{c['time_us']:.0f} us, {c['link_bytes'] / 2**20:.0f} MiB-hops"
    )

# --- 5. the distribution layer --------------------------------------------
from repro.dist.multicast import alltoall_schedule  # noqa: E402
from repro.dist.sharding import abstract_mesh, spec_for_shape  # noqa: E402

mesh = abstract_mesh(("data", 16), ("model", 16))
print("\nsharding rules on a 16x16 (data, model) mesh:")
for axes, shape in (
    (("batch", "seq", "embed"), (256, 4096, 2048)),
    (("experts", "embed", "expert_mlp"), (64, 2048, 1408)),
    (("vocab", "embed"), (163840, 2048)),
):
    print(f"  {axes} {shape} -> {spec_for_shape(axes, shape, mesh)}")

sched = alltoall_schedule(16, "DPM")
c = sched.cost(4 * 2**20, req_payload_bytes={})
print(
    f"\nEP dispatch all-to-all, 16 expert shards (4 MiB chunks): "
    f"{c['rounds']} ppermute rounds, ~{c['time_us']:.0f} us"
)
