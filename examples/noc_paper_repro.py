"""Mini reproduction of the paper's headline results (Figs. 6-8 in small).

    PYTHONPATH=src python examples/noc_paper_repro.py

Full sweeps live in the benchmark harness: python -m benchmarks.run
"""
from repro.core import available_algorithms
from repro.noc import NoCConfig, parsec_workload, simulate, synthetic_workload

FIG_ALGOS = available_algorithms("mesh", tag="fig")  # the paper's comparison set

print("latency vs injection rate, dest range 4-8 (Fig. 6 style):")
cfg = NoCConfig(dest_range=(4, 8))
print(f"{'rate':>6} " + "".join(f"{a:>8}" for a in FIG_ALGOS))
for rate in (0.02, 0.04, 0.06):
    wl = synthetic_workload(cfg, rate, 800, seed=3)
    lats = [simulate(cfg, wl, a).avg_latency for a in FIG_ALGOS]
    print(f"{rate:>6} " + "".join(f"{latency:8.1f}" for latency in lats))

print("\nfluidanimate-like trace vs MP baseline (Fig. 8 style):")
cfg = NoCConfig()
wl = parsec_workload(cfg, "fluidanimate", 1000, base_rate=0.085, seed=5)
stats = {a: simulate(cfg, wl, a) for a in FIG_ALGOS if a != "MU"}
base_lat = stats["MP"].avg_latency
base_pwr = stats["MP"].dyn_power(cfg.energy)
for a, st in stats.items():
    print(
        f"  {a:4s} latency {st.avg_latency:7.1f} "
        f"({100 * (1 - st.avg_latency / base_lat):+5.1f}% vs MP)   "
        f"power {st.dyn_power(cfg.energy):7.1f} pJ/cyc "
        f"({100 * (1 - st.dyn_power(cfg.energy) / base_pwr):+5.1f}%)"
    )
