"""Serving example: batched requests against a decoder LM with prefill +
KV-cache decode (greedy), via the queue-based batch server.

    PYTHONPATH=src python examples/serve_batch.py --arch qwen1.5-32b
    (any of the 10 assigned archs; smoke-scale weights on CPU)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import RunConfig, model_init
from repro.serve import BatchServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    run = RunConfig(
        remat="none", attn_chunk_q=64, attn_chunk_k=64, vocab_round=64,
        kv_cache_dtype="int8" if args.int8_kv else "bfloat16",
    )
    params, _ = model_init(jax.random.PRNGKey(0), cfg, run)
    server = BatchServer(params, cfg, run, max_batch=4, max_wait_s=0.01)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    if cfg.embed_input != "tokens":
        print(f"{args.arch} is a frame-input backbone; serving token archs only")
        return
    for rid in range(args.requests):
        plen = int(rng.integers(8, 33))
        server.submit(Request(rid, rng.integers(0, cfg.vocab, plen), args.max_tokens))
    done = 0
    while done < args.requests:
        for resp in server.serve_once():
            done += 1
            print(
                f"  req {resp.rid:2d}: {len(resp.tokens)} tokens in "
                f"{resp.latency_s * 1e3:6.0f} ms  head={resp.tokens[:6]}"
            )
    wall = time.monotonic() - t0
    s = server.stats
    print(
        f"\nserved {s['requests']} requests / {s['tokens']} tokens in "
        f"{wall:.1f}s ({s['tokens'] / wall:.1f} tok/s, {s['batches']} batches)"
    )


if __name__ == "__main__":
    main()
