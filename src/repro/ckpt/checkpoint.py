"""Sharded checkpointing with async save, auto-resume and elastic re-shard.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.msgpack   — tree structure, leaf paths, shapes, dtypes, step
        arrays/<leaf>.npy  — one file per leaf (per-host shard files on
                             multi-host: suffix .h<k>; single-process writes
                             the full array)
        COMMITTED          — written last; partial checkpoints are ignored

Elastic scaling: restore() takes target shardings for an arbitrary new mesh
and device_puts each leaf accordingly — a checkpoint written on a 256-chip
mesh restores onto 512 or 64 chips (tests/test_train.py exercises a
re-shard across mesh shapes).
"""
from __future__ import annotations

import os
import pathlib
import re
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


def save(ckpt_dir: str | os.PathLike, step: int, tree, async_: bool = False):
    """Serialize a pytree of arrays. Returns a join() callable."""
    base = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    arrays = base / "arrays"
    arrays.mkdir(parents=True, exist_ok=True)
    leaves = _leaf_paths(tree)
    manifest = {
        "step": step,
        "leaves": [
            {
                "name": n,
                "shape": list(np.shape(x)),
                "dtype": str(np.asarray(jax.device_get(x)).dtype)
                if hasattr(x, "dtype")
                else "float32",
            }
            for n, x in leaves
        ],
        "treedef": str(jax.tree_util.tree_structure(tree)),
    }

    # snapshot to host memory synchronously: the caller may donate/mutate
    # device buffers right after save() returns (async writer only does IO)
    host = [(n, np.asarray(jax.device_get(x))) for n, x in leaves]

    def _write():
        for name, arr in host:
            fn = arrays / (name.replace("/", "__") + ".npy")
            np.save(fn, arr)
        with open(base / "manifest.msgpack", "wb") as f:
            f.write(msgpack.packb(manifest))
        (base / "COMMITTED").touch()

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t.join
    _write()
    return lambda: None


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for d in base.iterdir():
        m = re.fullmatch(r"step_(\d+)", d.name)
        if m and (d / "COMMITTED").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | os.PathLike,
    step: int,
    like,  # pytree of arrays or ShapeDtypeStructs (target structure)
    shardings=None,  # optional pytree of NamedShardings (elastic re-shard)
):
    base = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    if not (base / "COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {base}")
    arrays = base / "arrays"
    names = [n for n, _ in _leaf_paths(like)]
    loaded = []
    for n in names:
        fn = arrays / (n.replace("/", "__") + ".npy")
        loaded.append(np.load(fn))
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    out = []
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    for i, (arr, ref) in enumerate(zip(loaded, flat_like)):
        target_dtype = ref.dtype if hasattr(ref, "dtype") else arr.dtype
        a = arr.astype(target_dtype)
        if shard_flat is not None:
            out.append(jax.device_put(a, shard_flat[i]))
        else:
            out.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out)
