"""Checkpointing: async committed saves, auto-resume, elastic re-shard."""
from .checkpoint import latest_step, restore, save

__all__ = ["latest_step", "restore", "save"]
