"""Production meshes. Functions, not module constants — importing this must
never touch jax device state (the dry-run sets device-count flags first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e-class); 2 pods for the multi-pod dry-run.

    Axes: "pod" (outer data-parallel over DCI), "data" (DP within pod),
    "model" (TP/EP within pod).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


# hardware constants (roofline) — TPU v5e-class target
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW_PER_LINK = 50e9  # B/s per link (~4 usable links/chip in a 2-D torus)
DCI_BW = 25e9  # B/s per chip across pods (pod axis)
