"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop (lax.scan) body ONCE,
which silently undercounts scanned-layer models by the layer count (verified
in tests/test_launch.py). This module re-derives the three roofline inputs
from the optimized HLO itself:

* computations are parsed into blocks and walked from ENTRY; a while op
  multiplies its body+condition cost by ``known_trip_count`` (emitted by XLA
  in backend_config), fusions add their called computation's flops but only
  the fusion call's operand/result bytes (fused kernels touch HBM once);
* dot flops = 2 x prod(result dims) x prod(contracting dims), elementwise
  and reduce ops count one flop per output element;
* collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute) are operand bytes x enclosing trip counts — the
  quantity cost_analysis does not report at all.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+)+)\s+"
    r"([\w\-]+)\((.*)$"
)
# computation headers sit at column 0: `%name (args) -> type {` — args/types
# may contain nested parens (tuples), so match greedily to the trailing `{`
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


_REF_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(rest: str) -> list[str]:
    """Operand references of an op line.

    ``rest`` starts immediately after the op's opening paren; operands are
    ``type %name`` entries (types may themselves contain commas and tuple
    parens), so splitting on commas corrupts the names — instead cut at the
    matching close paren and take the ``%name`` references, which excludes
    trailing attrs like ``calls=%...`` / ``body=%...``.
    """
    depth = 1
    seg = rest
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                seg = rest[:i]
                break
    return _REF_RE.findall(seg)


def _shapes(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _TYPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    kind: str
    result_type: str
    rest: str  # operand list + attrs


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)

    def param_slice_bytes(self, defs) -> dict[int, int]:
        """For fused computations: parameters consumed by interior
        dynamic-slice/dynamic-update-slice ops are NOT streamed in full —
        map param index -> effective bytes (slice size), mirroring XLA's
        HloCostAnalysis special cases. Layout-only chains
        (bitcast/reshape/transpose/copy) between the parameter and the
        slice op are traced through."""
        params: dict[str, int] = {}
        for op in self.ops:
            if op.kind == "parameter":
                m = re.match(r"\s*(\d+)", op.rest)
                if m:
                    params[op.name] = int(m.group(1))
        # origin[n] = param index if n derives from a parameter via
        # layout-only ops
        origin: dict[str, int] = dict(params)
        passthrough = {"bitcast", "reshape", "transpose", "copy", "convert"}
        for op in self.ops:
            if op.kind in passthrough:
                ops_in = _operand_names(op.rest)
                if ops_in and ops_in[0] in origin:
                    origin[op.name] = origin[ops_in[0]]
        out: dict[int, int] = {}
        for op in self.ops:
            operands = _operand_names(op.rest)
            if op.kind == "dynamic-slice" and operands and operands[0] in origin:
                out[origin[operands[0]]] = _bytes_of(op.result_type)
            if (
                op.kind == "dynamic-update-slice"
                and operands
                and operands[0] in origin
                and len(operands) > 1
            ):
                upd = defs.get(operands[1], "")
                if not upd:
                    # interior update operand: look it up locally
                    for o2 in self.ops:
                        if o2.name == operands[1]:
                            upd = o2.result_type
                            break
                out[origin[operands[0]]] = 2 * _bytes_of(upd)
        return out


def _parse(hlo_text: str) -> tuple[dict, str]:
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = _Comp(mc.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rtype, kind, rest = mo.groups()
        cur.ops.append(_Op(name, kind, rtype, rest))
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _dot_flops(op: _Op, defs: dict[str, str]) -> float:
    """2 x prod(result) x prod(contracting dims of lhs)."""
    res = _shapes(op.result_type)
    out_elems = 1
    for _, dims in res:
        for d in dims:
            out_elems *= d
    operands = _operand_names(op.rest)
    lhs_type = defs.get(operands[0], "") if operands else ""
    lhs_shapes = _shapes(lhs_type)
    contract = 1
    m = _LHS_C_RE.search(op.rest)
    if m and lhs_shapes:
        dims = lhs_shapes[0][1]
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def analyze(hlo_text: str) -> dict:
    comps, entry = _parse(hlo_text)
    # map op name -> result type (for operand byte lookups), global
    defs: dict[str, str] = {}
    for c in comps.values():
        for op in c.ops:
            defs[op.name] = op.result_type

    memo: dict[str, dict] = {}

    def cost_of(cname: str) -> dict:
        if cname in memo:
            return memo[cname]
        acc = {"flops": 0.0, "bytes": 0.0, "coll": defaultdict(float),
               "coll_count": 0.0, "by_kind": defaultdict(float)}
        memo[cname] = acc  # guards recursion
        comp = comps.get(cname)
        if comp is None:
            return acc
        for op in comp.ops:
            kind = op.kind
            if kind in _FREE_OPS:
                continue
            out_b = _bytes_of(op.result_type)
            operand_names = _operand_names(op.rest)
            slice_map: dict[int, int] = {}
            if kind == "fusion":
                m0 = _CALLS_RE.search(op.rest)
                if m0 and m0.group(1) in comps:
                    sub = comps[m0.group(1)]
                    slice_map = sub.param_slice_bytes(defs)
                    # fusion rooted in a dynamic-update-slice writes in
                    # place: the full-buffer output is aliased, only the
                    # update region is written (already counted 2x in the
                    # slice map), so drop the output bytes
                    if any(
                        o2.kind == "dynamic-update-slice" for o2 in sub.ops
                    ) and any(
                        i in slice_map
                        and defs.get(t, "")
                        and _bytes_of(defs[t]) == out_b
                        for i, t in enumerate(operand_names)
                    ):
                        out_b = 0
            opnd_b = 0
            for i, token in enumerate(operand_names):
                if token in defs:
                    opnd_b += slice_map.get(i, _bytes_of(defs[token]))
            if kind == "dynamic-slice":
                opnd_b = out_b  # reads only the slice
            elif kind == "dynamic-update-slice" and len(operand_names) > 1:
                upd = defs.get(operand_names[1], "")
                opnd_b = 2 * _bytes_of(upd)
                out_b = 0  # in-place; write already counted
            base_kind = kind.replace("-start", "").replace("-done", "")
            if base_kind in COLLECTIVE_OPS:
                if kind.endswith("-done"):
                    continue
                acc["coll"][base_kind] += opnd_b or out_b
                acc["coll_count"] += 1
                acc["bytes"] += opnd_b + out_b
                continue
            if kind == "while":
                m = _TRIP_RE.search(op.rest)
                trips = int(m.group(1)) if m else 1
                body = _CALLS_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                for sub, mult in ((body, trips), (cond, trips + 1)):
                    if sub:
                        c = cost_of(sub.group(1))
                        acc["flops"] += mult * c["flops"]
                        acc["bytes"] += mult * c["bytes"]
                        for k, v in c["coll"].items():
                            acc["coll"][k] += mult * v
                        acc["coll_count"] += mult * c["coll_count"]
                        for k, v in c["by_kind"].items():
                            acc["by_kind"][k] += mult * v
                continue
            if kind == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    subs = [s.strip().lstrip("%") for s in m.group(1).split(",")]
                    costs = [cost_of(s) for s in subs]
                    if costs:
                        best = max(costs, key=lambda c: c["flops"] + c["bytes"])
                        for k in ("flops", "bytes", "coll_count"):
                            acc[k] += best[k]
                        for k, v in best["coll"].items():
                            acc["coll"][k] += v
                continue
            if kind in ("fusion", "call", "custom-call", "map", "reduce",
                        "reduce-window", "scatter", "sort", "select-and-scatter"):
                acc["bytes"] += opnd_b + out_b
                acc["by_kind"][kind] += opnd_b + out_b
                m = _CALLS_RE.search(op.rest)
                if m:
                    c = cost_of(m.group(1))
                    acc["flops"] += c["flops"]
                    # fused internals do not re-touch HBM: bytes excluded,
                    # but nested collectives/whiles inside calls must count
                    for k, v in c["coll"].items():
                        acc["coll"][k] += v
                    acc["coll_count"] += c["coll_count"]
                    if kind == "call":
                        acc["bytes"] += c["bytes"]
                continue
            if kind == "dot" or kind == "convolution":
                acc["flops"] += _dot_flops(op, defs)
                acc["bytes"] += opnd_b + out_b
                acc["by_kind"][kind] += opnd_b + out_b
                continue
            # generic elementwise / data movement: 1 flop per output element
            out_elems = 0
            for _, dims in _shapes(op.result_type):
                n = 1
                for d in dims:
                    n *= d
                out_elems += n
            acc["flops"] += out_elems
            acc["bytes"] += opnd_b + out_b
            acc["by_kind"][kind] += opnd_b + out_b
        return acc

    total = cost_of(entry)
    coll = dict(total["coll"])
    coll["total"] = sum(coll.values())
    coll["count"] = total["coll_count"]

    # per-while attribution (uses the SAME accounting): trips x body cost
    whiles = []
    for c in comps.values():
        for op in c.ops:
            if op.kind != "while":
                continue
            m = _TRIP_RE.search(op.rest)
            trips = int(m.group(1)) if m else 1
            body = _CALLS_RE.search(op.rest)
            if not body:
                continue
            bc = cost_of(body.group(1))
            whiles.append(
                {
                    "body": body.group(1)[:60],
                    "trips": trips,
                    "bytes_total": trips * bc["bytes"],
                    "flops_total": trips * bc["flops"],
                }
            )
    whiles.sort(key=lambda w: -w["bytes_total"])
    return {
        "flops": total["flops"],
        "bytes": total["bytes"],
        "collectives": coll,
        "bytes_by_kind": dict(
            sorted(total["by_kind"].items(), key=lambda kv: -kv[1])
        ),
        "whiles": whiles[:8],
    }


def collective_bytes(hlo_text: str) -> dict:
    """Back-compat helper: trip-count-aware collective bytes only."""
    return analyze(hlo_text)["collectives"]
