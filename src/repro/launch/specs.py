"""Input/state ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

No device allocation happens here: everything is eval_shape'd and paired
with shape-aware NamedShardings (repro/dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..dist.sharding import (
    CACHE_RULES,
    DEFAULT_RULES,
    spec_for_shape,
    tree_shardings,
    zero1_shardings,
)
from ..models.config import ArchConfig, RunConfig, ShapeConfig
from ..models.model import abstract_init, cache_axes, init_caches
from ..train.optim import TrainState

SDS = jax.ShapeDtypeStruct


def make_run_config(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> RunConfig:
    """Per-cell execution config: remat for training, int8 KV when a bf16
    cache would not fit HBM, chunked attention sized to the sequence."""
    kv_dtype = "bfloat16"
    if shape.kind == "decode":
        # estimate bf16 KV bytes/chip: batch over data axes, seq over model
        n_data = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_data *= mesh.shape[a]
        n_model = mesh.shape.get("model", 1)
        b_local = max(1, shape.global_batch // n_data)
        if cfg.mla:
            per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        else:
            per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        layers_full = sum(
            c for k, c in cfg.layout if not k.endswith("_w") and k != "ssd"
        )
        gb = b_local * (shape.seq_len / n_model) * per_tok * 2 * layers_full / 1e9
        if gb > 11.0:
            kv_dtype = "int8"
    return RunConfig(
        remat="block" if shape.kind == "train" else "none",
        attn_chunk_q=min(512, shape.seq_len),
        attn_chunk_k=min(1024, shape.seq_len),
        kv_cache_dtype=kv_dtype,
        zero1=True,
    )


@dataclass
class CellSpecs:
    kind: str  # train | prefill | decode
    args: tuple  # ShapeDtypeStruct pytrees, in call order
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...]
    run: RunConfig
    meta: dict


def _batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh, run: RunConfig,
                 decode: bool):
    B = shape.global_batch
    S = 1 if decode else shape.seq_len
    specs: dict[str, Any] = {}
    shard: dict[str, Any] = {}
    if cfg.embed_input == "tokens":
        specs["tokens"] = SDS((B, S), jnp.int32)
        shard["tokens"] = NamedSharding(
            mesh, spec_for_shape(("batch", "seq"), (B, S), mesh)
        )
    else:
        specs["frames"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        shard["frames"] = NamedSharding(
            mesh, spec_for_shape(("batch", "seq", "embed"), (B, S, cfg.d_model), mesh)
        )
    if decode:
        specs["pos"] = SDS((), jnp.int32)
        shard["pos"] = NamedSharding(mesh, P())
    else:
        specs["labels"] = SDS((B, S), jnp.int32)
        shard["labels"] = NamedSharding(
            mesh, spec_for_shape(("batch", "seq"), (B, S), mesh)
        )
    return specs, shard


def train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               run_overrides: dict | None = None) -> CellSpecs:
    run = make_run_config(cfg, shape, mesh)
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    pshapes, pspecs = abstract_init(cfg, run)
    state_shapes = TrainState(
        step=SDS((), jnp.int32),
        params=pshapes,
        m=pshapes,
        v=pshapes,
    )
    psh = (
        zero1_shardings(pspecs, pshapes, mesh)
        if run.zero1
        else tree_shardings(pspecs, pshapes, mesh)
    )
    state_sh = TrainState(
        step=NamedSharding(mesh, P()), params=psh, m=psh, v=psh
    )
    bspec, bshard = _batch_specs(cfg, shape, mesh, run, decode=False)
    metrics_sh = None  # let XLA pick
    return CellSpecs(
        kind="train",
        args=(state_shapes, bspec),
        in_shardings=(state_sh, bshard),
        out_shardings=(state_sh, metrics_sh),
        donate=(0,),
        run=run,
        meta={"tokens": shape.global_batch * shape.seq_len},
    )


def prefill_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                 run_overrides: dict | None = None) -> CellSpecs:
    run = make_run_config(cfg, shape, mesh)
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    pshapes, pspecs = abstract_init(cfg, run)
    psh = tree_shardings(pspecs, pshapes, mesh)
    bspec, bshard = _batch_specs(cfg, shape, mesh, run, decode=False)
    bspec.pop("labels", None)
    bshard.pop("labels", None)
    # out: (last-token logits, caches)
    cshape = jax.eval_shape(
        lambda: init_caches(cfg, run, shape.global_batch, shape.seq_len)
    )
    csh = tree_shardings(cache_axes(cfg, run), cshape, mesh, CACHE_RULES)
    return CellSpecs(
        kind="prefill",
        args=(pshapes, bspec),
        in_shardings=(psh, bshard),
        out_shardings=(None, csh),
        donate=(),
        run=run,
        meta={"tokens": shape.global_batch * shape.seq_len},
    )


def decode_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                run_overrides: dict | None = None) -> CellSpecs:
    run = make_run_config(cfg, shape, mesh)
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    pshapes, pspecs = abstract_init(cfg, run)
    psh = tree_shardings(pspecs, pshapes, mesh)
    cshape = jax.eval_shape(
        lambda: init_caches(cfg, run, shape.global_batch, shape.seq_len)
    )
    csh = tree_shardings(cache_axes(cfg, run), cshape, mesh, CACHE_RULES)
    bspec, bshard = _batch_specs(cfg, shape, mesh, run, decode=True)
    return CellSpecs(
        kind="decode",
        args=(pshapes, cshape, bspec),
        in_shardings=(psh, csh, bshard),
        out_shardings=(None, csh),
        donate=(1,),
        run=run,
        meta={"tokens": shape.global_batch},
    )


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               run_overrides: dict | None = None) -> CellSpecs:
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh, run_overrides)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh, run_overrides)
    return decode_cell(cfg, shape, mesh, run_overrides)


# ---------------------------------------------------------------------------
# model-FLOPs accounting (roofline's "useful compute")
# ---------------------------------------------------------------------------
def param_counts(cfg: ArchConfig, run: RunConfig) -> dict:
    import math

    pshapes, _ = abstract_init(cfg, run)
    total = sum(math.prod(int(d) for d in s.shape) for s in jax.tree.leaves(pshapes))
    active = total
    if cfg.moe:
        m = cfg.moe
        for gi, (kind, count) in enumerate(cfg.layout):
            if not kind.endswith("_moe"):
                continue
            g = pshapes[f"g{gi}"]["ffn"]
            routed = sum(
                math.prod(int(d) for d in g[k].shape) for k in ("wi", "wg", "wo")
            )
            active -= routed
            active += int(routed * m.top_k / m.n_experts)
    return {"total": total, "active": active}


def model_flops(cfg: ArchConfig, shape: ShapeConfig, run: RunConfig) -> float:
    """6 N_active D for training, 2 N_active D for inference forward."""
    counts = param_counts(cfg, run)
    n = counts["active"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence
