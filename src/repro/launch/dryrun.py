import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, extract memory/cost/collective analyses, write JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k --mesh both

The two env lines above MUST stay the first statements in this module: jax
locks the device count on first init. Smoke tests / benches import other
modules and keep their 1-device view.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs import ARCHS, SHAPES  # noqa: E402
from ..models.model import decode_step, prefill  # noqa: E402
from ..train.step import build_train_step  # noqa: E402
from .hlo import analyze  # noqa: E402
from .mesh import (  # noqa: E402
    DCI_BW,
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from .specs import build_cell, model_flops, param_counts  # noqa: E402

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


# ---------------------------------------------------------------------------
# variants (perf hillclimbing levers — EXPERIMENTS.md §Perf)
# each: optional RunConfig overrides + optional activation sharding rules
# ---------------------------------------------------------------------------
STREAM = {"attn_stream_bf16": True, "ssd_stream_bf16": True}
STREAM2 = dict(STREAM, norm_stats_only_f32=True, attn_chunk_q=2048,
               attn_chunk_k=2048)
VARIANTS: dict[str, dict] = {
    "baseline": {},
    "stream_bf16": {"run": STREAM},
    "sp": {"rules": "seq"},
    "sp_stream": {"run": STREAM, "rules": "seq"},
    "ep": {"run": {"moe_impl": "ep"}},
    "ep_stream": {"run": dict(STREAM, moe_impl="ep"), "rules": None},
    "ep_sp_stream": {"run": dict(STREAM, moe_impl="ep"), "rules": "seq"},
    "remat_none": {"run": {"remat": "none"}},
    "no_zero1": {"run": {"zero1": False}},
    "chunk256": {"run": {"attn_chunk_q": 256, "attn_chunk_k": 256}},
    "chunk2k": {"run": {"attn_chunk_q": 2048, "attn_chunk_k": 2048}},
    "stream_chunk2k": {
        "run": dict(STREAM, attn_chunk_q=2048, attn_chunk_k=2048)
    },
    "ep_stream_chunk2k": {
        "run": dict(STREAM, moe_impl="ep", attn_chunk_q=2048, attn_chunk_k=2048)
    },
    "stream2": {"run": STREAM2},
    "ssd128": {"run": {"ssd_chunk": 128}},
    "ssd64": {"run": {"ssd_chunk": 64}},
    "ssd128_stream": {"run": dict(STREAM, ssd_chunk=128)},
    "ep_stream2": {"run": dict(STREAM2, moe_impl="ep")},
}


def run_cell(arch: str, shape_name: str, mesh_name: str, variant: str = "baseline"):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    spec = VARIANTS[variant]
    cell = build_cell(cfg, shape, mesh, run_overrides=spec.get("run"))
    run = cell.run
    rules = None
    if spec.get("rules") == "seq":
        from ..dist.sharding import SEQ_RULES

        rules = SEQ_RULES

    if cell.kind == "train":
        fn = build_train_step(cfg, run)
    elif cell.kind == "prefill":
        fn = lambda params, batch: prefill(params, batch, cfg, run)
    else:
        fn = lambda params, caches, batch: decode_step(params, caches, batch, cfg, run)

    from ..shardctx import clear_ctx, set_ctx

    set_ctx(mesh, rules)
    t0 = time.monotonic()
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
    clear_ctx()

    mem = compiled.memory_analysis()
    mem_d = {
        k: int(getattr(mem, k))
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        )
        if hasattr(mem, k)
    }
    # trip-count-aware analysis over the optimized HLO (repro/launch/hlo.py)
    # — compiled.cost_analysis() counts scan bodies once and has no
    # collective term, so it is recorded only as a cross-reference.
    hlo = analyze(compiled.as_text())
    flops = float(hlo["flops"])
    bytes_accessed = float(hlo["bytes"])
    coll = hlo["collectives"]
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # older jax returns [dict]
        xla_cost = xla_cost[0] if xla_cost else {}

    n_chips = mesh.size
    mf = model_flops(cfg, shape, run)
    counts = param_counts(cfg, run)
    compute_term = flops / PEAK_FLOPS_BF16
    memory_term = bytes_accessed / HBM_BW
    collective_term = coll.get("total", 0.0) / ICI_BW_PER_LINK
    terms = {
        "compute_s": compute_term,
        "memory_s": memory_term,
        "collective_s": collective_term,
    }
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant,
        "kind": cell.kind,
        "n_chips": n_chips,
        "kv_cache_dtype": run.kv_cache_dtype,
        "remat": run.remat,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "xla_cost_flops_unscaled": float(xla_cost.get("flops", 0.0)),
        "collectives_per_chip": coll,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else 0.0,
        "roofline": dict(terms, dominant=dominant),
        "step_time_lower_bound_s": max(terms.values()),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            if shape_name == "long_500k" and not ARCHS[arch].sub_quadratic:
                print(f"SKIP {arch} x long_500k (full attention; DESIGN.md)")
                continue
            for mesh_name in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}__{args.variant}"
                out_file = outdir / f"{tag}.json"
                if out_file.exists() and not args.force:
                    print(f"cached {tag}")
                    continue
                print(f"=== {tag}")
                try:
                    res = run_cell(arch, shape_name, mesh_name, args.variant)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((tag, str(e)))
                    continue
                out_file.write_text(json.dumps(res, indent=1))
                r = res["roofline"]
                print(
                    f"  ok: compile {res['compile_s']}s  "
                    f"flops/chip {res['hlo_flops_per_chip']:.3g}  "
                    f"terms c/m/x = {r['compute_s']:.4f}/{r['memory_s']:.4f}/"
                    f"{r['collective_s']:.4f}s  dominant={r['dominant']}  "
                    f"useful={res['useful_flops_ratio']:.2f}"
                )
                jax.clear_caches()
    if failures:
        print("FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
