"""Serving CLI: batched requests against a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 16 --max-tokens 16
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_arch
from ..models import RunConfig, model_init
from ..serve import BatchServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    run = RunConfig(remat="none", attn_chunk_q=64, attn_chunk_k=64, vocab_round=64)
    params, _ = model_init(jax.random.PRNGKey(0), cfg, run)
    server = BatchServer(params, cfg, run, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = rng.integers(4, args.prompt_len + 1)
        server.submit(
            Request(rid, rng.integers(0, cfg.vocab, plen), args.max_tokens)
        )
    done = 0
    while done < args.requests:
        for resp in server.serve_once():
            done += 1
            print(f"req {resp.rid}: {len(resp.tokens)} tokens, "
                  f"{resp.latency_s*1e3:.0f} ms")
    s = server.stats
    print(f"served {s['requests']} requests / {s['batches']} batches / "
          f"{s['tokens']} tokens")


if __name__ == "__main__":
    main()
