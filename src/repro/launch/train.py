"""Training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU cluster this entry point runs under one process per host
(jax.distributed.initialize), the mesh comes from launch.mesh, and the
sharding rules from repro.dist. On CPU it trains smoke-scale configs.
"""
from __future__ import annotations

import argparse

from ..configs import get_arch
from ..models import RunConfig
from ..train import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    run = RunConfig(
        remat="none",
        attn_chunk_q=min(512, args.seq),
        attn_chunk_k=min(1024, args.seq),
        learning_rate=args.lr,
        vocab_round=64 if args.smoke else 128,
    )
    res = train(
        cfg,
        run,
        LoopConfig(
            steps=args.steps,
            batch=args.batch,
            seq=args.seq,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            seed=args.seed,
            accum=args.accum,
        ),
    )
    print(
        f"done: {res.final_step} steps, loss {res.losses[0]:.3f} -> "
        f"{res.losses[-1]:.3f}, wall {res.wall_s:.1f}s, "
        f"resumed_from={res.resumed_from}, stragglers={len(res.straggler_steps)}"
    )


if __name__ == "__main__":
    main()
