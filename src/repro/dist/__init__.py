"""Distribution layer: collective scheduling on accelerator interconnects.

``repro.dist.multicast`` turns the paper's DPM partitioning into a
round-based ppermute scheduler for torus/ring collectives (DESIGN.md §3).

Other submodules referenced by the launch layer (``sharding``, ``ep``,
``pipeline``, ``compress``) are planned and land in later PRs.
"""
from .multicast import (
    Schedule,
    Torus,
    apply_schedule,
    dp_broadcast_schedule,
    plan_torus_multicast,
    schedule_multicasts,
)

__all__ = [
    "Schedule",
    "Torus",
    "apply_schedule",
    "dp_broadcast_schedule",
    "plan_torus_multicast",
    "schedule_multicasts",
]
