"""Distribution layer: collective scheduling on accelerator interconnects.

``repro.dist.multicast`` turns the paper's DPM partitioning into a
round-based ppermute scheduler for torus/ring collectives (DESIGN.md §3);
the remaining submodules are the model-side consumers (DESIGN.md §4):

* ``sharding``  — logical-axis -> mesh-axis rule tables and the
  spec/tree/param/ZeRO-1 sharding builders the launch layer compiles with;
* ``ep``        — shard_map expert-parallel MoE whose all-to-all dispatch
  and combine ride DPM-planned ppermute rounds;
* ``pipeline``  — GPipe microbatch pipeline over a ``pipe`` mesh axis with
  ppermute stage handoffs;
* ``compress``  — int8 reduce-scatter + all-gather gradient all-reduce
  with error feedback.
"""
from .compress import compressed_psum
from .ep import moe_apply_ep
from .multicast import (
    Schedule,
    Torus,
    alltoall_schedule,
    apply_alltoall_schedule,
    apply_schedule,
    dp_broadcast_schedule,
    plan_torus_multicast,
    ring_alltoall_schedule,
    ring_broadcast_schedule,
    schedule_multicasts,
)
from .pipeline import pipeline_apply
from .sharding import (
    CACHE_RULES,
    DEFAULT_RULES,
    SEQ_RULES,
    param_shardings,
    spec_for_shape,
    tree_shardings,
    zero1_shardings,
)

__all__ = [
    "CACHE_RULES",
    "DEFAULT_RULES",
    "SEQ_RULES",
    "Schedule",
    "Torus",
    "alltoall_schedule",
    "apply_alltoall_schedule",
    "apply_schedule",
    "compressed_psum",
    "dp_broadcast_schedule",
    "moe_apply_ep",
    "param_shardings",
    "pipeline_apply",
    "plan_torus_multicast",
    "ring_alltoall_schedule",
    "ring_broadcast_schedule",
    "schedule_multicasts",
    "spec_for_shape",
    "tree_shardings",
    "zero1_shardings",
]
