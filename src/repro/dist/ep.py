"""Expert-parallel MoE over shard_map, dispatched through DPM schedules.

``moe_apply_ep`` is the explicit-collective twin of
``repro.models.moe.moe_apply_dense``: experts shard over the ``model``
mesh axis, tokens over ``(data..., model)``, and the dispatch/combine
exchange runs as the ppermute rounds of ``repro.dist.multicast.
alltoall_schedule`` — DPM partition merging plans every (src, dst) token
chunk's route on the rank ring, instead of a bare ``lax.all_to_all``
(DESIGN.md §4).

Numerics: routing, dispatch ranking, and the per-row expert SwiGLU reuse
the dense path's helpers, so with a no-drop capacity factor the EP output
equals the dense output modulo f32 reduction order (tests/dist_checks.py
pins 2e-5).  The aux load-balance loss is the pmean of the per-shard
losses — an unbiased estimate of the dense aux, not bit-equal.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..models.config import ArchConfig, MoEConfig
from ..models.moe import (
    capacity,
    dispatch_indices,
    expert_ffn,
    moe_apply_dense,
    route,
)
from .multicast import alltoall_schedule, apply_alltoall_schedule

EP_AXIS = "model"
_EXPERT_LEAVES = ("wi", "wg", "wo")


def _param_specs(p) -> dict:
    """shard_map in_specs for the MoE param dict: stacked expert weights
    shard their leading experts axis over the EP axis, the router and
    shared experts replicate."""
    return {
        k: (P(EP_AXIS) if k in _EXPERT_LEAVES else jax.tree.map(lambda _: P(), v))
        for k, v in p.items()
    }


def moe_apply_ep(
    p,
    x: jax.Array,
    cfg: ArchConfig,
    mesh,
    data_axes: tuple[str, ...] | None = None,
    algo: str = "DPM",
):
    """Expert-parallel MoE FFN.  x: (B, S, d) -> (y, aux_loss).

    Tokens flatten to (T, d) and shard over ``(*data_axes, EP_AXIS)``;
    each shard routes its tokens locally, packs one (E_loc, cap, d) chunk
    per expert shard, and the chunks ride the DPM all-to-all schedule out
    and back.  Falls back to the dense path when the mesh or shapes don't
    divide (single EP rank, ragged experts or tokens).
    """
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    if data_axes is None:
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    sizes = dict(mesh.shape)
    n_ep = sizes.get(EP_AXIS, 1)
    n_data = math.prod(sizes[a] for a in data_axes) if data_axes else 1
    T = B * S
    if n_ep <= 1 or m.n_experts % n_ep or T % (n_data * n_ep):
        return moe_apply_dense(p, x, cfg)

    e_loc = m.n_experts // n_ep
    t_loc = T // (n_data * n_ep)
    cap = capacity(m, t_loc)
    sched = alltoall_schedule(n_ep, algo)
    tok_spec = P((*data_axes, EP_AXIS))
    mesh_axes = (*data_axes, EP_AXIS)

    def local(p_l, xt):
        # xt: (t_loc, d) local tokens; expert leaves of p_l: (e_loc, ...)
        ids, w, aux = route(p_l, xt, m)
        slot, keep = dispatch_indices(ids, m, cap)
        xt_rep = jnp.repeat(xt, m.top_k, axis=0)
        buf = jnp.zeros((m.n_experts * cap, d), xt.dtype)
        buf = buf.at[slot].add(jnp.where(keep[:, None], xt_rep, 0))
        # dispatch: chunk j goes to expert shard j over the DPM schedule
        chunks = buf.reshape(n_ep, e_loc * cap, d)
        recv = apply_alltoall_schedule(chunks, sched, EP_AXIS)
        xe = (
            recv.reshape(n_ep, e_loc, cap, d)
            .transpose(1, 0, 2, 3)
            .reshape(e_loc, n_ep * cap, d)
        )
        ye = expert_ffn({k: p_l[k] for k in _EXPERT_LEAVES}, xe)
        # combine: same schedule back (all-to-all is its own inverse here)
        back = (
            ye.reshape(e_loc, n_ep, cap, d)
            .transpose(1, 0, 2, 3)
            .reshape(n_ep, e_loc * cap, d)
        )
        outb = apply_alltoall_schedule(back, sched, EP_AXIS)
        gathered = outb.reshape(m.n_experts * cap, d)[slot]
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = (
            gathered.reshape(t_loc, m.top_k, d) * w[..., None].astype(xt.dtype)
        ).sum(1)
        if m.n_shared:
            h = xt @ p_l["shared_wi"].astype(xt.dtype)
            g = xt @ p_l["shared_wg"].astype(xt.dtype)
            y = y + (jax.nn.silu(g) * h) @ p_l["shared_wo"].astype(xt.dtype)
        return y, jax.lax.pmean(aux, mesh_axes)

    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(_param_specs(p), tok_spec),
        out_specs=(tok_spec, P()),
        check_rep=False,
    )(p, x.reshape(T, d))
    return y.reshape(B, S, d), aux
