"""Compressed gradient all-reduce: int8 reduce-scatter + all-gather with
error feedback.

``compressed_psum`` replaces a ``lax.psum`` of large f32 gradients with
two int8 exchange stages, cutting collective bytes ~4x:

1. the error-compensated gradient (``g + err``) splits into one chunk per
   rank, each quantized to int8 with a per-chunk f32 scale; chunks
   exchange (reduce-scatter) and every rank dequantizes and accumulates
   its owned chunk in f32;
2. the reduced chunk re-quantizes once and all-gathers back.

The local quantization residual from stage 1 is returned as the new
error-feedback state — carrying it into the next call makes the
compression error *accumulate-free* (1-bit/int8 SGD style) instead of
biasing the trajectory.  RunConfig.grad_compress="int8" is the launch-
layer knob that selects this path for DP gradient reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Rowwise symmetric int8: returns (q int8, scale f32 keepdims)."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(
    g: jax.Array, err: jax.Array, axis_name: str
) -> tuple[jax.Array, jax.Array]:
    """int8 RS+AG all-reduce of ``g`` over ``axis_name`` with error
    feedback state ``err`` (same shape as ``g``; start with zeros).

    Returns ``(sum_approx, new_err)`` where ``sum_approx ~= lax.psum(g)``
    and ``new_err`` is this rank's stage-1 quantization residual to feed
    into the next call.  Must run inside shard_map over ``axis_name``.
    """
    n = jax.lax.psum(1, axis_name)
    flat = (g + err).astype(jnp.float32).reshape(-1)
    length = flat.shape[0]
    pad = (-length) % n
    v = jnp.pad(flat, (0, pad))
    chunks = v.reshape(n, v.shape[0] // n)  # chunk j is owned by rank j

    q, scale = _quantize_int8(chunks)
    dq = q.astype(jnp.float32) * scale
    new_err = (v - dq.reshape(-1))[:length].reshape(g.shape).astype(g.dtype)

    # reduce-scatter: every rank collects the int8 chunks addressed to it
    # (one per peer), dequantizes with the matching scales, sums in f32
    qt = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0)
    st = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0)
    owned = jnp.sum(qt.astype(jnp.float32) * st, axis=0)

    # all-gather the re-quantized reduced chunks
    q2, s2 = _quantize_int8(owned[None])
    allq = jax.lax.all_gather(q2[0], axis_name)
    alls = jax.lax.all_gather(s2[0, 0], axis_name)
    total = (allq.astype(jnp.float32) * alls[:, None]).reshape(-1)[:length]
    return total.reshape(g.shape).astype(g.dtype), new_err
