"""Multicast scheduling on the accelerator torus (DESIGN.md §3).

The paper's DPM is a NoC routing optimization; this module lifts it one
level up: given a batch of concurrent multicast requests on a wraparound
torus (a TPU-pod ICI, or a 1-D rank ring for a data-parallel axis), produce
a round-based store-and-forward schedule in which every round is a partial
permutation — directly realizable as one ``jax.lax.ppermute`` per round.

Pipeline:

1. plan each request with any ``repro.core`` planner (default DPM) on the
   torus geometry;
2. decompose each wormhole packet path into *relay edges* ``holder ->
   next delivery`` — the path-order chain of a path-based multicast, with
   DPM's MU-mode children chained behind the representative's delivery;
3. greedily pack ready edges (sender already holds the payload) into rounds
   under ppermute's unique-sender / unique-receiver constraint.

``apply_schedule`` executes a schedule on a shard_map-local array;
``dp_broadcast_schedule`` specializes to a 1-D rank ring, which is how the
launch layer broadcasts parameters along a data axis. ``Schedule.cost``
prices a schedule with an alpha-beta-hop model for benchmark comparisons.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch_planner import bulk_plan
from ..core.grid import Coord
from ..core.planner import MulticastPlan, plan
from ..core.routefn import faulty
from ..core.topology import Topology, Torus, torus  # Torus re-exported (dist)

# Alpha-beta-hop calibration constants for Schedule.cost: per-round software/
# launch latency, per-hop fall-through, per-link bandwidth. Absolute values
# are ICI-ballpark; benchmarks compare algorithms *relatively*, exactly as
# the NoC EnergyModel does for power.
ALPHA_US = 1.0
HOP_US = 0.3
LINK_GBPS = 45.0


@dataclass
class Schedule:
    """Round-based store-and-forward multicast schedule.

    ``rounds[r]`` is a list of ``(sender_rank, receiver_rank)`` pairs and
    ``hops[r]`` the matching hop distances along the planned paths. Each
    round has unique senders and unique receivers, so it maps 1:1 onto a
    ``jax.lax.ppermute``; a sender only ever forwards a payload delivered to
    it in an earlier round (store-and-forward causality, per request).
    ``round_reqs[r]`` attributes each transfer to its request index.
    """

    num_ranks: int
    rounds: list[list[tuple[int, int]]]
    hops: list[list[int]] = field(default_factory=list)
    round_reqs: list[list[int]] = field(default_factory=list)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def total_hops(self) -> int:
        return sum(sum(h) for h in self.hops)

    def cost(
        self,
        payload_bytes: int,
        alpha_us: float = ALPHA_US,
        hop_us: float = HOP_US,
        link_gbps: float = LINK_GBPS,
        req_payload_bytes: dict[int, int] | None = None,
    ) -> dict:
        """Alpha-beta-hop price: per round one collective launch (alpha),
        payload serialization at link bandwidth, and the longest transfer's
        fall-through latency; ``link_bytes`` is total payload-hops moved.

        ``req_payload_bytes`` maps request index -> per-transfer bytes for
        schedules whose requests carry different payloads (an expert-
        parallel all-to-all moves one chunk per (src, dst) pair, not the
        full buffer); round serialization is then the round's largest
        transfer and unmapped requests fall back to ``payload_bytes``.
        """
        time_us = 0.0
        link_bytes = 0.0
        reqs = self.round_reqs or [[] for _ in self.hops]
        for rh, rr in zip(self.hops, reqs):
            if req_payload_bytes is None or len(rr) != len(rh):
                # no (usable) request attribution: uniform payload per
                # transfer, so a missing round_reqs can't drop transfers
                sizes = [payload_bytes] * len(rh)
            else:
                sizes = [req_payload_bytes.get(r, payload_bytes) for r in rr]
            ser_us = max(sizes, default=payload_bytes) / (link_gbps * 1e3)
            time_us += alpha_us + ser_us + hop_us * max(rh, default=0)
            link_bytes += sum(b * h for b, h in zip(sizes, rh))
        return {
            "rounds": self.num_rounds,
            "time_us": time_us,
            "link_bytes": link_bytes,
        }


def _relay_edges(p: MulticastPlan) -> list[tuple[Coord, Coord, int]]:
    """Decompose a plan into (holder, receiver, hops-along-path) edges.

    A path-based multicast delivers in path order, so each delivery can be
    served by the previous delivery point (or the injection node) relaying
    the payload — the store-and-forward rendering of one wormhole worm.
    Child paths start where their parent's header released them: at a
    *delivery* for DPM MU-mode re-injections, or at a transit boundary for
    the degraded-topology monotone segments (core.planner
    ``segment_plan_for_faults``). A transit boundary does not logically
    hold the payload at the collectives level, so each path's first edge
    is anchored at the nearest *delivered* point (or the root injection
    node) walking back through the ancestor chain, with hop counts
    accumulated along the way — segmentation leaves the edge set of the
    unsegmented plan unchanged.
    """
    edges: list[tuple[Coord, Coord, int]] = []

    def _entry(i: int) -> tuple[Coord, int]:
        """(nearest holder at/before path i's injection, hops back to it)."""
        node, back = p.paths[i].hops[0], 0
        j = p.paths[i].parent
        while j is not None:
            par = p.paths[j]
            pos = par.hops.index(node, 1)
            best = None  # latest delivery of par at/before pos
            for d in par.deliveries:
                dpos = par.hops.index(d, 1)
                if dpos <= pos and (best is None or dpos > best[1]):
                    best = (d, dpos)
            if best is not None:
                return best[0], back + (pos - best[1])
            back += pos
            node, j = par.hops[0], par.parent
        return node, back

    for i, path in enumerate(p.paths):
        if not path.deliveries:
            continue  # pure transit segment: no absorption to serve
        holder, back = _entry(i)
        hpos = 0
        for d in path.deliveries:
            pos = next(
                k for k in range(hpos, len(path.hops)) if path.hops[k] == d
            )
            if d != holder:
                edges.append((holder, d, pos - hpos + back))
            holder, hpos, back = d, pos, 0
    return edges


def plan_torus_multicast(
    t: Topology,
    src: Coord,
    dests: list[Coord],
    algo="DPM",
    cost_model=None,
    broken_links: tuple = (),
) -> MulticastPlan:
    """DPM partitioning (Algorithm 1) reused on interconnect geometry.

    ``t`` is any registered topology: a 2-D wraparound torus (the name's
    origin), a 3-D ``torus3d`` (a TPU-pod ICI is a 3-D torus — wedge
    partitions become the 26 sign patterns), or a ``chiplet`` package
    (multi-die ICI with interposer crossings priced by ``link_weight``).

    ``algo`` resolves through the routing-algorithm registry (name or
    ``RoutingAlgorithm`` instance; unknown names raise listing what is
    registered) and ``cost_model`` optionally overrides the objective.
    ``broken_links`` degrades the topology (``core.routefn.faulty``): plans
    then detour around the broken ICI links — the failed-link collective
    case — and an unreachable rank raises ``DisconnectedError``.
    Returns the same MulticastPlan structure the NoC simulator consumes;
    paths take shortest wraparound legs and partitions are the torus wedges.
    """
    if broken_links:
        t = faulty(t, tuple(broken_links))
    return plan(algo, t, src, list(dests), cost_model=cost_model)


def schedule_multicasts(
    topo: Topology,
    requests: list[tuple[Coord, list[Coord]]],
    algo="DPM",
    cost_model=None,
    broken_links: tuple = (),
) -> Schedule:
    """Schedule a batch of concurrent multicasts as ppermute rounds.

    ``topo`` is any registered topology (2-D/3-D torus, mesh, chiplet
    package — ranks are ``topo.idx`` order). ``requests`` is a list of
    ``(src, dests)`` coordinate pairs on ``topo``;
    each is planned by any registered routing algorithm under ``cost_model``.
    ``broken_links`` (or passing an already-degraded ``FaultyTopology``)
    schedules on the degraded fabric: relay edges follow the detoured
    provider routes, so their hop counts — and ``Schedule.cost`` — price the
    fault set, while the round structure stays a valid set of ppermutes
    (rank-to-rank sends are link-agnostic at the collectives level).
    Payload identity is per-request: a node forwards request r only after an
    earlier round delivered r to it. Rounds are packed greedily in plan
    order, one send and one receive per rank per round.
    """
    if broken_links:
        topo = faulty(topo, tuple(broken_links))
    have: list[set[int]] = []
    pend: list[tuple[int, int, int, int]] = []  # (req, sender, receiver, hops)
    # bulk-plan the request batch through the shared plan arena (one device
    # dispatch for all arena misses on supported fabrics; bit-identical to
    # the per-request plan_torus_multicast calls it replaces)
    plans = bulk_plan(
        topo, [(src, dests) for src, dests in requests], algo,
        cost_model=cost_model,
    )
    for rid, ((src, dests), p) in enumerate(zip(requests, plans)):
        src_i = topo.idx(src)
        have.append({src_i})
        targeted: set[int] = set()
        for s, d, h in _relay_edges(p):
            si, di = topo.idx(s), topo.idx(d)
            if di in targeted or di == src_i:
                continue  # already served by an earlier edge of this request
            targeted.add(di)
            pend.append((rid, si, di, h))

    rounds: list[list[tuple[int, int]]] = []
    hops: list[list[int]] = []
    round_reqs: list[list[int]] = []
    while pend:
        used_s: set[int] = set()
        used_d: set[int] = set()
        rnd: list[tuple[int, int]] = []
        rh: list[int] = []
        rr: list[int] = []
        nxt: list[tuple[int, int, int, int]] = []
        for e in pend:
            rid, s, d, h = e
            if s in have[rid] and s not in used_s and d not in used_d:
                used_s.add(s)
                used_d.add(d)
                rnd.append((s, d))
                rh.append(h)
                rr.append(rid)
            else:
                nxt.append(e)
        if not rnd:  # cannot happen: every chain is rooted at a source
            raise RuntimeError("multicast schedule stalled")
        for rid, (_, d) in zip(rr, rnd):
            have[rid].add(d)
        rounds.append(rnd)
        hops.append(rh)
        round_reqs.append(rr)
        pend = nxt
    return Schedule(topo.num_nodes, rounds, hops, round_reqs)


def dp_broadcast_schedule(num_ranks: int, algo="DPM", cost_model=None) -> Schedule:
    """Broadcast rank 0 -> all ranks on a 1-D ring (a data-parallel axis).

    The ring is ``Torus(num_ranks, 1)``; with DPM the destination set splits
    into the two ring directions and each side is a relay chain, roughly
    halving the rounds of MU's one-send-per-round direct scheme.
    """
    ring = torus(num_ranks, 1)
    dests = [(i, 0) for i in range(1, num_ranks)]
    return schedule_multicasts(ring, [((0, 0), dests)], algo, cost_model)


def ring_broadcast_schedule(num_ranks: int) -> Schedule:
    """Baseline store-and-forward ring broadcast: rank 0's payload relays
    neighbor-to-neighbor, one 1-hop transfer per round, ``n - 1`` rounds."""
    rounds = [[(i, i + 1)] for i in range(num_ranks - 1)]
    hops = [[1] for _ in range(num_ranks - 1)]
    reqs = [[0] for _ in range(num_ranks - 1)]
    return Schedule(num_ranks, rounds, hops, reqs)


def _a2a_req(num_ranks: int, rid: int) -> tuple[int, int]:
    """Request index -> (src, dst) for the all-to-all request ordering."""
    src, k = divmod(rid, num_ranks - 1)
    dst = k if k < src else k + 1
    return src, dst


def a2a_req_id(num_ranks: int, src: int, dst: int) -> int:
    """(src, dst) -> request index (inverse of ``_a2a_req``)."""
    return src * (num_ranks - 1) + (dst if dst < src else dst - 1)


@functools.lru_cache(maxsize=None)
def alltoall_schedule(num_ranks: int, algo: str = "DPM") -> Schedule:
    """All-to-all on a 1-D ring as registry-planned ppermute rounds.

    Each of the ``n(n-1)`` (src, dst) chunks is its own unicast request (a
    chunk is a *distinct* payload, so relay chains cannot serve it); the
    planner contributes the wraparound shortest-path hop counts and the
    greedy packer fills rounds under the ppermute constraint.  Request
    indices follow ``a2a_req_id`` so executors can recover (src, dst).

    Every transfer is asserted to originate at its request's source —
    the property ``repro.dist.ep`` relies on to ship each chunk directly.
    """
    ring = torus(num_ranks, 1)
    requests = [
        ((src, 0), [(dst, 0)])
        for rid in range(num_ranks * (num_ranks - 1))
        for src, dst in [_a2a_req(num_ranks, rid)]
    ]
    sched = schedule_multicasts(ring, requests, algo)
    for rnd, rr in zip(sched.rounds, sched.round_reqs):
        for (s, d), rid in zip(rnd, rr):
            src, dst = _a2a_req(num_ranks, rid)
            assert (s, d) == (src, dst), (s, d, src, dst)
    return sched


def ring_alltoall_schedule(num_ranks: int) -> Schedule:
    """Baseline shift all-to-all: round ``r`` is the +r rotation, every
    transfer walking the full ``r`` hops one way around the ring (no
    wraparound shortcut — the classic ring-shift collective)."""
    rounds, hops, reqs = [], [], []
    for r in range(1, num_ranks):
        rounds.append([(i, (i + r) % num_ranks) for i in range(num_ranks)])
        hops.append([r] * num_ranks)
        reqs.append(
            [a2a_req_id(num_ranks, i, (i + r) % num_ranks) for i in range(num_ranks)]
        )
    return Schedule(num_ranks, rounds, hops, reqs)


def apply_schedule(x: jax.Array, sched: Schedule, axis_name: str) -> jax.Array:
    """Execute a Schedule on a shard_map-local array: one ppermute per
    round; receivers adopt the incoming payload, all other ranks keep
    theirs. Only meaningful for single-request (broadcast-like) schedules,
    where every transfer carries the same logical payload."""
    idx = jax.lax.axis_index(axis_name)
    for rnd in sched.rounds:
        y = jax.lax.ppermute(x, axis_name, perm=list(rnd))
        recv = jnp.zeros((), dtype=bool)
        for _, d in rnd:
            recv = recv | (idx == d)
        x = jnp.where(recv, y, x)
    return x


def apply_alltoall_schedule(
    chunks: jax.Array, sched: Schedule, axis_name: str
) -> jax.Array:
    """Execute an ``alltoall_schedule`` on shard_map-local chunks.

    ``chunks[j]`` is this rank's payload for rank ``j``; the result's row
    ``i`` is the chunk rank ``i`` addressed to this rank.  Each round maps
    to one ``jax.lax.ppermute``: senders select the chunk for their round
    receiver, receivers store the incoming chunk under the sender's slot
    (the schedule guarantees direct src->dst transfers, so a sender always
    holds what it sends).
    """
    n = sched.num_ranks
    assert chunks.shape[0] == n, (chunks.shape, n)
    idx = jax.lax.axis_index(axis_name)
    slots = jnp.arange(n)
    out = jnp.where(
        (slots == idx).reshape((n,) + (1,) * (chunks.ndim - 1)), chunks, 0
    )
    for rnd in sched.rounds:
        send_to = np.zeros(n, np.int32)  # chunk index each sender ships
        recv_from = np.zeros(n, np.int32)  # slot each receiver stores into
        is_recv = np.zeros(n, bool)
        for s, d in rnd:
            send_to[s] = d
            recv_from[d] = s
            is_recv[d] = True
        payload = jnp.take(chunks, jnp.asarray(send_to)[idx], axis=0)
        y = jax.lax.ppermute(payload, axis_name, perm=list(rnd))
        store = (slots == jnp.asarray(recv_from)[idx]) & jnp.asarray(is_recv)[idx]
        out = jnp.where(
            store.reshape((n,) + (1,) * (chunks.ndim - 1)), y[None], out
        )
    return out
