"""Multicast scheduling on the accelerator torus (DESIGN.md §3).

The paper's DPM is a NoC routing optimization; this module lifts it one
level up: given a batch of concurrent multicast requests on a wraparound
torus (a TPU-pod ICI, or a 1-D rank ring for a data-parallel axis), produce
a round-based store-and-forward schedule in which every round is a partial
permutation — directly realizable as one ``jax.lax.ppermute`` per round.

Pipeline:

1. plan each request with any ``repro.core`` planner (default DPM) on the
   torus geometry;
2. decompose each wormhole packet path into *relay edges* ``holder ->
   next delivery`` — the path-order chain of a path-based multicast, with
   DPM's MU-mode children chained behind the representative's delivery;
3. greedily pack ready edges (sender already holds the payload) into rounds
   under ppermute's unique-sender / unique-receiver constraint.

``apply_schedule`` executes a schedule on a shard_map-local array;
``dp_broadcast_schedule`` specializes to a 1-D rank ring, which is how the
launch layer broadcasts parameters along a data axis. ``Schedule.cost``
prices a schedule with an alpha-beta-hop model for benchmark comparisons.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..core.grid import Coord
from ..core.planner import MulticastPlan, plan
from ..core.topology import Torus, make_topology, torus

# Alpha-beta-hop calibration constants for Schedule.cost: per-round software/
# launch latency, per-hop fall-through, per-link bandwidth. Absolute values
# are ICI-ballpark; benchmarks compare algorithms *relatively*, exactly as
# the NoC EnergyModel does for power.
ALPHA_US = 1.0
HOP_US = 0.3
LINK_GBPS = 45.0


@dataclass
class Schedule:
    """Round-based store-and-forward multicast schedule.

    ``rounds[r]`` is a list of ``(sender_rank, receiver_rank)`` pairs and
    ``hops[r]`` the matching hop distances along the planned paths. Each
    round has unique senders and unique receivers, so it maps 1:1 onto a
    ``jax.lax.ppermute``; a sender only ever forwards a payload delivered to
    it in an earlier round (store-and-forward causality, per request).
    ``round_reqs[r]`` attributes each transfer to its request index.
    """

    num_ranks: int
    rounds: list[list[tuple[int, int]]]
    hops: list[list[int]] = field(default_factory=list)
    round_reqs: list[list[int]] = field(default_factory=list)

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    @property
    def total_hops(self) -> int:
        return sum(sum(h) for h in self.hops)

    def cost(
        self,
        payload_bytes: int,
        alpha_us: float = ALPHA_US,
        hop_us: float = HOP_US,
        link_gbps: float = LINK_GBPS,
    ) -> dict:
        """Alpha-beta-hop price: per round one collective launch (alpha),
        payload serialization at link bandwidth, and the longest transfer's
        fall-through latency; ``link_bytes`` is total payload-hops moved."""
        time_us = 0.0
        for rh in self.hops:
            ser_us = payload_bytes / (link_gbps * 1e3)
            time_us += alpha_us + ser_us + hop_us * max(rh, default=0)
        return {
            "rounds": self.num_rounds,
            "time_us": time_us,
            "link_bytes": payload_bytes * self.total_hops,
        }


def _relay_edges(p: MulticastPlan) -> list[tuple[Coord, Coord, int]]:
    """Decompose a plan into (holder, receiver, hops-along-path) edges.

    A path-based multicast delivers in path order, so each delivery can be
    served by the previous delivery point (or the injection node) relaying
    the payload — the store-and-forward rendering of one wormhole worm.
    Child paths (DPM MU-mode re-injection) start at the representative,
    which the parent path has already delivered to.
    """
    edges: list[tuple[Coord, Coord, int]] = []
    for path in p.paths:
        holder, hpos = path.hops[0], 0
        for d in path.deliveries:
            pos = next(
                i for i in range(hpos, len(path.hops)) if path.hops[i] == d
            )
            if d != holder:
                edges.append((holder, d, pos - hpos))
            holder, hpos = d, pos
    return edges


def plan_torus_multicast(
    t: Torus, src: Coord, dests: list[Coord], algo: str = "DPM"
) -> MulticastPlan:
    """DPM partitioning (Algorithm 1) reused on torus geometry.

    Returns the same MulticastPlan structure the NoC simulator consumes;
    paths take shortest wraparound legs and partitions are the torus wedges.
    """
    return plan(algo, t, src, list(dests))


def schedule_multicasts(
    topo: Torus, requests: list[tuple[Coord, list[Coord]]], algo: str = "DPM"
) -> Schedule:
    """Schedule a batch of concurrent multicasts as ppermute rounds.

    ``requests`` is a list of ``(src, dests)`` coordinate pairs on ``topo``.
    Payload identity is per-request: a node forwards request r only after an
    earlier round delivered r to it. Rounds are packed greedily in plan
    order, one send and one receive per rank per round.
    """
    have: list[set[int]] = []
    pend: list[tuple[int, int, int, int]] = []  # (req, sender, receiver, hops)
    for rid, (src, dests) in enumerate(requests):
        p = plan_torus_multicast(topo, src, dests, algo)
        src_i = topo.idx(src)
        have.append({src_i})
        targeted: set[int] = set()
        for s, d, h in _relay_edges(p):
            si, di = topo.idx(s), topo.idx(d)
            if di in targeted or di == src_i:
                continue  # already served by an earlier edge of this request
            targeted.add(di)
            pend.append((rid, si, di, h))

    rounds: list[list[tuple[int, int]]] = []
    hops: list[list[int]] = []
    round_reqs: list[list[int]] = []
    while pend:
        used_s: set[int] = set()
        used_d: set[int] = set()
        rnd: list[tuple[int, int]] = []
        rh: list[int] = []
        rr: list[int] = []
        nxt: list[tuple[int, int, int, int]] = []
        for e in pend:
            rid, s, d, h = e
            if s in have[rid] and s not in used_s and d not in used_d:
                used_s.add(s)
                used_d.add(d)
                rnd.append((s, d))
                rh.append(h)
                rr.append(rid)
            else:
                nxt.append(e)
        if not rnd:  # cannot happen: every chain is rooted at a source
            raise RuntimeError("multicast schedule stalled")
        for rid, (_, d) in zip(rr, rnd):
            have[rid].add(d)
        rounds.append(rnd)
        hops.append(rh)
        round_reqs.append(rr)
        pend = nxt
    return Schedule(topo.num_nodes, rounds, hops, round_reqs)


def dp_broadcast_schedule(num_ranks: int, algo: str = "DPM") -> Schedule:
    """Broadcast rank 0 -> all ranks on a 1-D ring (a data-parallel axis).

    The ring is ``Torus(num_ranks, 1)``; with DPM the destination set splits
    into the two ring directions and each side is a relay chain, roughly
    halving the rounds of MU's one-send-per-round direct scheme.
    """
    ring = torus(num_ranks, 1)
    dests = [(i, 0) for i in range(1, num_ranks)]
    return schedule_multicasts(ring, [((0, 0), dests)], algo)


def apply_schedule(x: jax.Array, sched: Schedule, axis_name: str) -> jax.Array:
    """Execute a Schedule on a shard_map-local array: one ppermute per
    round; receivers adopt the incoming payload, all other ranks keep
    theirs. Only meaningful for single-request (broadcast-like) schedules,
    where every transfer carries the same logical payload."""
    idx = jax.lax.axis_index(axis_name)
    for rnd in sched.rounds:
        y = jax.lax.ppermute(x, axis_name, perm=list(rnd))
        recv = jnp.zeros((), dtype=bool)
        for _, d in rnd:
            recv = recv | (idx == d)
        x = jnp.where(recv, y, x)
    return x
