"""GPipe-style microbatched pipeline parallelism over a mesh axis.

``pipeline_apply`` schedules M microbatches across the S stages of a
``pipe`` mesh axis: at step t stage s runs microbatch ``t - s``, stage
outputs hand off to the next stage with a single ``jax.lax.ppermute``
shift per step, and the last stage's results are returned from the
drain.  The whole thing is a static Python loop of ``M + S - 1`` steps
inside one shard_map, so it traces once, scans each stage's stacked
layer weights, and is differentiable end-to-end (ppermute transposes to
the reverse shift; the warmup/drain bubbles contribute zeros).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(layer_fn, stage_params, x: jax.Array, mesh, axis: str = "pipe"):
    """Run ``layer_fn`` layers, partitioned into pipeline stages.

    layer_fn: (layer_params, h) -> h, one layer.
    stage_params: pytree with leading dims (S, L_per_stage, ...) — stage-
        major stacked layer weights; sharded over ``axis``.
    x: (M, microbatch...) — M microbatches, replicated.
    Returns (M, microbatch...): every microbatch through all S*L layers.
    """
    n_stages = dict(mesh.shape)[axis]
    n_micro = x.shape[0]
    for leaf in jax.tree.leaves(stage_params):
        if leaf.shape[0] != n_stages:
            # shard_map would accept any divisible leading dim and the
            # per-stage [0] slice would then silently drop layers
            raise ValueError(
                f"stage_params leading dim {leaf.shape[0]} != "
                f"{n_stages} pipeline stages on axis {axis!r}"
            )

    def local(sp, xl):
        sp = jax.tree.map(lambda a: a[0], sp)  # (L_per_stage, ...) this stage
        stage = jax.lax.axis_index(axis)
        first, last = stage == 0, stage == n_stages - 1
        shift = [(i, i + 1) for i in range(n_stages - 1)]

        def run_stage(h):
            def body(h, lp):
                return layer_fn(lp, h), None

            h, _ = jax.lax.scan(body, h, sp)
            return h

        state = jnp.zeros_like(xl[0])
        outs = jnp.zeros_like(xl)
        for t in range(n_micro + n_stages - 1):
            inject = xl[t] if t < n_micro else jnp.zeros_like(xl[0])
            state = jnp.where(first, inject, state)
            y = run_stage(state)
            if t >= n_stages - 1:
                outs = outs.at[t - n_stages + 1].set(
                    jnp.where(last, y, jnp.zeros_like(y))
                )
            state = jax.lax.ppermute(y, axis, perm=shift)
        # only the last stage wrote non-zeros; psum replicates the result
        return jax.lax.psum(outs, axis)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )(stage_params, x)
