"""Logical-axis -> mesh-axis sharding rules (DESIGN.md §4).

Every parameter/activation/cache array in the model carries a tuple of
logical axis names (repro/models/layers.py).  This module maps those names
onto mesh axes through *rule tables*: ``rules[logical] = (candidate, ...)``
where each candidate is a tuple of mesh axes to co-shard that dimension
over.  Candidates are tried in order (lookup precedence) and one is taken
iff

* every mesh axis of the candidate exists in the mesh (so ``("pod",
  "data")`` naturally degrades to the ``("data",)`` fallback on a
  single-pod mesh),
* none of its mesh axes is already used by an earlier dimension of the
  same array (a mesh axis can shard at most one dim),
* the product of the candidate's axis sizes is > 1 and divides the dim
  (shape-aware calls only) — otherwise the dim falls back to replication.

``zero1_shardings`` layers ZeRO-1 on top: each optimizer-state leaf gains
one extra shard over the free data axes (first still-replicated dim whose
size divides the data-parallel degree; leaves with no such dim keep the
plain parameter sharding).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Rule tables.  Values are ordered candidate tuples; each candidate is the
# tuple of mesh axes that dimension shards over.  Absent names (and None
# placeholder entries in axis tuples) replicate.
Rules = dict[str, tuple[tuple[str, ...], ...]]

_DATA = (("pod", "data"), ("data",))
_MODEL = (("model",),)

DEFAULT_RULES: Rules = {
    "batch": _DATA,
    "seq": (),
    "embed": (),
    "heads": _MODEL,
    "kv_heads": _MODEL,
    "head_dim": (),
    "mlp": _MODEL,
    "vocab": _MODEL,
    "experts": _MODEL,
    "expert_mlp": (),
    "layers": (),
    "state": (),
    "conv": (),
    "qk_rope": (),
    "kv_lora": (),
    "q_lora": (),
}

# Sequence parallelism: the residual stream's seq dim takes the model axis;
# a later dim wanting "model" (mlp/vocab) then replicates because the axis
# is used — GSPMD re-shards at the matmul boundaries.
SEQ_RULES: Rules = {**DEFAULT_RULES, "seq": _MODEL}

# Decode caches: batch over the data axes, seq over model (the layout
# launch/specs.py's HBM estimate assumes); head dims replicate.
CACHE_RULES: Rules = {
    **DEFAULT_RULES,
    "seq": _MODEL,
    "heads": (),
    "kv_heads": (),
}


def abstract_mesh(*axes: tuple[str, int]):
    """Device-free mesh of (name, size) axes for planning shardings.

    Wraps the AbstractMesh constructor across its jax signature change
    (<0.5 takes a shape tuple of pairs, newer takes (sizes, names)).
    """
    try:
        return jax.sharding.AbstractMesh(tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(s for _, s in axes), tuple(n for n, _ in axes)
        )


def _axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def _assign(
    axes: tuple, shape: tuple | None, mesh, rules: Rules | None
) -> list:
    """Per-dimension mesh-axis assignment (the engine behind every public
    helper).  ``shape`` entries of None skip the divisibility check."""
    rules = DEFAULT_RULES if rules is None else rules
    sizes = _axis_sizes(mesh)
    if shape is None:
        shape = (None,) * len(axes)
    used: set[str] = set()
    entries: list = []
    for name, dim in zip(axes, shape):
        assign = None
        for cand in rules.get(name, ()) if name is not None else ():
            if not cand or any(a not in sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            n = math.prod(sizes[a] for a in cand)
            if n <= 1:
                continue
            if dim is not None and dim % n != 0:
                continue
            assign = cand[0] if len(cand) == 1 else cand
            used.update(cand)
            break
        entries.append(assign)
    return entries


def spec_for_shape(axes: tuple, shape: tuple, mesh, rules: Rules | None = None) -> P:
    """Shape-aware PartitionSpec for one array: logical ``axes`` resolved
    through ``rules`` with divisibility fallback to replication."""
    return P(*_assign(axes, shape, mesh, rules))


def _is_axes(x) -> bool:
    return isinstance(x, tuple)


def tree_shardings(specs, shapes, mesh, rules: Rules | None = None):
    """NamedSharding pytree: ``specs`` leaves are logical-axis tuples,
    ``shapes`` the matching ShapeDtypeStruct (or array) pytree."""

    def one(axes, sds):
        return NamedSharding(mesh, spec_for_shape(axes, sds.shape, mesh, rules))

    return jax.tree.map(one, specs, shapes, is_leaf=_is_axes)


def param_shardings(specs, mesh, shapes=None, rules: Rules | None = None):
    """Parameter shardings from logical axes alone.

    Without ``shapes`` the divisibility check is skipped (structural
    mapping — jax pads uneven shards); pass ``shapes`` for the
    shape-checked variant (== ``tree_shardings``).
    """
    if shapes is not None:
        return tree_shardings(specs, shapes, mesh, rules)

    def one(axes):
        return NamedSharding(mesh, P(*_assign(axes, None, mesh, rules)))

    return jax.tree.map(one, specs, is_leaf=_is_axes)


def _flat_axes(entry) -> tuple[str, ...]:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def zero1_shardings(specs, shapes, mesh, rules: Rules | None = None):
    """ZeRO-1 optimizer-state shardings: the parameter sharding plus one
    extra shard over the free data axes per leaf.

    The first still-replicated dim whose size is divisible by the full free
    data-parallel degree takes it (then single data axes are tried in
    order); a leaf with no divisible dim falls back to the plain parameter
    sharding (replicated over data, as before ZeRO).
    """
    sizes = _axis_sizes(mesh)
    data_axes = tuple(
        a for a in ("pod", "data") if a in sizes and sizes[a] > 1
    )

    def one(axes, sds):
        entries = _assign(axes, sds.shape, mesh, rules)
        used = {a for e in entries for a in _flat_axes(e)}
        free = tuple(a for a in data_axes if a not in used)
        cands = [free] if free else []
        if len(free) > 1:  # then single axes, biggest shard degree first
            cands += [(a,) for a in sorted(free, key=lambda a: -sizes[a])]
        done = False
        for cand in cands:
            if done:
                break
            n = math.prod(sizes[a] for a in cand)
            for i, e in enumerate(entries):
                if e is None and sds.shape[i] % n == 0:
                    entries[i] = cand[0] if len(cand) == 1 else cand
                    done = True
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, specs, shapes, is_leaf=_is_axes)
