"""Attention: GQA (full/sliding-window) + DeepSeek-V2 MLA, train/prefill/decode.

The training/prefill path uses a chunked online-softmax attention written in
pure jnp (lax.scan over KV blocks) so that the 32k-prefill dry-run never
materializes S x S score matrices; on TPU the Pallas flash kernel
(repro/kernels/flash_attention) replaces it via RunConfig.use_pallas.

Decode attends a single query against a contiguous KV cache (bf16 or int8
with per-token-per-head scales). MLA caches the compressed latent (c_kv,
k_rope) only, and decodes with the absorbed-matmul formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import ArchConfig, MLAConfig, RunConfig
from .layers import Params, Specs, dense_apply, dense_init, norm_apply, norm_init
from .rope import apply_mrope, apply_rope
from ..shardctx import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked online-softmax attention (jnp flash, custom_vjp backward)
#
# The forward scans KV blocks with an online softmax; the BACKWARD is a
# hand-written flash backward (recompute p per block pair from the saved
# logsumexp) — without it, the VJP of the forward scans stacks every
# (cq x ck) probability block as a residual, which at 32k context is
# hundreds of GB per chip (found by the dry-run memory roofline).
# ---------------------------------------------------------------------------
def _flash_fwd_impl(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KH, D)
    v: jax.Array,  # (B, Sk, KH, Dv)
    causal: bool,
    window: int | None,
    chunk_q: int,
    chunk_k: int,
    q_offset: int,
    stream_bf16: bool = False,
):
    # stream_bf16: keep q/k/v/p tiles in bf16 on the HBM<->compute path and
    # accumulate in f32 via preferred_element_type — the numerics the Pallas
    # kernel (and any MXU matmul) uses; halves the attention HBM traffic.
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]  # may differ from D (MLA: qk 192, v 128)
    G = H // KH
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    # pad ragged lengths up to chunk multiples; padded KV is masked off and
    # padded Q rows are sliced off at the end
    pad_q = (-Sq) % cq
    pad_k = (-Sk) % ck
    if pad_q:
        q = jnp.pad(q, [(0, 0), (0, pad_q), (0, 0), (0, 0)])
    if pad_k:
        k = jnp.pad(k, [(0, 0), (0, pad_k), (0, 0), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, pad_k), (0, 0), (0, 0)])
    kv_len = Sk
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    nq, nk = Sq_p // cq, Sk_p // ck
    scale = D ** -0.5

    st = jnp.bfloat16 if stream_bf16 else jnp.float32
    qc = q.reshape(B, nq, cq, KH, G, D).astype(st)
    kc = k.reshape(B, nk, ck, KH, D).astype(st)
    vc = v.reshape(B, nk, ck, KH, Dv).astype(st)

    # Sliding-window: only the KV blocks overlapping [q_pos - window, q_pos]
    # are live; scan a static-length relative range instead of all nk blocks
    # (jnp analogue of the Pallas kernel's block skipping — a 1k window over
    # 32k context otherwise wastes 16x bytes and flops).
    if window is not None and causal:
        n_live = min(nk, (cq + window + ck - 1) // ck + 1)
    else:
        n_live = nk

    def q_block(iq, q_i):  # q_i: (B, cq, KH, G, D)
        q_pos = q_offset + iq * cq + jnp.arange(cq)
        if n_live < nk:
            j0 = jnp.clip((q_offset + iq * cq - (window or 0)) // ck, 0,
                          nk - n_live)
        else:
            j0 = jnp.int32(0)

        def kv_step(carry, jk):
            m, l, acc = carry
            ik = j0 + jk
            k_i = jax.lax.dynamic_index_in_dim(kc, ik, 1, keepdims=False)
            v_i = jax.lax.dynamic_index_in_dim(vc, ik, 1, keepdims=False)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_i,
                preferred_element_type=jnp.float32,
            ) * scale  # (B,KH,G,cq,ck) f32
            k_pos = ik * ck + jnp.arange(ck)
            mask = jnp.broadcast_to(k_pos[None, :] < kv_len, (cq, ck))
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(st), v_i,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_live))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KH,G,cq,Dv)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B,KH,G,cq)
        return out.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    outs, lses = jax.lax.map(lambda i: q_block(i, qc[:, i]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, Dv)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, KH, G)
    if pad_q:
        out = out[:, :Sq]
        lse = lse[:, :Sq]
    return out.astype(q.dtype), lse


def _flash_bwd_impl(
    q, k, v, lse, out, dout,
    causal, window, chunk_q, chunk_k, q_offset, stream_bf16=False,
):
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KH
    cq, ck = min(chunk_q, Sq), min(chunk_k, Sk)
    pad_q, pad_k = (-Sq) % cq, (-Sk) % ck
    if pad_q:
        padq = [(0, 0), (0, pad_q), (0, 0), (0, 0)]
        q = jnp.pad(q, padq)
        out = jnp.pad(out, padq[:2] + [(0, 0), (0, 0)])
        dout = jnp.pad(dout, padq[:2] + [(0, 0), (0, 0)])
        lse = jnp.pad(lse, [(0, 0), (0, pad_q), (0, 0), (0, 0)])
    if pad_k:
        padk = [(0, 0), (0, pad_k), (0, 0), (0, 0)]
        k, v = jnp.pad(k, padk), jnp.pad(v, padk)
    kv_len = Sk
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    nq, nk = Sq_p // cq, Sk_p // ck
    scale = D ** -0.5

    st = jnp.bfloat16 if stream_bf16 else jnp.float32
    qc = q.reshape(B, nq, cq, KH, G, D).astype(st)
    kc = k.reshape(B, nk, ck, KH, D).astype(st)
    vc = v.reshape(B, nk, ck, KH, Dv).astype(st)
    doc = dout.reshape(B, nq, cq, KH, G, Dv).astype(st)
    oc = out.reshape(B, nq, cq, KH, G, Dv).astype(st)
    lsec = lse.reshape(B, nq, cq, KH, G)
    # delta_i = rowsum(dout * out)
    delta = jnp.sum(
        doc.astype(jnp.float32) * oc.astype(jnp.float32), axis=-1
    )  # (B,nq,cq,KH,G)

    def q_step(carry, iq):
        dk_acc, dv_acc = carry  # (B,nk,ck,KH,D), (B,nk,ck,KH,Dv)
        q_i = qc[:, iq]
        do_i = doc[:, iq]
        lse_i = lsec[:, iq].transpose(0, 2, 3, 1)  # (B,KH,G,cq)
        dl_i = delta[:, iq].transpose(0, 2, 3, 1)  # (B,KH,G,cq)
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(dq_i, ik):
            k_j, v_j = kc[:, ik], vc[:, ik]
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_j,
                preferred_element_type=jnp.float32,
            ) * scale
            k_pos = ik * ck + jnp.arange(ck)
            mask = jnp.broadcast_to(k_pos[None, :] < kv_len, (cq, ck))
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            p = jnp.exp(jnp.minimum(s - lse_i[..., None], 30.0))
            p = jnp.where(mask, p, 0.0)  # (B,KH,G,cq,ck)
            dv_j = jnp.einsum(
                "bhgqk,bqhgd->bkhd", p.astype(st), do_i,
                preferred_element_type=jnp.float32,
            )
            dp = jnp.einsum(
                "bqhgd,bkhd->bhgqk", do_i, v_j,
                preferred_element_type=jnp.float32,
            )
            ds = (p * (dp - dl_i[..., None]) * scale).astype(st)
            dq_i = dq_i + jnp.einsum(
                "bhgqk,bkhd->bqhgd", ds, k_j,
                preferred_element_type=jnp.float32,
            )
            dk_j = jnp.einsum(
                "bhgqk,bqhgd->bkhd", ds, q_i,
                preferred_element_type=jnp.float32,
            )
            return dq_i, (dk_j, dv_j, ik)

        dq0 = jnp.zeros((B, cq, KH, G, D), jnp.float32)
        dq_i, (dks, dvs, iks) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
        # scatter dk/dv chunk grads back (scan order == chunk order)
        dk_acc = dk_acc + dks.transpose(1, 0, 2, 3, 4)
        dv_acc = dv_acc + dvs.transpose(1, 0, 2, 3, 4)
        return (dk_acc, dv_acc), dq_i

    dk0 = jnp.zeros((B, nk, ck, KH, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, ck, KH, Dv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_step, (dk0, dv0), jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, D)
    dk = dk.reshape(B, Sk_p, KH, D)
    dv = dv.reshape(B, Sk_p, KH, Dv)
    if pad_q:
        dq = dq[:, :Sq]
    if pad_k:
        dk, dv = dk[:, :Sk], dv[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, causal, window, chunk_q, chunk_k, q_offset, stream_bf16):
    out, _ = _flash_fwd_impl(
        q, k, v, causal, window, chunk_q, chunk_k, q_offset, stream_bf16
    )
    return out


def _flash_fwd_rule(q, k, v, causal, window, chunk_q, chunk_k, q_offset,
                    stream_bf16):
    out, lse = _flash_fwd_impl(
        q, k, v, causal, window, chunk_q, chunk_k, q_offset, stream_bf16
    )
    return out, (q, k, v, lse, out)


def _flash_bwd_rule(causal, window, chunk_q, chunk_k, q_offset, stream_bf16,
                    res, dout):
    q, k, v, lse, out = res
    return _flash_bwd_impl(
        q, k, v, lse, out, dout, causal, window, chunk_q, chunk_k, q_offset,
        stream_bf16,
    )


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KH, D)
    v: jax.Array,  # (B, Sk, KH, Dv)
    *,
    causal: bool = True,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_k: int = 1024,
    q_offset: int = 0,
    stream_bf16: bool = False,
) -> jax.Array:
    return _flash(q, k, v, causal, window, chunk_q, chunk_k, q_offset,
                  stream_bf16)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k: jax.Array,  # (B, S, KH, D)
    v: jax.Array,  # (B, S, KH, D)
    valid: jax.Array,  # (S,) or (B, S) bool
    *,
    window_ring: bool = False,
) -> jax.Array:
    B, _, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.reshape(B, KH, G, D).astype(jnp.float32) * D**-0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    if valid.ndim == 1:
        valid = valid[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV cache helpers (per-token-per-head scales)
# ---------------------------------------------------------------------------
def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B, S, KH, D) -> int8 values + (B, S, KH, 1) f32 scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    qv = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(
        jnp.int8
    )
    return qv, scale


def dequantize_kv(qv: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (qv.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ArchConfig) -> tuple[Params, Specs]:
    d, H, KH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pq, sq = dense_init(ks[0], d, H * Dh, "embed", "heads", bias=cfg.qkv_bias)
    pk, sk = dense_init(ks[1], d, KH * Dh, "embed", "kv_heads", bias=cfg.qkv_bias)
    pv, sv = dense_init(ks[2], d, KH * Dh, "embed", "kv_heads", bias=cfg.qkv_bias)
    po, so = dense_init(ks[3], H * Dh, d, "heads", "embed")
    return (
        {"wq": pq, "wk": pk, "wv": pv, "wo": po},
        {"wq": sq, "wk": sk, "wv": sv, "wo": so},
    )


def _positions_3d(positions: jax.Array) -> jax.Array:
    """Text-only stand-in for M-RoPE ids: (B,S) -> (B,S,3) equal sections."""
    return jnp.broadcast_to(positions[..., None], positions.shape + (3,))


def _rope_q_k(q, k, positions, cfg: ArchConfig):
    if cfg.pos == "rope":
        return (
            apply_rope(q, positions, cfg.rope_theta),
            apply_rope(k, positions, cfg.rope_theta),
        )
    if cfg.pos == "mrope":
        p3 = _positions_3d(positions)
        return (
            apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections),
            apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections),
        )
    return q, k  # sinusoidal/none handled at the embedding


def gqa_apply(
    p: Params,
    x: jax.Array,  # (B, S, d)
    cfg: ArchConfig,
    run: RunConfig,
    positions: jax.Array,  # (B, S)
    *,
    window: int | None = None,
    return_kv: bool = False,
):
    B, S, _ = x.shape
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, S, H, Dh)
    k = dense_apply(p["wk"], x).reshape(B, S, KH, Dh)
    v = dense_apply(p["wv"], x).reshape(B, S, KH, Dh)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    q, k = _rope_q_k(q, k, positions, cfg)
    out = chunked_attention(
        q,
        k,
        v,
        causal=True,
        window=window,
        chunk_q=run.attn_chunk_q,
        chunk_k=run.attn_chunk_k,
        stream_bf16=run.attn_stream_bf16,
    )
    out = dense_apply(p["wo"], out.reshape(B, S, H * Dh))
    if return_kv:
        return out, (k, v)
    return out


def gqa_init_cache(cfg: ArchConfig, run: RunConfig, batch: int, max_len: int, window: int | None):
    KH, Dh = cfg.n_kv_heads, cfg.head_dim
    S = min(max_len, window) if window else max_len
    dt = jnp.int8 if run.kv_cache_dtype == "int8" else jnp.dtype(run.kv_cache_dtype)
    cache = {
        "k": jnp.zeros((batch, S, KH, Dh), dt),
        "v": jnp.zeros((batch, S, KH, Dh), dt),
    }
    if run.kv_cache_dtype == "int8":
        cache["k_scale"] = jnp.zeros((batch, S, KH, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, S, KH, 1), jnp.float32)
    return cache


def gqa_decode(
    p: Params,
    cache: dict,
    x: jax.Array,  # (B, 1, d)
    cfg: ArchConfig,
    run: RunConfig,
    pos: jax.Array,  # scalar int32: tokens already in cache
    *,
    window: int | None = None,
):
    B = x.shape[0]
    H, KH, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    S = cache["k"].shape[1]
    q = dense_apply(p["wq"], x).reshape(B, 1, H, Dh)
    k = dense_apply(p["wk"], x).reshape(B, 1, KH, Dh)
    v = dense_apply(p["wv"], x).reshape(B, 1, KH, Dh)
    q, k = _rope_q_k(q, k, jnp.full((B, 1), pos, jnp.int32), cfg)
    slot = jnp.mod(pos, S) if window else pos
    if run.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        cache["k_scale"] = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks, (0, slot, 0, 0)
        )
        cache["v_scale"] = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs, (0, slot, 0, 0)
        )
        kk = dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
        vv = dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)
        )
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)
        )
        kk, vv = cache["k"], cache["v"]
    idx = jnp.arange(S)
    if window:
        # ring cache: every slot is valid once the cache has wrapped. RoPE
        # used absolute positions, so slot order does not matter for scores.
        valid = (idx <= slot) | (pos >= S)
    else:
        valid = idx <= pos
    out = decode_attention(q, kk, vv, valid)
    out = dense_apply(p["wo"], out.reshape(B, 1, H * Dh))
    return out, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ArchConfig) -> tuple[Params, Specs]:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    p_dq, s_dq = dense_init(ks[0], d, m.q_lora_rank, "embed", "q_lora")
    p_uq, s_uq = dense_init(
        ks[1], m.q_lora_rank, H * (m.qk_nope_head_dim + m.qk_rope_head_dim),
        "q_lora", "heads",
    )
    p_dkv, s_dkv = dense_init(ks[2], d, m.kv_lora_rank, "embed", "kv_lora")
    p_ukv, s_ukv = dense_init(
        ks[3], m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim),
        "kv_lora", "heads",
    )
    p_kr, s_kr = dense_init(ks[4], d, m.qk_rope_head_dim, "embed", "qk_rope")
    p_o, s_o = dense_init(ks[5], H * m.v_head_dim, d, "heads", "embed")
    nq, nsq = norm_init(m.q_lora_rank)
    nkv, nskv = norm_init(m.kv_lora_rank)
    return (
        {"wdq": p_dq, "wuq": p_uq, "wdkv": p_dkv, "wukv": p_ukv,
         "wkr": p_kr, "wo": p_o, "qnorm": nq, "kvnorm": nkv},
        {"wdq": s_dq, "wuq": s_uq, "wdkv": s_dkv, "wukv": s_ukv,
         "wkr": s_kr, "wo": s_o, "qnorm": nsq, "kvnorm": nskv},
    )


def _mla_qkv(p, x, cfg: ArchConfig, positions):
    """Full (naive) MLA q/k/v for train/prefill."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = norm_apply(p["qnorm"], dense_apply(p["wdq"], x))
    q = dense_apply(p["wuq"], cq).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    ckv = norm_apply(p["kvnorm"], dense_apply(p["wdkv"], x))
    kv = dense_apply(p["wukv"], ckv).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim
    )
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope = dense_apply(p["wkr"], x).reshape(B, S, 1, m.qk_rope_head_dim)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    return q_full, k_full, v, ckv, k_rope[:, :, 0]


def mla_apply(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    run: RunConfig,
    positions: jax.Array,
    *,
    return_kv: bool = False,
):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    q, k, v, ckv, krope = _mla_qkv(p, x, cfg, positions)
    out = chunked_attention(
        q, k, v,
        causal=True,
        chunk_q=run.attn_chunk_q,
        chunk_k=run.attn_chunk_k,
        stream_bf16=run.attn_stream_bf16,
    )
    out = dense_apply(p["wo"], out.reshape(B, S, cfg.n_heads * m.v_head_dim))
    if return_kv:
        return out, (ckv, krope)
    return out


def mla_init_cache(cfg: ArchConfig, run: RunConfig, batch: int, max_len: int):
    m: MLAConfig = cfg.mla
    dt = (
        jnp.bfloat16
        if run.kv_cache_dtype == "int8"
        else jnp.dtype(run.kv_cache_dtype)
    )
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
    }


def mla_decode(
    p: Params,
    cache: dict,
    x: jax.Array,  # (B, 1, d)
    cfg: ArchConfig,
    run: RunConfig,
    pos: jax.Array,
):
    """Absorbed-matmul MLA decode: attend in the 512-d latent space.

    q_eff = q_nope @ W_uk  (absorb key up-proj);  scores = q_eff . c_kv
    out_lat = attn @ c_kv; out = (out_lat @ W_uv) @ W_o  (absorb value up-proj)
    """
    m: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    cq = norm_apply(p["qnorm"], dense_apply(p["wdq"], x))
    q = dense_apply(p["wuq"], cq).reshape(
        B, 1, H, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, jnp.full((B, 1), pos, jnp.int32), cfg.rope_theta)
    ckv_new = norm_apply(p["kvnorm"], dense_apply(p["wdkv"], x))  # (B,1,Lkv)
    krope_new = apply_rope(
        dense_apply(p["wkr"], x).reshape(B, 1, 1, m.qk_rope_head_dim),
        jnp.full((B, 1), pos, jnp.int32),
        cfg.rope_theta,
    ).reshape(B, 1, m.qk_rope_head_dim)
    cache = dict(cache)
    cache["ckv"] = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0)
    )
    cache["krope"] = jax.lax.dynamic_update_slice(
        cache["krope"], krope_new.astype(cache["krope"].dtype), (0, pos, 0)
    )
    S = cache["ckv"].shape[1]
    # absorb W_uk: (Lkv, H, nope)
    wukv = p["wukv"]["w"].reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim
    )
    w_uk = wukv[:, :, : m.qk_nope_head_dim]
    w_uv = wukv[:, :, m.qk_nope_head_dim :]
    q_eff = jnp.einsum(  # (B,H,Lkv)
        "bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32), w_uk.astype(jnp.float32)
    )
    ckv_f = cache["ckv"].astype(jnp.float32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = jnp.einsum("bhl,bsl->bhs", q_eff, ckv_f)
    s += jnp.einsum(
        "bhr,bsr->bhs",
        q_rope[:, 0].astype(jnp.float32),
        cache["krope"].astype(jnp.float32),
    )
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, :], s * scale, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhs,bsl->bhl", prob, ckv_f)  # (B,H,Lkv)
    out = jnp.einsum("bhl,lhd->bhd", out_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    return dense_apply(p["wo"], out), cache
