"""Mixture-of-Experts FFN: softmax top-k router + sort-based dispatch.

Dispatch avoids the (tokens, E, C) one-hot tensor of the GShard einsum
formulation: routed (token, expert) pairs are sorted by expert id, ranked
within expert, and scattered into an (E * C, d) buffer (capacity-dropped).
This keeps the dense-path memory linear in tokens and maps directly onto the
expert-parallel shard_map path (repro/dist/ep.py), where the buffer's E axis
is what all_to_all / the DPM multicast schedule moves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig, MoEConfig
from .layers import Params, Specs, dense_apply, dense_init


def moe_init(key, cfg: ArchConfig) -> tuple[Params, Specs]:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p_router, s_router = dense_init(ks[0], d, m.n_experts, "embed", "experts")
    # stacked expert weights: (E, d, ff) / (E, ff, d)
    scale = d**-0.5
    p = {
        "router": p_router,
        "wi": jax.random.normal(ks[1], (m.n_experts, d, m.d_expert)) * scale,
        "wg": jax.random.normal(ks[2], (m.n_experts, d, m.d_expert)) * scale,
        "wo": jax.random.normal(ks[3], (m.n_experts, m.d_expert, d))
        * (m.d_expert**-0.5),
    }
    sp: Specs = {
        "router": s_router,
        "wi": ("experts", "embed", "expert_mlp"),
        "wg": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    if m.n_shared:
        p["shared_wi"] = (
            jax.random.normal(ks[4], (d, m.n_shared * m.d_expert)) * scale
        )
        p["shared_wg"] = (
            jax.random.normal(jax.random.fold_in(ks[4], 1), (d, m.n_shared * m.d_expert))
            * scale
        )
        p["shared_wo"] = (
            jax.random.normal(jax.random.fold_in(ks[4], 2), (m.n_shared * m.d_expert, d))
            * (m.d_expert**-0.5)
        )
        sp["shared_wi"] = ("embed", "mlp")
        sp["shared_wg"] = ("embed", "mlp")
        sp["shared_wo"] = ("mlp", "embed")
    return p, sp


def route(p: Params, x: jax.Array, m: MoEConfig):
    """Router: fp32 softmax over experts, top-k with renormalized weights.

    Returns (expert ids (T,k), weights (T,k), aux load-balance loss).
    """
    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * p_e
    E = m.n_experts
    assign = jnp.zeros((x.shape[0], E), jnp.float32)
    assign = assign.at[jnp.arange(x.shape[0])[:, None], ids].add(1.0)
    f = assign.mean(0) / m.top_k
    pbar = probs.mean(0)
    aux = E * jnp.sum(f * pbar)
    return ids, weights, aux


def capacity(m: MoEConfig, tokens: int) -> int:
    c = int(tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, (c + 7) // 8 * 8)


def dispatch_indices(ids: jax.Array, m: MoEConfig, cap: int):
    """Sort-based dispatch plan.

    ids: (T, k) expert choices. Returns (slot (T*k,), keep (T*k,)) where slot
    indexes an (E*cap,) buffer; dropped pairs get slot 0 / keep False.
    """
    Tk = ids.shape[0] * ids.shape[1]
    flat = ids.reshape(Tk)
    order = jnp.argsort(flat, stable=True)  # group by expert
    ranked = jnp.zeros((Tk,), jnp.int32)
    # rank within expert = position - first position of that expert
    sorted_e = flat[order]
    pos = jnp.arange(Tk)
    first = jnp.full((m.n_experts,), Tk, jnp.int32).at[sorted_e].min(
        pos.astype(jnp.int32), mode="drop"
    )
    rank_sorted = pos.astype(jnp.int32) - first[sorted_e]
    ranked = ranked.at[order].set(rank_sorted)
    keep = ranked < cap
    slot = jnp.where(keep, flat * cap + ranked, 0)
    return slot, keep


def expert_ffn(p: Params, xe: jax.Array) -> jax.Array:
    """xe: (E, cap, d) -> (E, cap, d) SwiGLU per expert."""
    dt = xe.dtype
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"].astype(dt))


def moe_apply_dense(p: Params, x: jax.Array, cfg: ArchConfig):
    """GSPMD path: token-major in, (E, cap, d) expert compute, combine.

    x: (B, S, d). Returns (y, aux_loss).
    """
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    ids, w, aux = route(p, xt, m)
    cap = capacity(m, T)
    slot, keep = dispatch_indices(ids, m, cap)
    k = m.top_k
    xt_rep = jnp.repeat(xt, k, axis=0)  # (T*k, d) token per routed pair
    from ..shardctx import constrain

    buf = jnp.zeros((m.n_experts * cap, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt_rep, 0))
    buf = constrain(buf.reshape(m.n_experts, cap, d), ("experts", None, None))
    ye = expert_ffn(p, buf)
    gathered = ye.reshape(m.n_experts * cap, d)[slot]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)).sum(1)
    if m.n_shared:
        h = xt @ p["shared_wi"].astype(x.dtype)
        g = xt @ p["shared_wg"].astype(x.dtype)
        y = y + (jax.nn.silu(g) * h) @ p["shared_wo"].astype(x.dtype)
    return y.reshape(B, S, d), aux
