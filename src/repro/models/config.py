"""Architecture + shape configuration dataclasses.

One ``ArchConfig`` covers the 10 assigned architectures: dense llama-style,
MoE (DeepSeek-V2 MLA / Moonlight), SSM (Mamba-2 SSD), hybrid (Hymba), audio
(MusicGen backbone) and VLM (Qwen2-VL backbone). Layer stacks are described
as ``layout`` groups of (block_kind, count); each group is scanned
(weights stacked on a leading "layers" dim) to keep HLO size independent of
depth.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden dim
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # layer stack: ordered groups of (block_kind, count); kinds:
    #   attn_dense  — GQA attention + dense MLP
    #   attn_moe    — GQA attention + MoE FFN
    #   mla_dense   — MLA attention + dense MLP
    #   mla_moe     — MLA attention + MoE FFN
    #   ssd         — Mamba-2 SSD block (attention-free)
    #   hymba_g     — parallel (global attention || SSM heads) + MLP
    #   hymba_w     — parallel (sliding-window attention || SSM heads) + MLP
    layout: tuple[tuple[str, int], ...] = ()
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    pos: str = "rope"  # rope | mrope | sinusoidal | none
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    window: int = 1024  # sliding-window size for *_w blocks
    embed_input: str = "tokens"  # tokens | frames (precomputed embeddings stub)
    tie_embeddings: bool = False
    dense_d_ff: int | None = None  # d_ff of dense layers in mostly-MoE stacks
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # notes for DESIGN.md §Arch-applicability
    source: str = ""
    sub_quadratic: bool = False  # can run long_500k decode

    def __post_init__(self):
        total = sum(c for _, c in self.layout)
        if total != self.n_layers:
            raise ValueError(f"{self.name}: layout sums to {total} != {self.n_layers}")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def scaled(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class RunConfig:
    """Execution-level knobs (independent of the architecture)."""

    params_dtype: str = "bfloat16"
    activations_dtype: str = "bfloat16"
    remat: str = "block"  # none | block | full
    attn_chunk_q: int = 512  # chunked-attention block sizes (jnp path)
    attn_chunk_k: int = 1024
    use_pallas: bool = False  # TPU target only; CPU dry-run uses jnp path
    attn_stream_bf16: bool = False  # bf16 HBM<->MXU tiles, f32 accumulate
    ssd_stream_bf16: bool = False  # same for the SSD dual-form matrices
    norm_stats_only_f32: bool = False  # fused-norm style: f32 stats, bf16 ops
    ssd_chunk: int | None = None  # override SSMConfig.chunk (intra-chunk L)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8
    seq_shard: bool = False  # sequence-parallel residual stream (SP)
    zero1: bool = True  # shard optimizer state over the data axis
    grad_compress: str = "none"  # none | int8
    moe_impl: str = "dense"  # dense (GSPMD einsum) | ep (shard_map all_to_all)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    z_loss: float = 1e-4
    vocab_round: int = 128  # pad vocab to a multiple (MXU alignment / TP)
