"""Rotary position embeddings: standard RoPE, Qwen2-VL M-RoPE, sinusoidal."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,  # (..., S, 3) — temporal, height, width ids
    theta: float,
    sections: tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: frequency bands split across (t, h, w) ids.

    ``sections`` are in D/2 units (16+24+24 = 64 = 128/2 for head_dim 128).
    For pure-text positions the three ids coincide and M-RoPE == RoPE.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d // 2)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions.shape[:-1] + (d // 2,)).astype(jnp.int32),
        axis=-1,
    )  # (..., S, D/2): per-band position id
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """(..., S) -> (..., S, d) classic transformer sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
