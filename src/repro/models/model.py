"""Full decoder LM: embed -> scanned block groups -> norm -> LM head.

Weights of each homogeneous layout group are stacked on a leading "layers"
axis and applied with lax.scan (optionally rematerialized), keeping the HLO
size depth-independent — a 60- or 80-layer dry-run compiles in roughly the
time of a 2-layer one.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import block_apply, block_decode, block_init, block_init_cache
from .config import ArchConfig, RunConfig
from .layers import (
    Params,
    Specs,
    embed_init,
    lm_head_apply,
    norm_apply,
    norm_init,
    stack_init,
)
from .rope import sinusoidal
from ..shardctx import constrain


def padded_vocab(cfg: ArchConfig, run: RunConfig) -> int:
    r = run.vocab_round
    return (cfg.vocab + r - 1) // r * r


def model_init(key, cfg: ArchConfig, run: RunConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, len(cfg.layout) + 3)
    params: Params = {}
    specs: Specs = {}
    vp = padded_vocab(cfg, run)
    if cfg.embed_input == "tokens":
        params["embed"], specs["embed"] = embed_init(ks[0], vp, cfg.d_model)
    for gi, (kind, count) in enumerate(cfg.layout):
        p, s = stack_init(lambda k: block_init(kind, k, cfg), ks[gi + 1], count)
        params[f"g{gi}"], specs[f"g{gi}"] = p, s
    params["final_norm"], specs["final_norm"] = norm_init(cfg.d_model, cfg.norm)
    if not (cfg.tie_embeddings and cfg.embed_input == "tokens"):
        params["lm_head"], specs["lm_head"] = embed_init(ks[-1], vp, cfg.d_model)
    return params, specs


def abstract_init(cfg: ArchConfig, run: RunConfig, key=None):
    """(ShapeDtypeStruct params, specs) without allocating anything."""
    holder = {}

    def f(k):
        p, s = model_init(k, cfg, run)
        holder["specs"] = s
        return p

    shapes = jax.eval_shape(f, key if key is not None else jax.random.PRNGKey(0))
    return shapes, holder["specs"]


def _embed(params, cfg: ArchConfig, run: RunConfig, batch: dict, pos0=0) -> jax.Array:
    dt = jnp.dtype(run.activations_dtype)
    if cfg.embed_input == "tokens":
        x = jnp.take(params["embed"]["table"].astype(dt), batch["tokens"], axis=0)
    else:  # modality frontend stub: precomputed frame/patch embeddings
        x = batch["frames"].astype(dt)
    if cfg.pos == "sinusoidal":
        S = x.shape[1]
        x = x + sinusoidal(pos0 + jnp.arange(S), cfg.d_model).astype(dt)
    return constrain(x, ("batch", "seq", "embed"))


def _group_apply(kind, gparams, x, cfg, run, positions, collect_cache=False,
                 cache_len=None):
    """lax.scan over the stacked layers of one group."""

    def body(carry, lp):
        h, aux = carry
        h = constrain(h, ("batch", "seq", "embed"))
        h2, a, cache = block_apply(
            kind, lp, h, cfg, run, positions, collect_cache=collect_cache,
            cache_len=cache_len,
        )
        h2 = constrain(h2, ("batch", "seq", "embed"))
        return (h2, aux + a), cache

    if run.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), gparams)
    return x, aux, caches


def _logits(params, cfg, run, x):
    table = params.get("lm_head", params.get("embed"))
    logits = lm_head_apply(table, x).astype(jnp.float32)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    vp = logits.shape[-1]
    if vp != cfg.vocab:  # mask padded vocab entries
        mask = jnp.arange(vp) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def forward(params: Params, batch: dict, cfg: ArchConfig, run: RunConfig):
    """Training forward: returns (loss, metrics)."""
    x = _embed(params, cfg, run, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    for gi, (kind, _) in enumerate(cfg.layout):
        x, aux, _ = _group_apply(kind, params[f"g{gi}"], x, cfg, run, positions)
        aux_total += aux
    x = norm_apply(params["final_norm"], x)
    logits = _logits(params, cfg, run, x)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = (lse - ll).mean()
    zl = run.z_loss * (lse**2).mean()
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    loss = ce + zl + aux_coef * aux_total
    return loss, {"ce": ce, "z_loss": zl, "moe_aux": aux_total}


def prefill(params: Params, batch: dict, cfg: ArchConfig, run: RunConfig,
            cache_len: int | None = None):
    """Run the prompt, return (last-token logits, caches).

    ``cache_len`` pads non-ring caches to that capacity so decode can append.
    """
    x = _embed(params, cfg, run, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    caches: dict[str, Any] = {}
    for gi, (kind, _) in enumerate(cfg.layout):
        x, _, cache = _group_apply(
            kind, params[f"g{gi}"], x, cfg, run, positions, collect_cache=True,
            cache_len=cache_len,
        )
        caches[f"g{gi}"] = cache
    x = norm_apply(params["final_norm"], x)
    logits = _logits(params, cfg, run, x[:, -1:, :])
    return logits, caches


def init_caches(cfg: ArchConfig, run: RunConfig, batch: int, max_len: int):
    """Zeroed decode caches for every group (layer-stacked leading dim)."""
    caches: dict[str, Any] = {}
    for gi, (kind, count) in enumerate(cfg.layout):
        one = block_init_cache(kind, cfg, run, batch, max_len)
        caches[f"g{gi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count, *a.shape)), one
        )
    return caches


def _block_cache_axes(kind: str, cfg: ArchConfig, run: RunConfig):
    kv = {
        "k": ("batch", "seq", "kv_heads", "head_dim"),
        "v": ("batch", "seq", "kv_heads", "head_dim"),
    }
    if run.kv_cache_dtype == "int8":
        kv["k_scale"] = ("batch", "seq", "kv_heads", None)
        kv["v_scale"] = ("batch", "seq", "kv_heads", None)
    ssd = {
        "conv": ("batch", "conv", "mlp"),
        "state": ("batch", "heads", "state", "head_dim"),
    }
    if kind in ("attn_dense", "attn_moe"):
        return kv
    if kind in ("mla_dense", "mla_moe"):
        return {"ckv": ("batch", "seq", "kv_lora"),
                "krope": ("batch", "seq", "qk_rope")}
    if kind == "ssd":
        return ssd
    if kind in ("hymba_g", "hymba_w"):
        return {"attn": dict(kv), "ssm": dict(ssd)}
    raise ValueError(kind)


def cache_axes(cfg: ArchConfig, run: RunConfig):
    """Logical-axis tuples mirroring init_caches' structure (leading
    "layers" dim per group)."""
    out = {}
    for gi, (kind, _) in enumerate(cfg.layout):
        one = _block_cache_axes(kind, cfg, run)
        out[f"g{gi}"] = jax.tree.map(
            lambda ax: ("layers", *ax),
            one,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    return out


def decode_step(
    params: Params,
    caches: dict,
    batch: dict,  # {"tokens": (B,1)} or {"frames": (B,1,d)}; plus "pos" scalar
    cfg: ArchConfig,
    run: RunConfig,
):
    """One decode step against the caches. Returns (logits, new caches)."""
    pos = batch["pos"]
    x = _embed(params, cfg, run, batch, pos0=pos)
    new_caches: dict[str, Any] = {}
    for gi, (kind, _) in enumerate(cfg.layout):

        def body(h, xs):
            lp, lcache = xs
            h2, c2 = block_decode(kind, lp, lcache, h, cfg, run, pos)
            return h2, c2

        x, nc = jax.lax.scan(body, x, (params[f"g{gi}"], caches[f"g{gi}"]))
        new_caches[f"g{gi}"] = nc
    x = norm_apply(params["final_norm"], x)
    logits = _logits(params, cfg, run, x)
    return logits, new_caches


def loss_fn(params, batch, cfg, run):
    return forward(params, batch, cfg, run)


def make_train_step(cfg: ArchConfig, run: RunConfig, optimizer):
    """(state, batch) -> (state, metrics); optimizer from repro/train/optim."""

    def train_step(state, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, run), has_aux=True
        )
        (loss, metrics), grads = grad_fn(state.params)
        state = optimizer.update(state, grads)
        metrics = dict(metrics, loss=loss)
        return state, metrics

    return train_step
