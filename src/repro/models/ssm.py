"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060), pure jnp.

Chunked algorithm: the sequence is split into chunks of length L; within a
chunk the recurrence is computed in its quadratic "attention" dual form, and
a lax.scan carries the (N x P) state across chunks. A Pallas kernel for the
intra-chunk part lives in repro/kernels/ssd with this module's
``ssd_reference`` as its oracle.

Shapes: batch B, seq S, heads H, head_dim P, groups G, state N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig, SSMConfig
from .layers import Params, Specs, dense_apply, dense_init, norm_apply

MIN_LOG = -30.0


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)  (already softplus'ed)
    A: jax.Array,  # (H,) negative decay rates
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P) initial state
    return_state: bool = False,
    stream_bf16: bool = False,
):
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # dt=0 on padding => decay exp(0)=1 and zero state update: identity
        # steps, so h_last stays exact and padded y rows are sliced off.
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        Bm = jnp.pad(Bm, [(0, 0), (0, pad), (0, 0), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, pad), (0, 0), (0, 0)])
    S_real, S = S, S + pad
    nc = S // L

    xf = x.astype(jnp.float32).reshape(B_, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(B_, nc, L, H)
    Bf = Bm.astype(jnp.float32).reshape(B_, nc, L, G, N)
    Cf = Cm.astype(jnp.float32).reshape(B_, nc, L, G, N)
    Af = A.astype(jnp.float32)

    # log-decay per step: (B, nc, L, H)
    la = dtf * Af  # negative
    cum = jnp.cumsum(la, axis=2)  # within-chunk cumulative log decay

    # broadcast B,C across heads in group: head h uses group h // hpg
    Bh = jnp.repeat(Bf, hpg, axis=3)  # (B, nc, L, H, N)
    Ch = jnp.repeat(Cf, hpg, axis=3)

    # ---- intra-chunk quadratic form ------------------------------------
    # M[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j   for j <= i
    st = jnp.bfloat16 if stream_bf16 else jnp.float32
    cb = jnp.einsum(
        "bclhn,bckhn->bchlk", Ch.astype(st), Bh.astype(st),
        preferred_element_type=jnp.float32,
    )  # (B,nc,H,L,L)
    # decay matrix exp(cum_i - cum_j) on the lower triangle
    ci = cum.transpose(0, 1, 3, 2)  # (B,nc,H,L)
    dmat = ci[..., :, None] - ci[..., None, :]  # (B,nc,H,L,L)
    tri = jnp.tril(jnp.ones((L, L), bool))
    m = jnp.where(tri, jnp.exp(jnp.maximum(dmat, MIN_LOG)), 0.0)
    m = m * cb * dtf.transpose(0, 1, 3, 2)[..., None, :]  # * dt_j
    y_intra = jnp.einsum(
        "bchlk,bckhp->bclhp", m.astype(st), xf.astype(st),
        preferred_element_type=jnp.float32,
    )

    # ---- chunk-boundary states -----------------------------------------
    # state contribution of chunk c: sum_j exp(cum_L - cum_j) dt_j B_j x_j^T
    tail = jnp.exp(jnp.maximum(cum[:, :, -1:, :] - cum, MIN_LOG))  # (B,nc,L,H)
    sc = jnp.einsum("bclh,bclh,bclhn,bclhp->bchnp", tail, dtf, Bh, xf)
    chunk_decay = jnp.exp(jnp.maximum(cum[:, :, -1, :], MIN_LOG))  # (B,nc,H)

    def step(h, inputs):
        sc_c, dec_c = inputs  # (B,H,N,P), (B,H)
        h_new = h * dec_c[..., None, None] + sc_c
        return h_new, h  # emit state *entering* the chunk

    h_init = (
        jnp.zeros((B_, H, N, P), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    h_last, h_in = jax.lax.scan(
        step,
        h_init,
        (sc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) state entering chunk

    # ---- inter-chunk contribution --------------------------------------
    inter_decay = jnp.exp(jnp.maximum(cum, MIN_LOG))  # (B,nc,L,H)
    y_inter = jnp.einsum(
        "bclhn,bchnp,bclh->bclhp", Ch, h_in, inter_decay
    )

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    if pad:
        y = y[:, :S_real]
    if return_state:
        return y, h_last
    return y


def ssd_reference(x, dt, A, Bm, Cm, h0=None):
    """Naive per-step recurrence (oracle for tests and the Pallas kernel)."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    Bh = jnp.repeat(Bm.astype(jnp.float32), hpg, axis=2)
    Ch = jnp.repeat(Cm.astype(jnp.float32), hpg, axis=2)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    a = jnp.exp(dtf * A.astype(jnp.float32))  # (B,S,H)

    def step(h, t):
        ht = h * a[:, t][..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh[:, t] * dtf[:, t][..., None], xf[:, t]
        )
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], ht)
        return ht, y

    h = (
        jnp.zeros((B_, H, N, P), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    h, ys = jax.lax.scan(step, h, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3), h  # (B,S,H,P), final state


# ---------------------------------------------------------------------------
# full Mamba-2 block
# ---------------------------------------------------------------------------
def ssd_init(key, cfg: ArchConfig) -> tuple[Params, Specs]:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.n_heads(d)
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 4)
    d_in = 2 * di + 2 * s.n_groups * s.d_state + H
    p_in, sp_in = dense_init(ks[0], d, d_in, "embed", "mlp")
    p_out, sp_out = dense_init(ks[1], di, d, "mlp", "embed")
    p = {
        "in_proj": p_in,
        "out_proj": p_out,
        "conv_w": jax.random.normal(ks[2], (s.d_conv, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 1e-2, jnp.float32))),
        "norm_scale": jnp.ones((di,), jnp.float32),
    }
    sp = {
        "in_proj": sp_in,
        "out_proj": sp_out,
        "conv_w": ("conv", "mlp"),
        "conv_b": ("mlp",),
        "A_log": ("heads",),
        "D": ("heads",),
        "dt_bias": ("heads",),
        "norm_scale": ("mlp",),
    }
    return p, sp


def _split_zxbcdt(z_x_b_c_dt, di, gn, H):
    z = z_x_b_c_dt[..., :di]
    x = z_x_b_c_dt[..., di : 2 * di]
    b = z_x_b_c_dt[..., 2 * di : 2 * di + gn]
    c = z_x_b_c_dt[..., 2 * di + gn : 2 * di + 2 * gn]
    dt = z_x_b_c_dt[..., 2 * di + 2 * gn :]
    return z, x, b, c, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array, state=None):
    """Depthwise causal conv1d. xbc: (B,S,C); w: (K,C). state: (B,K-1,C)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, S+K-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * w[i].astype(xbc.dtype) for i in range(K)
    )
    out = out + b.astype(xbc.dtype)
    new_state = xp[:, -(K - 1) :, :] if K > 1 else pad
    return jax.nn.silu(out), new_state


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = (yf * yf).mean(-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + 1e-6) * scale).astype(y.dtype)


def ssd_block_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
                    return_state=False, stream_bf16=False, chunk=None):
    s: SSMConfig = cfg.ssm
    B_, S, d = x.shape
    di, H, G, N = s.d_inner(d), s.n_heads(d), s.n_groups, s.d_state
    from ..shardctx import constrain

    zxbcdt = dense_apply(p["in_proj"], x)
    zxbcdt = constrain(zxbcdt, ("batch", "seq", "mlp"))
    z, xi, bm, cm, dt = _split_zxbcdt(zxbcdt, di, G * N, H)
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xi, bm, cm = (
        xbc[..., :di],
        xbc[..., di : di + G * N],
        xbc[..., di + G * N :],
    )
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = ssd_scan(
        xi.reshape(B_, S, H, s.head_dim),
        dtp,
        A,
        bm.reshape(B_, S, G, N),
        cm.reshape(B_, S, G, N),
        chunk or s.chunk,
        return_state=True,
        stream_bf16=stream_bf16,
    )
    y = y + xi.reshape(B_, S, H, s.head_dim) * p["D"][:, None]
    y = y.reshape(B_, S, di).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    out = dense_apply(p["out_proj"], y)
    if return_state:
        return out, {"conv": conv_state, "state": h_last}
    return out


def ssd_init_cache(cfg: ArchConfig, batch: int):
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di, H, G, N = s.d_inner(d), s.n_heads(d), s.n_groups, s.d_state
    conv_dim = di + 2 * G * N
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), jnp.bfloat16),
        "state": jnp.zeros((batch, H, N, s.head_dim), jnp.float32),
    }


def ssd_block_decode(p: Params, cache: dict, x: jax.Array, cfg: ArchConfig):
    """Single-token recurrent step. x: (B, 1, d)."""
    s: SSMConfig = cfg.ssm
    B_, _, d = x.shape
    di, H, G, N = s.d_inner(d), s.n_heads(d), s.n_groups, s.d_state
    zxbcdt = dense_apply(p["in_proj"], x)
    z, xi, bm, cm, dt = _split_zxbcdt(zxbcdt, di, G * N, H)
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    xi = xbc[..., :di].reshape(B_, H, s.head_dim)
    bm = xbc[..., di : di + G * N].reshape(B_, G, N)
    cm = xbc[..., di + G * N :].reshape(B_, G, N)
    hpg = H // G
    bh = jnp.repeat(bm, hpg, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cm, hpg, axis=1).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = jnp.exp(dtp * -jnp.exp(p["A_log"]))  # (B,H)
    h = cache["state"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", bh * dtp[..., None], xi.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch, h) + xi.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B_, 1, di).astype(x.dtype)
    y = _gated_rmsnorm(y, z, p["norm_scale"])
    return dense_apply(p["out_proj"], y), {"conv": conv_state, "state": h}
