"""Model substrate: layers, attention (GQA/MLA), SSD, MoE, blocks, LM."""
from .config import SHAPES, ArchConfig, MLAConfig, MoEConfig, RunConfig, ShapeConfig, SSMConfig
from .layers import count_params
from .model import (
    abstract_init,
    decode_step,
    forward,
    init_caches,
    loss_fn,
    model_init,
    padded_vocab,
    prefill,
)

__all__ = [
    "abstract_init",
    "SHAPES",
    "ArchConfig",
    "MLAConfig",
    "MoEConfig",
    "RunConfig",
    "SSMConfig",
    "ShapeConfig",
    "count_params",
    "decode_step",
    "forward",
    "init_caches",
    "loss_fn",
    "model_init",
    "padded_vocab",
    "prefill",
]
