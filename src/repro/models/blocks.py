"""Decoder blocks: attention/MLA/SSD/Hymba-hybrid x dense/MoE FFN.

Block kinds (ArchConfig.layout):
    attn_dense  attn_moe  mla_dense  mla_moe  ssd  hymba_g  hymba_w

Every kind implements init / apply (train + prefill) / init_cache / decode
with a common signature so the model can lax.scan over homogeneous groups.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (
    gqa_apply,
    gqa_decode,
    gqa_init,
    gqa_init_cache,
    mla_apply,
    mla_decode,
    mla_init,
    mla_init_cache,
)
from .config import ArchConfig, RunConfig
from .layers import (
    Params,
    Specs,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
)
from .moe import moe_apply_dense, moe_init
from .ssm import (
    ssd_block_apply,
    ssd_block_decode,
    ssd_init,
    ssd_init_cache,
)


def _window(kind: str, cfg: ArchConfig) -> int | None:
    return cfg.window if kind.endswith("_w") else None


def _moe_ffn(pf, xn, cfg: ArchConfig, run: RunConfig):
    """Dense (GSPMD) or explicit expert-parallel (shard_map all_to_all)."""
    if run.moe_impl == "ep":
        from ..dist.ep import moe_apply_ep
        from ..shardctx import _CTX

        mesh = _CTX["mesh"]
        if mesh is not None and cfg.moe.n_experts % mesh.shape["model"] == 0:
            data_axes = tuple(
                a for a in ("pod", "data") if a in mesh.axis_names
            )
            return moe_apply_ep(pf, xn, cfg, mesh, data_axes=data_axes)
    return moe_apply_dense(pf, xn, cfg)


# ---------------------------------------------------------------- init
def block_init(kind: str, key, cfg: ArchConfig) -> tuple[Params, Specs]:
    ks = jax.random.split(key, 4)
    p: Params = {}
    sp: Specs = {}
    p["norm1"], sp["norm1"] = norm_init(cfg.d_model, cfg.norm)
    if kind in ("attn_dense", "attn_moe"):
        p["attn"], sp["attn"] = gqa_init(ks[0], cfg)
    elif kind in ("mla_dense", "mla_moe"):
        p["attn"], sp["attn"] = mla_init(ks[0], cfg)
    elif kind == "ssd":
        p["ssd"], sp["ssd"] = ssd_init(ks[0], cfg)
        return p, sp  # mamba2 block has no FFN sublayer
    elif kind in ("hymba_g", "hymba_w"):
        p["attn"], sp["attn"] = gqa_init(ks[0], cfg)
        p["ssd"], sp["ssd"] = ssd_init(ks[3], cfg)
        p["bnorm_a"], sp["bnorm_a"] = norm_init(cfg.d_model)
        p["bnorm_s"], sp["bnorm_s"] = norm_init(cfg.d_model)
    else:
        raise ValueError(kind)
    p["norm2"], sp["norm2"] = norm_init(cfg.d_model, cfg.norm)
    if kind.endswith("_moe"):
        p["ffn"], sp["ffn"] = moe_init(ks[1], cfg)
    else:
        d_ff = cfg.dense_d_ff if (cfg.moe and cfg.dense_d_ff) else cfg.d_ff
        p["ffn"], sp["ffn"] = mlp_init(ks[1], cfg, d_ff)
    return p, sp


# ---------------------------------------------------------------- train / prefill
def _mixer_apply(kind, p, xn, cfg, run, positions, collect_cache, cache_len=None):
    """The token-mixing sublayer. Returns (out, cache | None)."""
    if kind in ("attn_dense", "attn_moe"):
        if collect_cache:
            out, (k, v) = gqa_apply(p["attn"], xn, cfg, run, positions, return_kv=True)
            return out, _kv_to_cache(k, v, cfg, run, None, cache_len)
        return gqa_apply(p["attn"], xn, cfg, run, positions), None
    if kind in ("mla_dense", "mla_moe"):
        if collect_cache:
            out, (ckv, krope) = mla_apply(
                p["attn"], xn, cfg, run, positions, return_kv=True
            )
            if cache_len is not None and cache_len > ckv.shape[1]:
                grow = cache_len - ckv.shape[1]
                ckv = jnp.pad(ckv, [(0, 0), (0, grow), (0, 0)])
                krope = jnp.pad(krope, [(0, 0), (0, grow), (0, 0)])
            cdt = (
                jnp.bfloat16
                if run.kv_cache_dtype == "int8"
                else jnp.dtype(run.kv_cache_dtype)
            )
            return out, {"ckv": ckv.astype(cdt), "krope": krope.astype(cdt)}
        return mla_apply(p["attn"], xn, cfg, run, positions), None
    if kind == "ssd":
        if collect_cache:
            out, st = ssd_block_apply(
                p["ssd"], xn, cfg, return_state=True,
                stream_bf16=run.ssd_stream_bf16, chunk=run.ssd_chunk,
            )
            return out, st
        return ssd_block_apply(
            p["ssd"], xn, cfg, stream_bf16=run.ssd_stream_bf16,
            chunk=run.ssd_chunk,
        ), None
    if kind in ("hymba_g", "hymba_w"):
        w = _window(kind, cfg)
        if collect_cache:
            a, (k, v) = gqa_apply(
                p["attn"], xn, cfg, run, positions, window=w, return_kv=True
            )
            s, st = ssd_block_apply(
                p["ssd"], xn, cfg, return_state=True,
                stream_bf16=run.ssd_stream_bf16, chunk=run.ssd_chunk,
            )
            cache = {"attn": _kv_to_cache(k, v, cfg, run, w, cache_len), "ssm": st}
        else:
            a = gqa_apply(p["attn"], xn, cfg, run, positions, window=w)
            s = ssd_block_apply(
                p["ssd"], xn, cfg, stream_bf16=run.ssd_stream_bf16,
                chunk=run.ssd_chunk,
            )
            cache = None
        out = 0.5 * (norm_apply(p["bnorm_a"], a) + norm_apply(p["bnorm_s"], s))
        return out, cache
    raise ValueError(kind)


def _kv_to_cache(k, v, cfg: ArchConfig, run: RunConfig, window: int | None, cache_len=None):
    """Full-sequence K/V -> decode cache layout (ring-truncated for SWA,
    zero-padded to ``cache_len`` capacity for cache growth during decode)."""
    if window:
        S = k.shape[1]
        if S >= window:
            # keep the last `window` tokens; position p lands at ring slot
            # p % window (the layout gqa_decode continues to write)
            k, v = k[:, -window:], v[:, -window:]
            shift = S % window
            if shift:
                k = jnp.roll(k, shift, axis=1)
                v = jnp.roll(v, shift, axis=1)
        else:
            pad = [(0, 0), (0, window - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    elif cache_len is not None and cache_len > k.shape[1]:
        pad = [(0, 0), (0, cache_len - k.shape[1]), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    if run.kv_cache_dtype == "int8":
        from .attention import quantize_kv

        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    dt = jnp.dtype(run.kv_cache_dtype)
    return {"k": k.astype(dt), "v": v.astype(dt)}


def block_apply(
    kind: str,
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    run: RunConfig,
    positions: jax.Array,
    collect_cache: bool = False,
    cache_len: int | None = None,
):
    """Returns (x_out, aux_loss, cache|None)."""
    mix, cache = _mixer_apply(
        kind, p,
        norm_apply(p["norm1"], x, stats_only_f32=run.norm_stats_only_f32),
        cfg, run, positions, collect_cache, cache_len,
    )
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssd":
        return x, aux, cache
    xn = norm_apply(p["norm2"], x, stats_only_f32=run.norm_stats_only_f32)
    if kind.endswith("_moe"):
        y, aux = _moe_ffn(p["ffn"], xn, cfg, run)
    else:
        y = mlp_apply(p["ffn"], xn, cfg.mlp)
    return x + y, aux, cache


# ---------------------------------------------------------------- decode
def block_init_cache(kind: str, cfg: ArchConfig, run: RunConfig, batch: int, max_len: int):
    if kind in ("attn_dense", "attn_moe"):
        return gqa_init_cache(cfg, run, batch, max_len, None)
    if kind in ("mla_dense", "mla_moe"):
        return mla_init_cache(cfg, run, batch, max_len)
    if kind == "ssd":
        return ssd_init_cache(cfg, batch)
    if kind in ("hymba_g", "hymba_w"):
        return {
            "attn": gqa_init_cache(cfg, run, batch, max_len, _window(kind, cfg)),
            "ssm": ssd_init_cache(cfg, batch),
        }
    raise ValueError(kind)


def block_decode(
    kind: str,
    p: Params,
    cache,
    x: jax.Array,  # (B, 1, d)
    cfg: ArchConfig,
    run: RunConfig,
    pos: jax.Array,
):
    """Returns (x_out, new_cache)."""
    xn = norm_apply(p["norm1"], x)
    if kind in ("attn_dense", "attn_moe"):
        mix, cache = gqa_decode(p["attn"], cache, xn, cfg, run, pos)
    elif kind in ("mla_dense", "mla_moe"):
        mix, cache = mla_decode(p["attn"], cache, xn, cfg, run, pos)
    elif kind == "ssd":
        mix, cache = ssd_block_decode(p["ssd"], cache, xn, cfg)
    else:  # hymba
        a, ac = gqa_decode(
            p["attn"], cache["attn"], xn, cfg, run, pos, window=_window(kind, cfg)
        )
        s, sc = ssd_block_decode(p["ssd"], cache["ssm"], xn, cfg)
        mix = 0.5 * (norm_apply(p["bnorm_a"], a) + norm_apply(p["bnorm_s"], s))
        cache = {"attn": ac, "ssm": sc}
    x = x + mix
    if kind == "ssd":
        return x, cache
    xn = norm_apply(p["norm2"], x)
    if kind.endswith("_moe"):
        y, _ = _moe_ffn(p["ffn"], xn, cfg, run)
    else:
        y = mlp_apply(p["ffn"], xn, cfg.mlp)
    return x + y, cache
