"""Primitive layers: dual (params, specs) pytrees.

Every ``*_init`` returns two parallel pytrees: arrays and logical-axis tuples
(one logical name per array dim). The dist layer maps logical names to mesh
axes (repro/dist/sharding.py). Keeping specs structural (not attached to the
arrays) keeps everything a plain pytree for jit/scan/optimizers.

Logical axis vocabulary:
    batch seq embed heads kv_heads head_dim mlp vocab experts expert_mlp
    layers state conv qk_rope kv_lora q_lora
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]
Specs = dict[str, Any]


def dense_init(
    key,
    in_dim: int,
    out_dim: int,
    in_axis: str | None,
    out_axis: str | None,
    bias: bool = False,
    scale: float | None = None,
    dtype=jnp.float32,
) -> tuple[Params, Specs]:
    s = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    p: Params = {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * s}
    sp: Specs = {"w": (in_axis, out_axis)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        sp["b"] = (out_axis,)
    return p, sp


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, kind: str = "rmsnorm") -> tuple[Params, Specs]:
    p: Params = {"scale": jnp.ones((d,), jnp.float32)}
    sp: Specs = {"scale": ("embed",)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
        sp["bias"] = ("embed",)
    return p, sp


def norm_apply(p: Params, x: jax.Array, eps: float = 1e-6,
               stats_only_f32: bool = False) -> jax.Array:
    """RMSNorm / LayerNorm with fp32 accumulation.

    stats_only_f32=True computes the reduction statistics in f32 but applies
    the normalization in the input dtype (what fused TPU norm kernels do) —
    this keeps the backward's residual-stream gradient chain in bf16 instead
    of dragging f32 tensors through every layer (§Perf finding).
    """
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        if stats_only_f32:
            inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
            return (x - mu.astype(x.dtype)) * inv * p["scale"].astype(
                x.dtype
            ) + p["bias"].astype(x.dtype)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        if stats_only_f32:
            inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
            return x * inv * p["scale"].astype(x.dtype)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> tuple[Params, Specs]:
    p = {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}
    return p, {"table": ("vocab", "embed")}


def embed_apply(p: Params, ids: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["table"].astype(dtype), ids, axis=0)


def lm_head_apply(p: Params, x: jax.Array) -> jax.Array:
    """Project to (padded) vocab logits using the (vocab, embed) table."""
    return x @ p["table"].astype(x.dtype).T


def mlp_init(key, cfg, d_ff: int | None = None) -> tuple[Params, Specs]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        p0, s0 = dense_init(ks[0], d, ff, "embed", "mlp")
        p1, s1 = dense_init(ks[1], d, ff, "embed", "mlp")
        p2, s2 = dense_init(ks[2], ff, d, "mlp", "embed")
        return (
            {"wi": p0, "wg": p1, "wo": p2},
            {"wi": s0, "wg": s1, "wo": s2},
        )
    p0, s0 = dense_init(ks[0], d, ff, "embed", "mlp", bias=True)
    p2, s2 = dense_init(ks[2], ff, d, "mlp", "embed", bias=True)
    return {"wi": p0, "wo": p2}, {"wi": s0, "wo": s2}


def mlp_apply(p: Params, x: jax.Array, kind: str) -> jax.Array:
    from ..shardctx import constrain

    mlp_axes = ("batch", "seq", "mlp")
    if kind == "swiglu":
        h = jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wi"], x)
        return dense_apply(p["wo"], constrain(h, mlp_axes))
    h = jax.nn.gelu(dense_apply(p["wi"], x))
    return dense_apply(p["wo"], constrain(h, mlp_axes))


def stack_init(init_fn, key, n: int) -> tuple[Params, Specs]:
    """Stack ``n`` layers' params on a leading "layers" axis (for lax.scan)."""
    keys = jax.random.split(key, n)
    p0, s0 = init_fn(keys[0])
    stacked = jax.vmap(lambda k: init_fn(k)[0])(keys)
    specs = jax.tree.map(
        lambda ax: ("layers", *ax), s0, is_leaf=lambda x: isinstance(x, tuple)
    )
    return stacked, specs


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
