"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]. d_inner = 2*d_model = 4096, 64 heads of dim 64,
state 128, conv 4. No FFN sublayer (d_ff = 0 per the assignment).
"""
from repro.models.config import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=64,  # SSD heads (d_inner / head_dim); no attention heads
    n_kv_heads=64,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    layout=(("ssd", 48),),
    norm="rmsnorm",
    pos="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
    source="arXiv:2405.21060",
)

SMOKE = ARCH.scaled(
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    head_dim=32,
    vocab=512,
    layout=(("ssd", 3),),
    ssm=SSMConfig(d_state=32, d_conv=4, expand=2, head_dim=32, chunk=32),
)
