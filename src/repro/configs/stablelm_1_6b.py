"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

Fidelity note: StableLM-2 applies RoPE to 25 % of head dims; we apply full
RoPE (backbone-level simplification recorded in DESIGN.md).
"""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab=100352,
    layout=(("attn_dense", 24),),
    norm="layernorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=320,
    vocab=512,
    layout=(("attn_dense", 2),),
)
