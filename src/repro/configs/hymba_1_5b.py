"""hymba-1.5b [hybrid] — parallel attention + mamba heads [arXiv:2411.13676].

Layout: 3 global-attention layers (first / middle / last) with
sliding-window hybrid layers elsewhere, per the Hymba recipe. Meta-token
prefix is a frontend-level feature and is stubbed out (DESIGN.md).
sub_quadratic=True: SWA caches are O(window) and the SSM state is O(1), so
long_500k decode runs (the 3 global layers keep full KV).
"""
from repro.models.config import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    layout=(
        ("hymba_g", 1),
        ("hymba_w", 14),
        ("hymba_g", 1),
        ("hymba_w", 15),
        ("hymba_g", 1),
    ),
    norm="rmsnorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=10_000.0,
    window=1024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
    source="arXiv:2411.13676",
)

SMOKE = ARCH.scaled(
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    window=64,
    layout=(("hymba_g", 1), ("hymba_w", 2), ("hymba_g", 1)),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
)
