"""moonshot-v1-16b-a3b [moe] — 64 routed experts top-6
[hf:moonshotai/Moonlight-16B-A3B].
"""
from repro.models.config import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,  # per-expert hidden dim (assignment spec)
    vocab=163840,
    layout=(("attn_moe", 48),),
    norm="rmsnorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=50_000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    source="hf:moonshotai/Moonlight-16B-A3B",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=64,
    vocab=512,
    layout=(("attn_moe", 2),),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1),
)
