"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Backbone only: the vision tower is a stub — input_specs() provides
precomputed patch/text embeddings (B, S, d_model). M-RoPE uses equal
(t, h, w) position ids for the text-only stand-in.
"""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    layout=(("attn_dense", 80),),
    norm="rmsnorm",
    mlp="swiglu",
    qkv_bias=True,
    pos="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    embed_input="frames",
    source="arXiv:2409.12191",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab=512,
    mrope_sections=(6, 5, 5),
    layout=(("attn_dense", 2),),
)
