"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. Backbone only: the EnCodec frontend is a stub —
input_specs() provides precomputed frame embeddings (B, S, d_model); the
LM head predicts one 2048-way codebook (assignment spec vocab).
"""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    layout=(("attn_dense", 48),),
    norm="layernorm",
    mlp="gelu",
    pos="sinusoidal",
    embed_input="frames",
    source="arXiv:2306.05284",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab=256,
    layout=(("attn_dense", 2),),
)
