"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434]. First layer dense (d_ff 12288), 59 MLA+MoE layers.
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

ARCH = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,  # routed-expert hidden dim (assignment spec)
    vocab=102400,
    layout=(("mla_dense", 1), ("mla_moe", 59)),
    norm="rmsnorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=10_000.0,
    dense_d_ff=12288,
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    source="arXiv:2405.04434",
)

SMOKE = ARCH.scaled(
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=64,
    vocab=512,
    layout=(("mla_dense", 1), ("mla_moe", 2)),
    dense_d_ff=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=64, n_shared=1),
    mla=MLAConfig(
        q_lora_rank=64,
        kv_lora_rank=32,
        qk_nope_head_dim=32,
        qk_rope_head_dim=16,
        v_head_dim=32,
    ),
)
