"""qwen1.5-32b [dense] — MHA with QKV bias [hf:Qwen/Qwen1.5-32B]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    layout=(("attn_dense", 64),),
    norm="rmsnorm",
    mlp="swiglu",
    qkv_bias=True,
    pos="rope",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-32B",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=384,
    vocab=512,
    layout=(("attn_dense", 2),),
)
