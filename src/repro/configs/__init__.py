"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from repro.models.config import SHAPES, ArchConfig, RunConfig, ShapeConfig

from . import (
    deepseek_v2_236b,
    hymba_1_5b,
    mamba2_1_3b,
    moonshot_v1_16b,
    musicgen_medium,
    qwen1_5_32b,
    qwen2_vl_72b,
    smollm_135m,
    stablelm_1_6b,
    starcoder2_7b,
)

_MODULES = [
    hymba_1_5b,
    deepseek_v2_236b,
    moonshot_v1_16b,
    smollm_135m,
    stablelm_1_6b,
    starcoder2_7b,
    qwen1_5_32b,
    mamba2_1_3b,
    musicgen_medium,
    qwen2_vl_72b,
]

ARCHS: dict[str, ArchConfig] = {m.ARCH.name: m.ARCH for m in _MODULES}
SMOKES: dict[str, ArchConfig] = {m.ARCH.name: m.SMOKE for m in _MODULES}


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    table = SMOKES if smoke else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str | None = None) -> list[tuple[str, str]]:
    """All (arch, shape) dry-run cells, with long_500k restricted to
    sub-quadratic archs (full-attention skips recorded by the caller)."""
    out = []
    for a, cfg in ARCHS.items():
        if arch and a != arch:
            continue
        for s in SHAPES:
            if s == "long_500k" and not cfg.sub_quadratic:
                continue
            out.append((a, s))
    return out


__all__ = [
    "ARCHS",
    "SMOKES",
    "SHAPES",
    "ArchConfig",
    "RunConfig",
    "ShapeConfig",
    "cells",
    "get_arch",
    "get_shape",
]
