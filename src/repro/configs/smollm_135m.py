"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab=49152,
    layout=(("attn_dense", 30),),
    norm="rmsnorm",
    mlp="swiglu",
    pos="rope",
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

SMOKE = ARCH.scaled(
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab=512,
    layout=(("attn_dense", 3),),
)
