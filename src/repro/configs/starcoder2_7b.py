"""starcoder2-7b [dense] — GQA, RoPE [arXiv:2402.19173]."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    layout=(("attn_dense", 32),),
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    pos="rope",
    rope_theta=1_000_000.0,
    source="arXiv:2402.19173",
)

SMOKE = ARCH.scaled(
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=512,
    vocab=512,
    layout=(("attn_dense", 2),),
)
