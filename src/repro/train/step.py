"""Train-step builder: loss -> grads -> clip -> AdamW, with microbatch
gradient accumulation and bf16 compute over fp32 master params."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig, RunConfig
from ..models.model import loss_fn
from .optim import TrainState, adamw_update, clip_by_global_norm, cosine_lr


def cast_params(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), params)


def build_train_step(
    cfg: ArchConfig,
    run: RunConfig,
    *,
    accum: int = 1,
    lr_fn: Callable | None = None,
):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum`` > 1 splits the per-device batch into microbatches with a
    lax.scan accumulation (fp32 grads)."""
    compute_dtype = jnp.dtype(run.params_dtype)
    lr_fn = lr_fn or cosine_lr(run)

    def loss_of(params, batch):
        return loss_fn(cast_params(params, compute_dtype), batch, cfg, run)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state: TrainState, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = grad_fn(state.params, mb)
                gacc = jax.tree.map(lambda a, b: a + b, gacc, g)
                return (gacc, lacc + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch,
            )
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, ltot), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = ltot / accum
            metrics = {}
        grads, gnorm = clip_by_global_norm(grads)
        new_state = adamw_update(state, grads, run, lr_fn)
        out = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr_fn(state.step),
        }
        out.update({k: v for k, v in (metrics or {}).items()})
        return new_state, out

    return train_step
