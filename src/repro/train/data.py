"""Deterministic data pipeline.

Synthetic corpus: batches are a pure function of (seed, step) — restart at
step k reproduces exactly the stream a continuous run would have seen, which
makes checkpoint-restart bitwise reproducible (fault-tolerance requirement).
A file-backed mode memory-maps a token binary and shards it by host.
Prefetch runs one step ahead on a background thread.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 0
    corpus_path: str | None = None  # uint16/uint32 token binary (memmap)
    host_index: int = 0
    host_count: int = 1


def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, seed: int, step: int):
    """Markov synthetic tokens with learnable structure: a restricted
    effective vocabulary plus a strong successor bias, so smoke training
    shows a real loss decrease within tens of steps (unigram first, then
    the bigram rule)."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + np.uint64(step))
    v = cfg.vocab
    ev = min(v, 64)  # effective vocab
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, ev, batch)
    jump = rng.random((batch, seq)) < 0.1  # 10% random restarts
    rand = rng.integers(0, ev, (batch, seq))
    for t in range(seq):
        nxt = (toks[:, t] + 1) % ev
        toks[:, t + 1] = np.where(jump[:, t], rand[:, t], nxt)
    out = {}
    if cfg.embed_input == "tokens":
        out["tokens"] = jnp.asarray(toks[:, :seq])
    else:
        emb_rng = np.random.default_rng(np.uint64(seed) + 17)
        table = emb_rng.standard_normal((v, cfg.d_model), np.float32)
        out["frames"] = jnp.asarray(table[toks[:, :seq]])
    out["labels"] = jnp.asarray(toks[:, 1 : seq + 1])
    return out


class FileCorpus:
    """Memory-mapped token binary, sharded by host, sequential windows."""

    def __init__(self, path: str, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")

    def batch(self, cfg: ArchConfig, batch: int, seq: int, step: int,
              host_index: int = 0, host_count: int = 1):
        n = len(self.tokens)
        span = batch * (seq + 1)
        start = (step * host_count + host_index) * span % max(1, n - span - 1)
        window = np.asarray(self.tokens[start : start + span]).astype(np.int32)
        window = window.reshape(batch, seq + 1) % cfg.vocab
        return {
            "tokens": jnp.asarray(window[:, :seq]),
            "labels": jnp.asarray(window[:, 1:]),
        }


class Prefetcher:
    """One-step-ahead background prefetch (straggler smoothing on hosts)."""

    def __init__(self, make_batch, start_step: int, depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._make(s), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
