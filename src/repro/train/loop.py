"""Training driver: checkpoint/restart, straggler watchdog, metrics log.

Fault-tolerance model (scales to 1000+ nodes — DESIGN.md §4):
* **checkpoint/restart** — async committed checkpoints every N steps;
  auto-resume picks the latest COMMITTED step; the data pipeline is a pure
  function of step, so a restart replays the exact stream.
* **straggler mitigation** — per-step wall-clock EWMA; steps slower than
  ``straggler_factor`` x the EWMA are logged and counted (on a real cluster
  this signal feeds the reschedule/hot-spare controller; here it feeds
  metrics and tests). Host-side input prefetch decouples data hiccups.
* **elastic scaling** — restore() re-shards onto whatever mesh the loop was
  launched with (see repro/ckpt/checkpoint.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax

from ..ckpt.checkpoint import latest_step, restore, save
from ..models.config import ArchConfig, RunConfig
from ..models.model import model_init
from .data import synthetic_batch
from .optim import TrainState, init_state
from .step import build_train_step


@dataclass
class LoopConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    log_every: int = 10
    seed: int = 0
    accum: int = 1
    straggler_factor: float = 3.0
    warmup: int | None = None  # default: 5% of steps


@dataclass
class LoopResult:
    losses: list = field(default_factory=list)
    final_step: int = 0
    resumed_from: int | None = None
    straggler_steps: list = field(default_factory=list)
    wall_s: float = 0.0


def train(cfg: ArchConfig, run: RunConfig, loop: LoopConfig) -> LoopResult:
    res = LoopResult()
    params, _ = model_init(jax.random.PRNGKey(loop.seed), cfg, run)
    state = init_state(params)
    del params

    start = 0
    if loop.ckpt_dir:
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            state = restore(loop.ckpt_dir, last, state)
            start = int(state.step)
            res.resumed_from = last

    from .optim import cosine_lr

    warmup = loop.warmup if loop.warmup is not None else max(2, loop.steps // 20)
    lr_fn = cosine_lr(run, warmup=warmup, total=loop.steps)
    step_fn = jax.jit(
        build_train_step(cfg, run, accum=loop.accum, lr_fn=lr_fn),
        donate_argnums=0,
    )

    ewma = None
    t_loop = time.monotonic()
    pending_join = lambda: None
    for step in range(start, loop.steps):
        batch = synthetic_batch(cfg, loop.batch, loop.seq, loop.seed, step)
        t0 = time.monotonic()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if step > start + 2 and dt > loop.straggler_factor * ewma:
            res.straggler_steps.append((step, dt, ewma))
        res.losses.append(loss)
        if loop.log_every and step % loop.log_every == 0:
            print(
                f"step {step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms"
            )
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            pending_join()  # never more than one async save in flight
            pending_join = save(loop.ckpt_dir, step + 1, state, async_=True)
    pending_join()
    if loop.ckpt_dir:
        save(loop.ckpt_dir, loop.steps, state)
    res.final_step = loop.steps
    res.wall_s = time.monotonic() - t_loop
    return res
