"""AdamW + cosine schedule + mixed precision + ZeRO-1 state sharding.

TrainState holds fp32 master params and moments; the forward runs on a bf16
cast. With RunConfig.zero1 the master/m/v leaves are additionally sharded
over the data axes (repro/dist/sharding.zero1_shardings), cutting optimizer
bytes per chip by the DP degree — the lever that fits deepseek-v2-236B
training on 256 chips (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..models.config import RunConfig


class TrainState(NamedTuple):
    step: jax.Array  # int32 scalar
    params: Any  # fp32 master
    m: Any
    v: Any


def init_state(params) -> TrainState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), t)
    return TrainState(jnp.zeros((), jnp.int32), f32(params), zeros(params), zeros(params))


def cosine_lr(run: RunConfig, warmup: int = 100, total: int = 10_000):
    base = run.learning_rate

    def lr(step):
        warm = base * (step + 1) / warmup
        t = jnp.clip((step - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * base))

    return lr


def adamw_update(
    state: TrainState,
    grads,
    run: RunConfig,
    lr_fn=None,
) -> TrainState:
    lr = (lr_fn or cosine_lr(run))(state.step)
    b1, b2, eps, wd = run.beta1, run.beta2, run.eps, run.weight_decay
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return p, m, v

    # three passes (XLA CSEs the shared math under jit)
    params = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[0],
                          state.params, grads, state.m, state.v)
    m = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[1],
                     state.params, grads, state.m, state.v)
    v = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v)[2],
                     state.params, grads, state.m, state.v)
    return TrainState(step, params, m, v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float = 1.0):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm
