"""Training substrate: optimizer, step builder, data pipeline, loop."""
from .data import DataConfig, FileCorpus, Prefetcher, synthetic_batch
from .loop import LoopConfig, LoopResult, train
from .optim import TrainState, adamw_update, clip_by_global_norm, cosine_lr, init_state
from .step import build_train_step, cast_params

__all__ = [
    "DataConfig", "FileCorpus", "LoopConfig", "LoopResult", "Prefetcher",
    "TrainState", "adamw_update", "build_train_step", "cast_params",
    "clip_by_global_norm", "cosine_lr", "init_state", "synthetic_batch",
    "train",
]
