"""Ambient sharding context for activation constraints inside jitted code.

GSPMD propagation loses batch sharding across lax.scan carries (the dry-run
roofline exposed fully-replicated activations inside the layer loop), so the
model inserts logical-axis constraints at block boundaries. The launcher
sets the context before tracing; without a context every call is a no-op, so
single-device tests and CPU training are unaffected.

Standalone module (not inside repro.dist) to avoid import cycles; the
resolver is imported lazily at call time.
"""
from __future__ import annotations

from typing import Any

_CTX: dict[str, Any] = {"mesh": None, "rules": None}


def set_ctx(mesh, rules=None) -> None:
    _CTX["mesh"] = mesh
    _CTX["rules"] = rules


def clear_ctx() -> None:
    set_ctx(None, None)


def constrain(x, axes: tuple):
    """with_sharding_constraint by logical axes; no-op without a context."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding

    from .dist.sharding import spec_for_shape

    spec = spec_for_shape(axes, x.shape, mesh, _CTX["rules"])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
