"""Topology protocol + the wraparound ``Torus`` (DESIGN.md §3).

The paper defines DPM on a 2-D mesh; the deployments the ROADMAP targets run
on wraparound tori (TPU-pod ICI). Everything geometric that the routing
functions, planners, simulator, and kernels need is expressed through the
``Topology`` protocol below, with ``MeshGrid`` and ``Torus`` as the two
implementations:

* **labeling** — the boustrophedon snake label order. On the torus the wrap
  link from the last to the first snake node closes the path into a
  Hamiltonian cycle, so label-ordered (dual-path) routing stays valid: mesh
  links are a subset of torus links, and the label-monotone progress argument
  only needs the snake successor to be a neighbor.
* **delta / distance** — the signed shortest per-dimension displacement. On
  a torus each dimension independently takes the shorter way around the
  ring; an exact half-way tie breaks toward the negative direction, matching
  the kernels' ``((d + size//2) % size) - size//2`` formula bit for bit.
* **neighbors / normalize** — wrap links and coordinate canonicalization.

The 8-partition geometry of Definitions 1-3 generalizes through ``delta``:
partition membership is the sign pattern of the shortest displacement, which
on the torus makes each basic partition the wedge of nodes whose minimal
route leaves the source in that direction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from .grid import Coord, MeshGrid, grid


@runtime_checkable
class Topology(Protocol):
    """Structural interface shared by MeshGrid and Torus."""

    kind: str
    wrap: bool
    n: int

    @property
    def rows(self) -> int: ...

    @property
    def num_nodes(self) -> int: ...

    def label(self, x: int, y: int) -> int: ...

    def unlabel(self, lab: int) -> Coord: ...

    def row_major(self, x: int, y: int) -> int: ...

    def idx(self, c: Coord) -> int: ...

    def normalize(self, x: int, y: int) -> Coord: ...

    def neighbors(self, x: int, y: int) -> list[Coord]: ...

    def delta(self, a: Coord, b: Coord) -> Coord: ...

    def distance(self, a: Coord, b: Coord) -> int: ...


def ring_delta(d: int, size: int) -> int:
    """Signed shortest displacement on a ring of ``size`` nodes.

    Result lies in [-size//2, (size-1)//2]; an exact half-way tie (even
    ``size``) goes negative — the same convention as the Pallas kernel's
    wrapped-distance formula, so host and device partitions always agree.
    """
    if size <= 1:
        return 0
    return (d + size // 2) % size - size // 2


@dataclass(frozen=True)
class Torus(MeshGrid):
    """n x m wraparound torus.

    Inherits the boustrophedon labeling and vectorized helpers from
    ``MeshGrid``; overrides the geometric methods with wraparound semantics.
    ``Torus(n, 1)`` degenerates to a 1-D ring of ``n`` ranks (used by
    ``repro.dist.multicast.dp_broadcast_schedule`` for a data-parallel axis).
    """

    kind = "torus"
    wrap = True

    def normalize(self, x: int, y: int) -> Coord:
        return x % self.n, y % self.rows

    def neighbors(self, x: int, y: int) -> list[Coord]:
        out: list[Coord] = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            v = self.normalize(x + dx, y + dy)
            if v != (x, y) and v not in out:  # size-1/2 rings: no self/dup links
                out.append(v)
        return out

    def delta(self, a: Coord, b: Coord) -> Coord:
        return (
            ring_delta(b[0] - a[0], self.n),
            ring_delta(b[1] - a[1], self.rows),
        )

    def manhattan(self, a: Coord, b: Coord) -> int:  # type: ignore[override]
        """Toroidal distance (shadows the mesh staticmethod on instances so
        no call site can accidentally get non-wrapped distances)."""
        return self.distance(a, b)


@functools.lru_cache(maxsize=None)
def _torus(n: int, m: int) -> Torus:
    return Torus(n, m)


def torus(n: int, m: int | None = None) -> Torus:
    """Interned torus factory (normalized like ``grid``)."""
    return _torus(n, n if m is None else m)


_FACTORIES = {"mesh": grid, "torus": torus}


def register_topology(kind: str, factory) -> None:
    """Register a topology factory under ``kind``.

    ``factory(n, m, *params)`` must return an interned instance whose
    ``kind``/``params`` attributes round-trip through ``make_topology`` —
    that tuple is the planner cache key. Registering lets new topology
    modules (e.g. ``core.topo3d``) plug in without editing this file;
    re-registering an existing kind raises to keep cache keys unambiguous.
    """
    if kind in _FACTORIES:
        raise ValueError(f"topology kind {kind!r} is already registered")
    _FACTORIES[kind] = factory


def registered_topology_kinds() -> tuple[str, ...]:
    return tuple(sorted(_FACTORIES))


def make_topology(
    kind: str, n: int, m: int | None = None, faults: tuple = (),
    params: tuple = (),
) -> MeshGrid:
    """Construct a topology from its cache key (kind, n, m, faults, params).

    ``faults`` is an iterable of broken (u, v) links; when non-empty the
    base topology is wrapped in a ``FaultyTopology`` (interned, like the
    bases), which is what keys the planner cache for degraded plans.
    ``params`` are the extra factory arguments beyond (n, m) — empty for
    mesh/torus; depth/weight-class tuples for the ``topo3d`` kinds.
    """
    try:
        factory = _FACTORIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown topology kind {kind!r}; registered kinds: "
            f"{', '.join(registered_topology_kinds())}"
        ) from None
    base = factory(n, m, *params)
    if not faults:
        return base
    from .routefn import faulty  # routefn imports grid only; no cycle

    return faulty(base, tuple(faults))
