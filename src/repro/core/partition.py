"""Destination-set partitioning: Definitions 1-3 and Algorithm 1 (DPM).

The destination set partition problem (Section III.A) is an exact weighted
set-cover instance: choose disjoint partitions covering all destinations with
minimum total routing cost. DPM is the paper's greedy heuristic over a
restricted candidate family: the 8 basic geometric partitions P0..P7 around
the source plus merges of up to 3 *consecutive* basic partitions.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

from .algo import CostModel, get_cost_model
from .grid import Coord, MeshGrid
from .routefn import provider_for

# The paper's 2-D wedge order, counter-clockwise from the upper-right
# quadrant (Fig. 2a), as (sign(dx), sign(dy)) patterns:
# P0 (+,+)  P1 (0,+)  P2 (-,+)  P3 (-,0)  P4 (-,-)  P5 (0,-)  P6 (+,-)  P7 (+,0)
_RING2: tuple[tuple[int, int], ...] = (
    (1, 1), (0, 1), (-1, 1), (-1, 0), (-1, -1), (0, -1), (1, -1), (1, 0),
)


@functools.lru_cache(maxsize=None)
def wedge_patterns(ndim: int) -> tuple[tuple[int, ...], ...]:
    """Canonical ordered sign patterns of the basic partitions in ``ndim``
    dimensions: every non-zero pattern in {-1, 0, +1}^ndim.

    2-D: the paper's 8 wedges P0..P7 in the order above. 3-D: 26 wedges —
    the dz=0 ring first (the 2-D order, so a flat destination set partitions
    identically to the 2-D case), then the dz=+1 block of 9 (the 8 ring
    patterns followed by the (0,0,+1) pole), then the dz=-1 block. This is
    the order the dpm_cost kernels' partition-membership tables are built in
    (``kernels/dpm_cost``) — keep them in lockstep.
    """
    if ndim == 2:
        return _RING2
    if ndim == 3:
        pats = [(sx, sy, 0) for sx, sy in _RING2]
        for sz in (1, -1):
            pats += [(sx, sy, sz) for sx, sy in _RING2]
            pats.append((0, 0, sz))
        return tuple(pats)
    raise ValueError(f"unsupported dimensionality {ndim}")


@functools.lru_cache(maxsize=None)
def _pattern_index(ndim: int) -> dict[tuple[int, ...], int]:
    return {p: i for i, p in enumerate(wedge_patterns(ndim))}


def num_wedges(topo: MeshGrid | None, src: Coord | None = None) -> int:
    """Number of basic partitions for a topology (8 in 2-D, 26 in 3-D)."""
    ndim = len(src) if topo is None else len(topo.from_idx(0))
    return len(wedge_patterns(ndim))


@functools.lru_cache(maxsize=None)
def candidate_ids_for(np_: int, max_merge: int = 3) -> tuple[tuple[int, ...], ...]:
    """DPM's candidate family over ``np_`` basic partitions: singles plus
    merges of up to ``max_merge`` cyclically *consecutive* partitions."""
    out: list[tuple[int, ...]] = [(i,) for i in range(np_)]
    for k in range(2, max_merge + 1):
        out += [tuple((i + j) % np_ for j in range(k)) for i in range(np_)]
    return tuple(out)


# 2-D candidate index sets: 8 singles, 8 consecutive pairs, 8 triples.
SINGLE_IDS: list[tuple[int, ...]] = [(i,) for i in range(8)]
PAIR_IDS: list[tuple[int, ...]] = [(i, (i + 1) % 8) for i in range(8)]
TRIPLE_IDS: list[tuple[int, ...]] = [(i, (i + 1) % 8, (i + 2) % 8) for i in range(8)]
ALL_CANDIDATE_IDS: list[tuple[int, ...]] = SINGLE_IDS + PAIR_IDS + TRIPLE_IDS


def basic_partitions(
    src: Coord, dests: list[Coord], topo: MeshGrid | None = None
) -> list[list[Coord]]:
    """Split destinations into the basic partitions around ``src``.

    Membership is the sign pattern of the signed shortest displacement from
    the source — 8 wedges P0..P7 in 2-D (counter-clockwise from the
    upper-right quadrant, Fig. 2a), 26 in 3-D (``wedge_patterns``).

    Without ``topo`` (or on a mesh) the displacement is the plain coordinate
    difference, reproducing the paper's geometry; edge/corner sources simply
    leave the out-of-mesh partitions empty. On a torus ``topo.delta`` takes
    the shorter way around each ring, so each partition is the wedge of
    nodes whose minimal route leaves the source in that direction
    (DESIGN.md §3). On a chiplet package the delta stays geometric, so the
    8 wedges apply unchanged even though routes cross declared boundaries.
    """
    ndim = len(src)
    index = _pattern_index(ndim)
    parts: list[list[Coord]] = [[] for _ in range(len(index))]
    for d in dests:
        if topo is None:
            dv = tuple(d[k] - src[k] for k in range(ndim))
        else:
            dv = topo.delta(src, d)
        sign = tuple((x > 0) - (x < 0) for x in dv)
        i = index.get(sign)
        if i is not None:  # all-zero pattern == src: already "delivered"
            parts[i].append(d)
    return parts


@dataclass
class PartitionCost:
    """Cost record for one candidate partition (Definitions 1-2).

    Costs are priced by a ``CostModel`` (repro.core.algo); under the default
    hop-count model they are the paper's integer hop counts.
    """

    ids: tuple[int, ...]
    dests: list[Coord]
    rep: Coord | None  # representative node R (Definition 1)
    cost_mu: float  # C_t: multiple unicast from R
    cost_dp: float  # C_p: dual-path from R
    source_leg: float  # S -> R XY leg, priced by the model
    mode: str  # "MU" | "DP" — the cheaper of the two

    def cost(self, include_source_leg: bool) -> float:
        base = min(self.cost_mu, self.cost_dp)
        return base + (self.source_leg if include_source_leg else 0)


def representative(g: MeshGrid, src: Coord, dests: list[Coord]) -> Coord:
    """Definition 1: nearest destination to the source (topology distance —
    on a degraded topology the BFS shortest-path distance, so the
    representative choice adapts to faults).

    Ties broken by smallest boustrophedon label for determinism.
    """
    return min(dests, key=lambda d: (g.distance(src, d), g.label(*d)))


def candidate_cost(
    g: MeshGrid,
    src: Coord,
    ids: tuple[int, ...],
    dests: list[Coord],
    cost_model: CostModel | str | None = None,
) -> PartitionCost:
    """Definition 2: C = min(C_t, C_p), measured from the representative R.

    Under the default hop-count model C_t = sum of Manhattan(R, d) and C_p =
    dual-path hop count from R, exactly as printed; any registered
    ``CostModel`` (name or instance) re-prices both plus the S->R leg. When
    the two tie, MU is preferred (the paper: "the overhead of computing D_H,
    D_L is eliminated using MU").

    All three terms are priced on hop sequences from the topology's route
    provider (``routefn.provider_for``): on a degraded topology the S->R leg
    and every C_t/C_p route detour around broken links, so Algorithm 1's
    merge decisions see the fault set — the dynamic, global-view behaviour
    the paper claims over static partitioning.
    """
    cm = get_cost_model(cost_model)
    if not dests:
        return PartitionCost(ids, [], None, 0, 0, 0, "MU")
    rep = representative(g, src, dests)
    rest = [d for d in dests if d != rep]
    cost_mu = cm.multi_unicast_cost(g, rep, rest)
    cost_dp = cm.dual_path_cost(g, rep, rest)
    source_leg = cm.route_cost(g, provider_for(g).unicast(g, src, rep))
    mode = "MU" if cost_mu <= cost_dp else "DP"
    return PartitionCost(ids, list(dests), rep, cost_mu, cost_dp, source_leg, mode)


@dataclass
class DPMResult:
    """Final partition set I with per-partition routing decisions."""

    partitions: list[PartitionCost]
    iterations: int  # greedy merge iterations taken (paper: converges <= 4)
    savings_trace: list[tuple[tuple[int, ...], float]] = field(default_factory=list)

    def total_cost(self, include_source_leg: bool = True) -> float:
        return sum(p.cost(include_source_leg) for p in self.partitions)


def dpm_partition(
    g: MeshGrid,
    src: Coord,
    dests: list[Coord],
    include_source_leg: bool = True,
    max_merge: int = 3,
    cost_model: CostModel | str | None = None,
) -> DPMResult:
    """Algorithm 1: Dynamic Partition Merging.

    ``include_source_leg`` controls whether the S->R XY leg is counted inside
    C_i (see DESIGN.md §2 — Definition 2 as printed excludes it; the stated
    objective function includes it; default True).
    ``max_merge`` is the paper's limit of 3 consecutive partitions.
    ``g`` may be a MeshGrid or a Torus; all distances, partitions, and
    routes follow the topology.
    ``cost_model`` is the objective the merge loop optimizes — the paper's
    hop counting by default; any registered model (e.g. "energy") re-prices
    every candidate, which is the lever DPM-E pulls (DESIGN.md §6).
    """
    cm = get_cost_model(cost_model)
    parts = basic_partitions(src, dests, g)
    np_ = len(parts)

    candidate_ids = list(candidate_ids_for(np_, max_merge))

    costs: dict[tuple[int, ...], PartitionCost] = {}
    for ids in candidate_ids:
        union: list[Coord] = []
        for i in ids:
            union.extend(parts[i])
        costs[ids] = candidate_cost(g, src, ids, union, cm)

    # Definition 3: saving of each merged candidate vs its components.
    savings: dict[tuple[int, ...], float] = {}
    for ids in candidate_ids:
        if len(ids) == 1:
            continue
        if not costs[ids].dests:
            continue
        merged = costs[ids].cost(include_source_leg)
        split = sum(costs[(i,)].cost(include_source_leg) for i in ids)
        savings[ids] = max(0, split - merged)

    chosen: list[tuple[int, ...]] = []
    iterations = 0
    trace: list[tuple[tuple[int, ...], int]] = []
    while True:
        best_ids, best_a = None, 0
        for ids, a in savings.items():
            if a <= 0:
                continue
            # tie-break: fewer merged partitions first, then smallest index.
            if (
                best_ids is None
                or a > best_a
                or (a == best_a and (len(ids), ids) < (len(best_ids), best_ids))
            ):
                best_ids, best_a = ids, a
        if best_ids is None:
            break
        iterations += 1
        chosen.append(best_ids)
        trace.append((best_ids, best_a))
        covered = set(best_ids)
        for ids in list(savings):
            if covered & set(ids):
                savings[ids] = 0

    covered: set[int] = set()
    for ids in chosen:
        covered |= set(ids)

    final: list[PartitionCost] = [costs[ids] for ids in chosen]
    # Leftover basic partitions that did not take part in any merge.
    for i in range(np_):
        if i not in covered and parts[i]:
            final.append(costs[(i,)])
    return DPMResult(final, iterations, trace)


def brute_force_partition(
    g: MeshGrid,
    src: Coord,
    dests: list[Coord],
    include_source_leg: bool = True,
    cost_model: CostModel | str | None = None,
) -> tuple[float, list[tuple[int, ...]]]:
    """Exact minimum over DPM's candidate family (exponential; tests only).

    Enumerates every exact cover of the non-empty basic partitions by
    candidate index sets and returns (min cost, chosen ids). This is the
    optimum of the *restricted* set-cover the paper's heuristic addresses,
    under whichever ``cost_model`` prices the candidates.
    """
    cm = get_cost_model(cost_model)
    parts = basic_partitions(src, dests, g)
    candidates = candidate_ids_for(len(parts))
    nonempty = frozenset(i for i in range(len(parts)) if parts[i])
    costs: dict[tuple[int, ...], float] = {}
    for ids in candidates:
        union: list[Coord] = []
        for i in ids:
            union.extend(parts[i])
        costs[ids] = candidate_cost(g, src, ids, union, cm).cost(include_source_leg)

    best = (float("inf"), [])

    def rec(remaining: frozenset[int], acc_cost: int, acc: list[tuple[int, ...]]):
        nonlocal best
        if acc_cost >= best[0]:
            return
        if not remaining:
            best = (acc_cost, list(acc))
            return
        pivot = min(remaining)
        for ids in candidates:
            s = set(ids) & nonempty
            if pivot not in s or not s <= remaining:
                continue
            acc.append(ids)
            rec(remaining - s, acc_cost + costs[ids], acc)
            acc.pop()

    rec(nonempty, 0, [])
    return best
