"""3-D mesh/torus and two-level chiplet-package topologies (DESIGN.md §11).

The paper defines DPM on a 2-D mesh; the fabrics the ROADMAP targets are
3-D tori (TPU-pod ICI, stacked dies with TSV pillars) and chiplet packages
(per-chiplet NoC meshes stitched by an interposer NoI). All three shapes
here implement the ``Topology`` protocol, so the planner cache,
``FaultyTopology`` wrapping, registry capability filtering, both
simulators, and the telemetry link indexing apply unchanged:

* ``Mesh3D`` / ``Torus3D`` — nx x ny x nz grids with 6-port routers. The
  snake label order is the per-layer 2-D boustrophedon with every odd
  layer traversed in *reverse*: consecutive labels inside a layer are the
  2-D snake (a neighbor step), and the layer boundary lands on the same
  (x, y) of the adjacent layer (a z-link) — a Hamiltonian path, so
  label-monotone dual-path routing stays valid exactly as on the 2-D
  mesh. ``delta`` is the signed per-dimension shortest displacement with
  the kernels' half-way tie-break on the torus. TSV z-links carry a
  ``z_weight`` price class (>= 1.0) that the weighted cost path prices.
* ``ChipletPackage`` — cx x cy chiplets of cw x ch routers each, in one
  global coordinate frame. Within-chiplet links form the full 2-D mesh;
  inter-chiplet (NoI) links exist only through declared boundary routers
  (``h_rows`` local rows for east-west crossings, ``v_cols`` local cols
  for north-south) and carry the ``noi_weight`` price class. All links
  are unit x/y steps, so routers keep 4 ports and the 2-D directed-link
  convention; ``distance`` is BFS over the sparse link set and routes go
  through the BFS provider (``needs_bfs_routes``). The snake is a
  two-level boustrophedon — chiplets in chiplet-level snake order, each
  traversed corner-to-corner by a serpentine whose crossings land on
  boundary routers (validated at construction) — again a Hamiltonian
  path, so the dual-path label argument carries over.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass

import numpy as np

from .grid import MeshGrid
from .topology import register_topology, ring_delta

Coord3 = tuple[int, int, int]

# canonical 3-D direction order (+x, -x, +y, -y, +z, -z): extends the 2-D
# (+x, -x, +y, -y) prefix so planar link ids keep their relative order
DIRS3 = ((1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1))
_DIR_OF3 = {d: i for i, d in enumerate(DIRS3)}


@dataclass(frozen=True)
class Mesh3D:
    """nx x ny x nz 3-D mesh with 6-port routers and weighted TSV z-links.

    Protocol mapping: ``n`` is the x extent; ``rows = ny * nz`` so the
    ``num_nodes == rows * n`` invariant (telemetry heatmaps, kernel node
    numbering ``idx = (z*ny + y)*nx + x``) holds with no 2-D special
    cases downstream.
    """

    n: int  # x extent
    m: int  # y extent
    d: int  # z extent (layers)
    z_weight: float = 1.0  # TSV price class (>= 1.0; 1.0 = uniform)

    kind = "mesh3d"
    wrap = False
    ports = 6

    def __post_init__(self):
        if min(self.n, self.m, self.d) < 1:
            raise ValueError("Mesh3D dimensions must be positive")
        if self.z_weight < 1.0:
            raise ValueError("z_weight must be >= 1.0")

    @property
    def params(self) -> tuple:
        return (self.d, self.z_weight)

    @property
    def rows(self) -> int:
        return self.m * self.d

    @property
    def num_nodes(self) -> int:
        return self.n * self.m * self.d

    # -- labeling -----------------------------------------------------------
    def _label2(self, x: int, y: int) -> int:
        return y * self.n + (x if y % 2 == 0 else self.n - x - 1)

    def label(self, x: int, y: int, z: int) -> int:
        """Layered boustrophedon: odd layers traverse the 2-D snake in
        reverse, so the path crosses layers on a single z-link."""
        nn = self.n * self.m
        s = self._label2(x, y)
        return z * nn + (s if z % 2 == 0 else nn - 1 - s)

    def unlabel(self, lab: int) -> Coord3:
        nn = self.n * self.m
        z, s = divmod(lab, nn)
        if z % 2 == 1:
            s = nn - 1 - s
        y, r = divmod(s, self.n)
        x = r if y % 2 == 0 else self.n - r - 1
        return x, y, z

    def row_major(self, x: int, y: int, z: int) -> int:
        return (z * self.m + y) * self.n + x

    def idx(self, c: Coord3) -> int:
        return (c[2] * self.m + c[1]) * self.n + c[0]

    def from_idx(self, i: int) -> Coord3:
        r, x = divmod(i, self.n)
        z, y = divmod(r, self.m)
        return x, y, z

    # -- geometry -----------------------------------------------------------
    def in_bounds(self, x: int, y: int, z: int) -> bool:
        return 0 <= x < self.n and 0 <= y < self.m and 0 <= z < self.d

    def normalize(self, x: int, y: int, z: int) -> Coord3:
        return x, y, z

    def neighbors(self, x: int, y: int, z: int) -> list[Coord3]:
        out = []
        for dx, dy, dz in DIRS3:
            v = (x + dx, y + dy, z + dz)
            if self.in_bounds(*v):
                out.append(v)
        return out

    def delta(self, a: Coord3, b: Coord3) -> Coord3:
        return b[0] - a[0], b[1] - a[1], b[2] - a[2]

    def distance(self, a: Coord3, b: Coord3) -> int:
        return sum(abs(d) for d in self.delta(a, b))

    def direction(self, u: Coord3, v: Coord3) -> int:
        d = _DIR_OF3.get(tuple(self.delta(u, v)))
        if d is None:
            raise ValueError(f"{u}->{v} is not a single-hop link")
        return d

    def dir_delta(self, d: int) -> Coord3:
        return DIRS3[d]

    def link_weight(self, u: Coord3, v: Coord3) -> float:
        return self.z_weight if u[2] != v[2] else 1.0

    def nodes(self) -> list[Coord3]:
        return [self.from_idx(i) for i in range(self.num_nodes)]

    # -- vectorized helpers -------------------------------------------------
    def all_labels(self) -> np.ndarray:
        """(rows, n) = (ny*nz, nx) array of snake labels in idx layout."""
        out = np.zeros((self.rows, self.n), dtype=np.int64)
        for i in range(self.num_nodes):
            x, y, z = self.from_idx(i)
            out[z * self.m + y, x] = self.label(x, y, z)
        return out

    def label_table(self) -> np.ndarray:
        """label -> (x, y, z), shape (num_nodes, 3)."""
        out = np.zeros((self.num_nodes, 3), dtype=np.int32)
        for i in range(self.num_nodes):
            c = self.from_idx(i)
            out[self.label(*c)] = c
        return out


@dataclass(frozen=True)
class Torus3D(Mesh3D):
    """nx x ny x nz wraparound 3-D torus (shortest-way-around deltas with
    the kernels' half-way tie-break, per dimension independently)."""

    kind = "torus3d"
    wrap = True

    def normalize(self, x: int, y: int, z: int) -> Coord3:
        return x % self.n, y % self.m, z % self.d

    def neighbors(self, x: int, y: int, z: int) -> list[Coord3]:
        out: list[Coord3] = []
        for dx, dy, dz in DIRS3:
            v = self.normalize(x + dx, y + dy, z + dz)
            if v != (x, y, z) and v not in out:  # size-1/2 rings
                out.append(v)
        return out

    def delta(self, a: Coord3, b: Coord3) -> Coord3:
        return (
            ring_delta(b[0] - a[0], self.n),
            ring_delta(b[1] - a[1], self.m),
            ring_delta(b[2] - a[2], self.d),
        )


def _col_serpentine(W: int, H: int) -> list[tuple]:
    """Column-by-column Hamiltonian path (0,0) -> (W-1, 0); W even keeps
    the exit on the entry row."""
    path = []
    for j in range(W):
        ys = range(H) if j % 2 == 0 else range(H - 1, -1, -1)
        path.extend((j, y) for y in ys)
    return path


def _row_serpentine(W: int, H: int) -> list[tuple]:
    """Row-by-row Hamiltonian path (0,0) -> (0, H-1); H even keeps the
    exit on the entry column."""
    path = []
    for i in range(H):
        xs = range(W) if i % 2 == 0 else range(W - 1, -1, -1)
        path.extend((x, i) for x in xs)
    return path


def _comb(W: int, H: int) -> list[tuple]:
    """Hamiltonian path (W-1, H-1) -> (0, H-1) for even W: up the east
    column, then a column serpentine over the remaining odd count of
    columns (ends on the bottom row)."""
    path = [(W - 1, y) for y in range(H - 1, -1, -1)]
    for j in range(W - 2, -1, -1):
        ys = range(H) if (W - 2 - j) % 2 == 0 else range(H - 1, -1, -1)
        path.extend((j, y) for y in ys)
    return path


def _flip(path: list[tuple], W: int, H: int, fx: bool, fy: bool):
    return [
        (W - 1 - x if fx else x, H - 1 - y if fy else y) for x, y in path
    ]


@dataclass(frozen=True)
class ChipletPackage:
    """cx x cy chiplets of cw x ch routers with an interposer NoI.

    Global coordinates (x, y) over a (cx*cw) x (cy*ch) frame; ``n``/``m``
    are the *global* extents so the protocol invariants (idx = y*n + x,
    num_nodes = rows*n) match the 2-D mesh. ``params`` round-trips the
    chiplet grid and boundary declaration through ``make_topology``.
    """

    n: int  # global columns = cx * chiplet width
    m: int  # global rows = cy * chiplet height
    cx: int  # chiplets per package row
    cy: int  # chiplet rows
    noi_weight: float = 2.0  # interposer (NoI) link price class
    h_rows: tuple = None  # local rows carrying east-west NoI links
    v_cols: tuple = None  # local cols carrying north-south NoI links

    kind = "chiplet"
    wrap = False
    ports = 4  # all links are unit x/y steps in the global frame
    needs_bfs_routes = True  # dimension-ordered routes may cross gaps

    def __post_init__(self):
        if self.n % self.cx or self.m % self.cy:
            raise ValueError(
                f"global {self.n}x{self.m} does not tile into "
                f"{self.cx}x{self.cy} chiplets"
            )
        cw, ch = self.cw, self.ch
        if cw % 2 or ch % 2:
            raise ValueError(
                "chiplet extents must be even (the two-level snake needs "
                f"corner-preserving serpentines); got {cw}x{ch}"
            )
        if self.noi_weight < 1.0:
            raise ValueError("noi_weight must be >= 1.0")
        if self.h_rows is None:
            object.__setattr__(self, "h_rows", (0, ch - 1))
        if self.v_cols is None:
            object.__setattr__(self, "v_cols", (0, cw - 1))
        hr, vc = tuple(self.h_rows), tuple(self.v_cols)
        if any(r < 0 or r >= ch for r in hr) or any(
            c < 0 or c >= cw for c in vc
        ):
            raise ValueError("boundary routers outside the chiplet extent")
        object.__setattr__(self, "h_rows", hr)
        object.__setattr__(self, "v_cols", vc)
        # the two-level snake crosses east-west at local rows 0 (rightward
        # chiplet rows) / ch-1 (leftward rows) and north-south at local
        # col 0 — those routers must be declared boundary routers or the
        # label path is broken (conformance tests pin successor-is-neighbor)
        if self.cx > 1 and 0 not in hr:
            raise ValueError("snake needs local row 0 in h_rows")
        if self.cx > 1 and self.cy > 1 and ch - 1 not in hr:
            raise ValueError("snake needs local row ch-1 in h_rows")
        if self.cy > 1 and 0 not in vc:
            raise ValueError("snake needs local col 0 in v_cols")

    @property
    def cw(self) -> int:
        return self.n // self.cx

    @property
    def ch(self) -> int:
        return self.m // self.cy

    @property
    def params(self) -> tuple:
        return (self.cx, self.cy, self.noi_weight, self.h_rows, self.v_cols)

    @property
    def rows(self) -> int:
        return self.m

    @property
    def num_nodes(self) -> int:
        return self.n * self.m

    # -- labeling: two-level boustrophedon ----------------------------------
    @functools.cached_property
    def _snake(self) -> list[tuple]:
        """Global snake path: chiplet-level boustrophedon with corner-
        preserving serpentines (the labeling proof sketch is DESIGN.md
        §11). Rightward rows run column-serpentines NW -> NE (crossing
        east at local row 0) and end with a row-serpentine NW -> SW
        (crossing south at local col 0); leftward rows open with a
        row-serpentine NW -> SW (crossing west at local row ch-1),
        continue with x/y-flipped column-serpentines SE -> SW, and end
        with a comb path SE -> SW (crossing south at local col 0). Every
        chiplet-interior step is a mesh link and every crossing lands on
        a declared boundary router, so the path is Hamiltonian over the
        package's link set."""
        cw, ch = self.cw, self.ch
        path: list[tuple] = []
        for cj in range(self.cy):
            rightward = cj % 2 == 0
            order = (
                range(self.cx) if rightward else range(self.cx - 1, -1, -1)
            )
            for k, ci in enumerate(order):
                first, last = k == 0, k == self.cx - 1
                if rightward:
                    local = (
                        _row_serpentine(cw, ch) if last
                        else _col_serpentine(cw, ch)
                    )
                elif first:
                    # entered from above at the NW corner (crossing came
                    # down local col 0); exits SW for the westward hop
                    # (or the southward one when cx == 1)
                    local = _row_serpentine(cw, ch)
                elif last:
                    local = _comb(cw, ch)
                else:
                    local = _flip(
                        _col_serpentine(cw, ch), cw, ch, fx=True, fy=True
                    )
                path.extend(
                    (ci * cw + lx, cj * ch + ly) for lx, ly in local
                )
        assert len(path) == self.num_nodes
        return path

    @functools.cached_property
    def _label_of(self) -> dict:
        return {c: i for i, c in enumerate(self._snake)}

    def label(self, x: int, y: int) -> int:
        return self._label_of[(x, y)]

    def unlabel(self, lab: int) -> tuple:
        return self._snake[lab]

    def row_major(self, x: int, y: int) -> int:
        return y * self.n + x

    def idx(self, c: tuple) -> int:
        return c[1] * self.n + c[0]

    def from_idx(self, i: int) -> tuple:
        y, x = divmod(i, self.n)
        return x, y

    # -- geometry -----------------------------------------------------------
    def in_bounds(self, x: int, y: int) -> bool:
        return 0 <= x < self.n and 0 <= y < self.m

    def normalize(self, x: int, y: int) -> tuple:
        return x, y

    def chiplet_of(self, c: tuple) -> tuple:
        return c[0] // self.cw, c[1] // self.ch

    def is_noi(self, u: tuple, v: tuple) -> bool:
        """True when u-v is an inter-chiplet (interposer) link."""
        return self.chiplet_of(u) != self.chiplet_of(v)

    def _has_link(self, u: tuple, v: tuple) -> bool:
        if not self.is_noi(u, v):
            return True
        if u[1] == v[1]:  # east-west crossing at a boundary row
            return u[1] % self.ch in self.h_rows
        return u[0] % self.cw in self.v_cols  # north-south crossing

    def neighbors(self, x: int, y: int) -> list[tuple]:
        out = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            v = (x + dx, y + dy)
            if self.in_bounds(*v) and self._has_link((x, y), v):
                out.append(v)
        return out

    def delta(self, a: tuple, b: tuple) -> tuple:
        """Geometric displacement (not a link count): partition wedges
        stay the paper's 8 sign patterns over the global frame."""
        return b[0] - a[0], b[1] - a[1]

    @functools.cached_property
    def _dist(self) -> np.ndarray:
        """All-pairs BFS hop counts over the sparse link set."""
        nn = self.num_nodes
        dist = np.full((nn, nn), -1, dtype=np.int32)
        for s in range(nn):
            dist[s, s] = 0
            dq = deque([self.from_idx(s)])
            while dq:
                u = dq.popleft()
                du = dist[s, self.idx(u)]
                for v in self.neighbors(*u):
                    vi = self.idx(v)
                    if dist[s, vi] < 0:
                        dist[s, vi] = du + 1
                        dq.append(v)
        return dist

    def distance(self, a: tuple, b: tuple) -> int:
        return int(self._dist[self.idx(a), self.idx(b)])

    def direction(self, u: tuple, v: tuple) -> int:
        d = {(1, 0): 0, (-1, 0): 1, (0, 1): 2, (0, -1): 3}.get(
            self.delta(u, v)
        )
        if d is None or not self._has_link(u, v):
            raise ValueError(f"{u}->{v} is not a single-hop link")
        return d

    def dir_delta(self, d: int) -> tuple:
        return ((1, 0), (-1, 0), (0, 1), (0, -1))[d]

    def link_weight(self, u: tuple, v: tuple) -> float:
        return self.noi_weight if self.is_noi(u, v) else 1.0

    def nodes(self) -> list[tuple]:
        return [self.from_idx(i) for i in range(self.num_nodes)]

    def all_labels(self) -> np.ndarray:
        out = np.zeros((self.m, self.n), dtype=np.int64)
        for i, (x, y) in enumerate(self._snake):
            out[y, x] = i
        return out

    def label_table(self) -> np.ndarray:
        return np.array(self._snake, dtype=np.int32)


@functools.lru_cache(maxsize=None)
def _mesh3d(n: int, m: int, d: int, z_weight: float) -> Mesh3D:
    return Mesh3D(n, m, d, z_weight)


def mesh3d(n: int, m: int | None = None, d: int | None = None,
           z_weight: float = 1.0) -> Mesh3D:
    """Interned 3-D mesh factory (``m``/``d`` default to ``n``)."""
    m = n if m is None else m
    return _mesh3d(n, m, m if d is None else d, float(z_weight))


@functools.lru_cache(maxsize=None)
def _torus3d(n: int, m: int, d: int, z_weight: float) -> Torus3D:
    return Torus3D(n, m, d, z_weight)


def torus3d(n: int, m: int | None = None, d: int | None = None,
            z_weight: float = 1.0) -> Torus3D:
    """Interned 3-D torus factory (``m``/``d`` default to ``n``)."""
    m = n if m is None else m
    return _torus3d(n, m, m if d is None else d, float(z_weight))


@functools.lru_cache(maxsize=None)
def _chiplet(n, m, cx, cy, noi_weight, h_rows, v_cols) -> ChipletPackage:
    return ChipletPackage(n, m, cx, cy, noi_weight, h_rows, v_cols)


def chiplet(n: int, m: int | None = None, cx: int = 2, cy: int | None = None,
            noi_weight: float = 2.0, h_rows: tuple | None = None,
            v_cols: tuple | None = None) -> ChipletPackage:
    """Interned chiplet-package factory over *global* extents (n, m)."""
    m = n if m is None else m
    cy = cx if cy is None else cy
    t = _chiplet(
        n, m, cx, cy, float(noi_weight),
        None if h_rows is None else tuple(h_rows),
        None if v_cols is None else tuple(v_cols),
    )
    # re-intern under the resolved default boundary so params round-trip
    return _chiplet(n, m, cx, cy, float(noi_weight), t.h_rows, t.v_cols)


register_topology("mesh3d", mesh3d)
register_topology("torus3d", torus3d)
register_topology("chiplet", chiplet)
