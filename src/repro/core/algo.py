"""Pluggable routing-algorithm registry + cost-model protocol (DESIGN.md §6).

The paper's DPM chooses partition merges *by comparing routing cost*; this
module makes both axes of that comparison pluggable:

* ``CostModel`` — prices routes. The planner's merge loop (Algorithm 1)
  optimizes whatever objective the model encodes: the shipped models are
  hop counting (the paper's Definition 2, exactly), a link-contention-
  weighted variant (mesh bisection links cost more), and a dynamic-energy
  model derived from ``repro.noc.config.EnergyModel``.
* ``RoutingAlgorithm`` — a named multicast planner with capability metadata
  (supported topology kinds, whether its output depends on the cost model).
  ``@register_algorithm`` publishes one; every consumer (``core.planner``'s
  cached ``plan`` facade, both simulators, the dist schedule builders, the
  figure benchmarks) resolves algorithms through the registry, so a new
  algorithm is one registration, not a many-file sweep.

Registries are process-global with insertion order preserved. Cost models
may be registered as instances or as zero-argument factories — factories
instantiate lazily on first use (the energy model imports ``repro.noc``
config, which would be a circular import at ``repro.core`` import time).
"""
from __future__ import annotations

import functools
from contextlib import contextmanager
from typing import TYPE_CHECKING, Callable, Iterable

from .grid import Coord, MeshGrid
from .routefn import provider_for
from .routing import path_multicast

if TYPE_CHECKING:  # planner imports this module; annotation-only reverse dep
    from .planner import MulticastPlan

TOPOLOGY_KINDS = ("mesh", "torus", "mesh3d", "torus3d", "chiplet")


# ---------------------------------------------------------------------------
# Cost models
# ---------------------------------------------------------------------------
class CostModel:
    """Prices routes for the planners' cost comparisons (Definition 2).

    Subclasses override ``link_cost``/``packet_overhead`` (or any of the
    derived methods) to change the objective. The derived methods mirror the
    quantities Algorithm 1 compares: ``multi_unicast_cost`` is C_t,
    ``dual_path_cost`` is C_p, and ``route_cost`` prices the S->R source leg
    and arbitrary explicit hop sequences.

    Representative selection (Definition 1) stays topological — nearest
    destination by hop distance — under every model; the cost model only
    prices C_t / C_p / the source leg, which is where the paper's merge
    decisions live.
    """

    name: str = "abstract"

    def link_cost(self, g: MeshGrid, u: Coord, v: Coord) -> float:
        """Price of one worm crossing the directed link u -> v."""
        return 1.0

    def packet_overhead(self, g: MeshGrid) -> float:
        """Price of injecting one worm (NI cost; 0 under pure hop counting)."""
        return 0.0

    def route_cost(self, g: MeshGrid, hops: list[Coord]) -> float:
        """Price of one worm traversing an explicit hop sequence."""
        return sum(self.link_cost(g, u, v) for u, v in zip(hops, hops[1:]))

    def unicast_cost(self, g: MeshGrid, a: Coord, b: Coord) -> float:
        """Price of the provider's unicast route a -> b (dimension-ordered
        on a healthy topology; detoured on a degraded one)."""
        return self.route_cost(g, provider_for(g).unicast(g, a, b))

    def multi_unicast_cost(self, g: MeshGrid, src: Coord, dests: list[Coord]) -> float:
        """Definition 2's C_t under this model: one worm per destination."""
        return sum(
            self.unicast_cost(g, src, d) + self.packet_overhead(g) for d in dests
        )

    def dual_path_cost(self, g: MeshGrid, src: Coord, dests: list[Coord]) -> float:
        """Definition 2's C_p under this model: one label-ordered chain per
        subnetwork (high: labels above src, low: below)."""
        ls = g.label(*src)
        d_h = [d for d in dests if g.label(*d) > ls]
        d_l = [d for d in dests if g.label(*d) < ls]
        cost = 0  # stays int under hop counting, floats under float models
        for group, high in ((d_h, True), (d_l, False)):
            if group:
                chain = path_multicast(g, src, group, high=high)
                cost += self.route_cost(g, chain) + self.packet_overhead(g)
        return cost

    def plan_cost(self, g: MeshGrid, plan: "MulticastPlan") -> float:
        """Price a whole MulticastPlan: every path is one injected worm."""
        return sum(
            self.route_cost(g, path.hops) + self.packet_overhead(g)
            for path in plan.paths
        )


class HopCountCost(CostModel):
    """The paper's Definition 2 exactly: integer hop counts, no NI term.

    This is the default model; ``dpm_partition`` under it is bit-identical
    to the pre-registry behaviour (and to the Pallas ``dpm_cost`` tables).
    """

    name = "hops"

    def route_cost(self, g: MeshGrid, hops: list[Coord]) -> int:
        return len(hops) - 1

    def unicast_cost(self, g: MeshGrid, a: Coord, b: Coord) -> int:
        # == len(provider unicast) - 1 on every topology: the provider's
        # route is shortest on the (possibly degraded) graph, and
        # FaultyTopology.distance is exactly that BFS shortest-path length.
        return g.distance(a, b)

    def packet_overhead(self, g: MeshGrid) -> int:
        return 0


class LinkContentionCost(CostModel):
    """Hop counting with mesh bisection links weighted up.

    Under uniform traffic with minimal routing, the expected load of the
    link crossing the cut between columns i and i+1 of an n-column mesh is
    proportional to (i+1)(n-i-1) — central links are the contended ones. A
    hop costs ``1 + lam * cut_load / peak_load``, steering plans toward the
    mesh edge. On a torus every ring cut carries the same expected load
    (edge-transitive), so the model degenerates to hop counting there.
    """

    name = "contention"

    def __init__(self, lam: float = 1.0):
        self.lam = lam

    @staticmethod
    def _cut_ratio(i: int, size: int) -> float:
        peak = (size // 2) * (size - size // 2)
        if peak <= 0:
            return 0.0
        return (i + 1) * (size - i - 1) / peak

    def link_cost(self, g: MeshGrid, u: Coord, v: Coord) -> float:
        if g.wrap:
            return 1.0
        # the one axis the link moves along; cut between planes i, i+1
        for k in range(len(u)):
            if u[k] != v[k]:
                extent = (g.n, getattr(g, "m", g.rows) or g.rows,
                          getattr(g, "d", 1))[k]
                return 1.0 + self.lam * self._cut_ratio(min(u[k], v[k]), extent)
        return 1.0


class WeightedLinkCost(CostModel):
    """Hop counting priced by the topology's heterogeneous link classes.

    Each hop costs ``Topology.link_weight(u, v)`` — 1.0 for planar mesh
    links, ``z_weight`` for TSV pillars on the 3-D topologies,
    ``noi_weight`` for interposer crossings on a chiplet package. On a
    uniform topology every weight is 1.0 and the model degenerates to hop
    counting, so it is safe as a default objective everywhere; on a
    heterogeneous fabric it is the lever that makes Algorithm 1's merge
    loop prefer partitions whose chains stay on cheap planar links
    (asserted by tests/test_topo3d.py, quantified by
    benchmarks/topo3d_sweep.py).
    """

    name = "weighted"

    def link_cost(self, g: MeshGrid, u: Coord, v: Coord) -> float:
        lw = getattr(g, "link_weight", None)
        return 1.0 if lw is None else lw(u, v)


class EnergyCost(CostModel):
    """Dynamic-energy objective (pJ) from the NoC per-event energies.

    One hop moves F flits through a buffer write, buffer read, crossbar and
    link traversal (plus one arbitration); ``packet_overhead`` charges the
    NI injection of one worm (F * e_ni) — the term hop counting cannot see:
    MU-mode re-injections pay it once per destination, a dual-path chain
    once per chain, so the energy objective shifts Algorithm 1's MU/DP mode
    choices and merge decisions. Ejection energy is partition-invariant
    (every destination ejects its copy exactly once under any algorithm)
    and is therefore omitted from the comparison.
    """

    name = "energy"

    def __init__(self, energy=None, flits_per_packet: int | None = None):
        if energy is None or flits_per_packet is None:
            # Lazy: repro.noc imports repro.core, so this import must not
            # run at repro.core import time (the registry stores this class
            # as a factory and instantiates on first use).
            from ..noc.config import NoCConfig

            cfg = NoCConfig()
            energy = energy if energy is not None else cfg.energy
            if flits_per_packet is None:
                flits_per_packet = cfg.flits_per_packet
        self.energy = energy
        self.flits_per_packet = flits_per_packet
        e = energy
        self._per_hop = (
            flits_per_packet
            * (e.e_buffer_write + e.e_buffer_read + e.e_xbar + e.e_link)
            + e.e_arbiter
        )
        self._per_packet = flits_per_packet * e.e_ni

    def link_cost(self, g: MeshGrid, u: Coord, v: Coord) -> float:
        return self._per_hop

    def route_cost(self, g: MeshGrid, hops: list[Coord]) -> float:
        return (len(hops) - 1) * self._per_hop

    def unicast_cost(self, g: MeshGrid, a: Coord, b: Coord) -> float:
        return g.distance(a, b) * self._per_hop

    def packet_overhead(self, g: MeshGrid) -> float:
        return self._per_packet


_COST_MODELS: dict[str, CostModel | Callable[[], CostModel]] = {}


def register_cost_model(
    obj: CostModel | Callable[[], CostModel], *, name: str | None = None
) -> None:
    """Register a cost model instance, or a zero-arg factory for one.

    Factories instantiate lazily on first ``get_cost_model`` and the
    instance replaces the factory in the registry. Duplicate names raise.
    """
    n = name or getattr(obj, "name", None)
    if not n or n == CostModel.name:
        raise ValueError("cost model needs a name (set .name or pass name=)")
    if n in _COST_MODELS:
        raise ValueError(
            f"cost model {n!r} already registered; unregister_cost_model({n!r}) "
            f"first or pick another name"
        )
    if isinstance(obj, CostModel):
        # Sync the instance to its registration key so the plan cache's
        # canonical-instance check (is_registered_cost_model) recognizes it
        # when registered under a custom name. Factories sync on first use.
        obj.name = n
    _COST_MODELS[n] = obj


def unregister_cost_model(name: str) -> None:
    _COST_MODELS.pop(name, None)
    _invalidate_caches()


def get_cost_model(ref: CostModel | str | None) -> CostModel:
    """Resolve a cost model: an instance passes through, a name looks up the
    registry (instantiating a factory on first use), None means 'hops'."""
    if isinstance(ref, CostModel):
        return ref
    name = "hops" if ref is None else ref
    entry = _COST_MODELS.get(name)
    if entry is None:
        raise KeyError(
            f"unknown cost model {name!r}; registered: "
            f"{', '.join(available_cost_models())}"
        )
    if not isinstance(entry, CostModel):
        entry = entry()
        entry.name = name
        _COST_MODELS[name] = entry
    return entry


def available_cost_models() -> list[str]:
    return list(_COST_MODELS)


def is_registered_cost_model(cm: CostModel) -> bool:
    """True iff ``cm`` is the canonical instance its name resolves to (the
    planner cache may then key on the name alone)."""
    return _COST_MODELS.get(cm.name) is cm


# ---------------------------------------------------------------------------
# Routing algorithms
# ---------------------------------------------------------------------------
class RoutingAlgorithm:
    """A named multicast routing algorithm with capability metadata.

    ``plan(topo, src, dests, cost_model=...)`` returns a ``MulticastPlan``.
    ``topologies`` lists the topology kinds the algorithm can route on;
    ``cost_sensitive`` says whether the produced plan depends on the cost
    model (False for the fixed-shape baselines — the planner cache then
    shares one entry across models); ``default_cost_model`` names the
    objective the algorithm optimizes when the caller does not pick one;
    ``tags`` is free-form metadata (the figure benchmarks select the
    paper's comparison set via the "fig" tag).
    """

    name: str = "?"
    topologies: frozenset[str] = frozenset(TOPOLOGY_KINDS)
    cost_sensitive: bool = False
    default_cost_model: str = "hops"
    tags: frozenset[str] = frozenset()

    def plan(
        self,
        topo: MeshGrid,
        src: Coord,
        dests: list[Coord],
        *,
        cost_model: CostModel,
    ) -> "MulticastPlan":
        raise NotImplementedError

    def supports(self, topo: MeshGrid | str) -> bool:
        kind = topo if isinstance(topo, str) else topo.kind
        return kind in self.topologies


class _FunctionAlgorithm(RoutingAlgorithm):
    """Adapter registering a plain planning function.

    Cost-insensitive functions keep the legacy ``f(g, src, dests)``
    signature; cost-sensitive ones receive ``cost_model=`` as a keyword.
    """

    def __init__(
        self,
        fn: Callable,
        name: str,
        topologies: Iterable[str],
        cost_sensitive: bool,
        default_cost_model: str,
        tags: Iterable[str],
    ):
        self._fn = fn
        self.name = name
        self.topologies = frozenset(topologies)
        self.cost_sensitive = cost_sensitive
        self.default_cost_model = default_cost_model
        self.tags = frozenset(tags)

    def plan(self, topo, src, dests, *, cost_model):
        if self.cost_sensitive:
            return self._fn(topo, src, dests, cost_model=cost_model)
        return self._fn(topo, src, dests)


_ALGORITHMS: dict[str, RoutingAlgorithm] = {}
# Caches keyed on algorithm names (the planner's plan cache) must flush when
# a name is unregistered or re-registered; they subscribe here.
_CACHE_INVALIDATORS: list[Callable[[], None]] = []


def _invalidate_caches() -> None:
    for fn in _CACHE_INVALIDATORS:
        fn()


def on_registry_change(fn: Callable[[], None]) -> None:
    """Subscribe a cache-flush callback to registry mutations."""
    _CACHE_INVALIDATORS.append(fn)


def register_algorithm(
    obj=None,
    *,
    name: str | None = None,
    topologies: Iterable[str] | None = None,
    cost_sensitive: bool | None = None,
    default_cost_model: str | None = None,
    tags: Iterable[str] | None = None,
):
    """Register a routing algorithm; usable as decorator or direct call.

    Accepts a ``RoutingAlgorithm`` subclass (instantiated), an instance, or
    a planning function (wrapped — see ``_FunctionAlgorithm``). Keyword
    arguments override the object's own metadata. Registering a name twice
    raises; use ``temporary_algorithm`` for scoped registration in tests.
    """
    if obj is None:  # decorator-factory form: @register_algorithm(name=...)
        return functools.partial(
            register_algorithm,
            name=name,
            topologies=topologies,
            cost_sensitive=cost_sensitive,
            default_cost_model=default_cost_model,
            tags=tags,
        )
    if isinstance(obj, type) and issubclass(obj, RoutingAlgorithm):
        algo: RoutingAlgorithm = obj()
    elif isinstance(obj, RoutingAlgorithm):
        algo = obj
    elif callable(obj):
        algo = _FunctionAlgorithm(
            obj,
            name=name or obj.__name__,
            topologies=topologies or TOPOLOGY_KINDS,
            cost_sensitive=bool(cost_sensitive),
            default_cost_model=default_cost_model or "hops",
            tags=tags or (),
        )
    else:
        raise TypeError(f"cannot register {obj!r} as a routing algorithm")
    # Duplicate check BEFORE any metadata mutation: a raising registration
    # must not leave an already-registered instance renamed (which would
    # silently decouple it from its cache key).
    final_name = name or algo.name
    if final_name in _ALGORITHMS:
        raise ValueError(
            f"routing algorithm {final_name!r} already registered; "
            f"unregister_algorithm({final_name!r}) first or pick another name"
        )
    if not isinstance(algo, _FunctionAlgorithm):  # kwargs override metadata
        algo.name = final_name
        if topologies is not None:
            algo.topologies = frozenset(topologies)
        if cost_sensitive is not None:
            algo.cost_sensitive = cost_sensitive
        if default_cost_model is not None:
            algo.default_cost_model = default_cost_model
        if tags is not None:
            algo.tags = frozenset(tags)
    _ALGORITHMS[algo.name] = algo
    return obj


def unregister_algorithm(name: str) -> None:
    """Remove an algorithm and flush name-keyed caches (plan cache)."""
    _ALGORITHMS.pop(name, None)
    _invalidate_caches()


@contextmanager
def temporary_algorithm(obj=None, **kwargs):
    """Scoped registration for tests / experiments; yields the instance and
    unregisters (flushing the plan cache) on exit."""
    register_algorithm(obj, **kwargs)
    name = kwargs.get("name") or getattr(obj, "name", None) or obj.__name__
    try:
        yield get_algorithm(name)
    finally:
        unregister_algorithm(name)


def get_algorithm(ref: "RoutingAlgorithm | str") -> RoutingAlgorithm:
    """Resolve an algorithm: an instance passes through (registered or not),
    a name looks up the registry. Unknown names list what is registered."""
    if isinstance(ref, RoutingAlgorithm):
        return ref
    algo = _ALGORITHMS.get(ref)
    if algo is None:
        raise KeyError(
            f"unknown routing algorithm {ref!r}; registered: "
            f"{', '.join(available_algorithms())}"
        )
    return algo


def is_registered_algorithm(algo: RoutingAlgorithm) -> bool:
    """True iff ``algo`` is the canonical instance its name resolves to."""
    return _ALGORITHMS.get(algo.name) is algo


def available_algorithms(
    topo: MeshGrid | str | None = None, *, tag: str | None = None
) -> list[str]:
    """Registered algorithm names, in registration order, optionally
    filtered by supported topology kind and/or tag."""
    out = []
    for name, algo in _ALGORITHMS.items():
        if topo is not None and not algo.supports(topo):
            continue
        if tag is not None and tag not in algo.tags:
            continue
        out.append(name)
    return out


# Built-in cost models. "energy" is a lazy factory: instantiating it reads
# the NoC config (repro.noc imports repro.core, so it cannot load here).
register_cost_model(HopCountCost())
register_cost_model(LinkContentionCost())
register_cost_model(WeightedLinkCost())
register_cost_model(EnergyCost, name="energy")
