"""Multicast planners: MU, DP (dual-path), MP (multipath), NMP, DPM.

Each planner maps (source, destination set) -> MulticastPlan: a list of
physical packet paths. A path is an explicit hop sequence plus the set of
nodes where a copy is absorbed. DPM paths may spawn *child* packets at the
representative node (the MU-mode re-injection); the simulator honours the
dependency, and hop-count accounting sums parent and child paths.

These planners run on the host (plan/trace time); the vectorized cost-table
computation also exists as a Pallas kernel (kernels/dpm_cost) with a jnp
reference, validated against this module.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from collections.abc import Mapping
from dataclasses import dataclass, field
from typing import NamedTuple

from .algo import (
    CostModel,
    available_algorithms,
    get_algorithm,
    get_cost_model,
    is_registered_algorithm,
    is_registered_cost_model,
    on_registry_change,
    register_algorithm,
)
from .grid import Coord, MeshGrid
from .partition import basic_partitions, dpm_partition
from .routing import greedy_tour, path_multicast, xy_route
from .topology import make_topology


@dataclass
class PacketPath:
    """One wormhole packet: hops[0] is the injection node."""

    hops: list[Coord]
    deliveries: list[Coord]
    parent: int | None = None  # index of parent path; injected when the
    # parent delivers at hops[0] (DPM MU re-injection)

    @property
    def hop_count(self) -> int:
        return len(self.hops) - 1


@dataclass
class MulticastPlan:
    algorithm: str
    src: Coord
    dests: list[Coord]
    paths: list[PacketPath] = field(default_factory=list)

    @property
    def total_hops(self) -> int:
        return sum(p.hop_count for p in self.paths)

    def check_covers(self) -> bool:
        delivered = set()
        for p in self.paths:
            delivered |= set(p.deliveries)
        return delivered == set(self.dests)


def canonical_dests(dests) -> tuple[Coord, ...]:
    """Intern a destination set to its canonical key: sorted unique tuple.

    The single canonicalization point shared by the plan cache
    (``_plan_cached``), the device plan arena (``core.batch_planner``), and
    the dist schedule builders — permuted or duplicated destination lists
    all map to the same entry. Coordinates arriving as lists are normalized
    to tuples so the result is always hashable.
    """
    return tuple(sorted({tuple(d) for d in dests}))


def _deliveries_on(path: list[Coord], dests: set[Coord]) -> list[Coord]:
    seen, out = set(), []
    for node in path:
        if node in dests and node not in seen:
            seen.add(node)
            out.append(node)
    return out


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------
def plan_mu(g: MeshGrid, src: Coord, dests: list[Coord]) -> MulticastPlan:
    """Multiple unicast: one XY packet per destination."""
    plan = MulticastPlan("MU", src, list(dests))
    for d in dests:
        plan.paths.append(PacketPath(xy_route(g, src, d), [d]))
    return plan


def plan_dp(g: MeshGrid, src: Coord, dests: list[Coord]) -> MulticastPlan:
    """Dual-path [10]: D_H in ascending label order, D_L descending."""
    plan = MulticastPlan("DP", src, list(dests))
    ls = g.label(*src)
    d_h = [d for d in dests if g.label(*d) > ls]
    d_l = [d for d in dests if g.label(*d) < ls]
    for group, high in ((d_h, True), (d_l, False)):
        if group:
            path = path_multicast(g, src, group, high=high)
            plan.paths.append(PacketPath(path, _deliveries_on(path, set(group))))
    return plan


def _mp_groups(g: MeshGrid, src: Coord, dests: list[Coord]):
    """MP's static 4-way split: label high/low x {x < sx, x >= sx}."""
    ls = g.label(*src)
    sx = src[0]
    d_h = [d for d in dests if g.label(*d) > ls]
    d_l = [d for d in dests if g.label(*d) < ls]
    return (
        [d for d in d_h if d[0] < sx],  # D_H1
        [d for d in d_h if d[0] >= sx],  # D_H2
        [d for d in d_l if d[0] < sx],  # D_L1
        [d for d in d_l if d[0] >= sx],  # D_L2
    )


def plan_mp(g: MeshGrid, src: Coord, dests: list[Coord]) -> MulticastPlan:
    """Multipath [11]: four label-ordered path packets, one per static group."""
    plan = MulticastPlan("MP", src, list(dests))
    g_h1, g_h2, g_l1, g_l2 = _mp_groups(g, src, dests)
    for group, high in ((g_h1, True), (g_h2, True), (g_l1, False), (g_l2, False)):
        if group:
            path = path_multicast(g, src, group, high=high)
            plan.paths.append(PacketPath(path, _deliveries_on(path, set(group))))
    return plan


def plan_nmp(g: MeshGrid, src: Coord, dests: list[Coord]) -> MulticastPlan:
    """NMP [18]: MP's static partition, but nearest-first greedy tours with
    XY legs (destinations sorted by hop distance instead of label)."""
    plan = MulticastPlan("NMP", src, list(dests))
    for group in _mp_groups(g, src, dests):
        if group:
            path = greedy_tour(g, src, group)
            plan.paths.append(PacketPath(path, _deliveries_on(path, set(group))))
    return plan


# --------------------------------------------------------------------------
# DPM
# --------------------------------------------------------------------------
def _emit_dpm_partition(
    plan: MulticastPlan, g: MeshGrid, src: Coord, dests: list[Coord],
    rep: Coord, mode: str, *, unicast=None, chain=None,
) -> None:
    """Append one final partition's delivery paths to ``plan``.

    S --XY--> R head, then either the dual-path continuation (the chain
    continues into the larger label side; the other side is a sibling child
    re-injected at R) or MU-mode child unicasts. Shared by the host
    construction loop (``plan_dpm``) and the batched planner's decode step
    (``core.batch_planner``) — device-planned partitions decode through the
    exact code path host plans are built with, which is what makes the
    bit-identical contract hold structurally rather than by coincidence.

    ``unicast(a, b)`` / ``chain(a, group, high=...)`` override the route
    primitives (defaults: ``xy_route`` / ``path_multicast``). The batched
    decode passes memoized equivalents so repeated (src, rep) legs across a
    batch don't re-walk routes hop by hop; the partition-to-paths structure
    (DP split, larger-side-first, deliveries, parent links) stays here.
    """
    if unicast is None:
        unicast = functools.partial(xy_route, g)
    if chain is None:
        chain = functools.partial(path_multicast, g)
    head = unicast(src, rep)
    rest = [d for d in dests if d != rep]
    if mode == "DP" and rest:
        lr = g.label(*rep)
        d_h = [d for d in rest if g.label(*d) > lr]
        d_l = [d for d in rest if g.label(*d) < lr]
        # The chain continues into the *larger* side from the head packet;
        # the other side is a sibling packet re-injected at R.
        first, second = (d_h, d_l) if len(d_h) >= len(d_l) else (d_l, d_h)
        tail = chain(rep, first, high=first is d_h) if first else [rep]
        full = head + tail[1:]
        deliver = _deliveries_on(full, set(dests))
        parent_idx = len(plan.paths)
        plan.paths.append(PacketPath(full, deliver))
        if second:
            spath = chain(rep, second, high=second is d_h)
            plan.paths.append(
                PacketPath(
                    spath,
                    _deliveries_on(spath, set(second)),
                    parent=parent_idx,
                )
            )
    else:  # MU mode (or singleton partition)
        deliver = _deliveries_on(head, set(dests))
        parent_idx = len(plan.paths)
        plan.paths.append(PacketPath(head, deliver))
        remaining = [d for d in rest if d not in set(deliver)]
        for d in remaining:
            plan.paths.append(
                PacketPath(unicast(rep, d), [d], parent=parent_idx)
            )


def plan_dpm(
    g: MeshGrid,
    src: Coord,
    dests: list[Coord],
    include_source_leg: bool = True,
    max_merge: int = 3,
    *,
    cost_model: CostModel | str | None = None,
) -> MulticastPlan:
    """DPM: Algorithm 1 partitions, then per-partition delivery:

    S --XY--> R, then from R either dual-path (one packet continues) or
    multiple unicast (child packets re-injected at R). ``cost_model`` is
    the objective Algorithm 1's merge comparisons optimize (default: the
    paper's hop counting).
    """
    plan = MulticastPlan("DPM", src, list(dests))
    result = dpm_partition(g, src, dests, include_source_leg, max_merge, cost_model)
    for part in result.partitions:
        if not part.dests:
            continue
        assert part.rep is not None
        _emit_dpm_partition(plan, g, src, part.dests, part.rep, part.mode)
    return plan


def plan_dpm_e(
    g: MeshGrid,
    src: Coord,
    dests: list[Coord],
    *,
    cost_model: CostModel | str | None = None,
) -> MulticastPlan:
    """DPM-E: Algorithm 1 merging under the dynamic-energy objective.

    Identical machinery to DPM; only the cost model the merge loop compares
    candidates with changes (default "energy" — DESIGN.md §6). Shipped as
    the proof that a new algorithm is one registration: no consumer file
    (noc/, dist/, benchmarks/) mentions it by name.
    """
    p = plan_dpm(g, src, dests, cost_model="energy" if cost_model is None else cost_model)
    p.algorithm = "DPM-E"
    return p


# ---------------------------------------------------------------------------
# Deadlock-free segmentation on degraded topologies (DESIGN.md §7)
# ---------------------------------------------------------------------------
def _monotone_runs(g: MeshGrid, hops: list[Coord]) -> list[tuple[int, int]]:
    """Split a hop sequence into maximal label-monotone runs.

    Returns inclusive (start, end) index ranges; consecutive runs share the
    boundary node. A worm confined to one run crosses links of exactly one
    VC class (HIGH iff labels increase), which is the property the
    degraded-topology deadlock-freedom argument needs.
    """
    labs = [g.label(*h) for h in hops]
    runs: list[tuple[int, int]] = []
    start, direction = 0, 0
    for i in range(1, len(hops)):
        d = 1 if labs[i] > labs[i - 1] else -1
        if direction == 0:
            direction = d
        elif d != direction:
            runs.append((start, i - 1))
            start, direction = i - 1, d
    runs.append((start, len(hops) - 1))
    return runs


def segment_plan_for_faults(p: MulticastPlan, g: MeshGrid) -> MulticastPlan:
    """Decompose every packet into label-monotone worm segments.

    On a degraded topology detoured routes (and even clean dimension-ordered
    ones) mix label-increasing and label-decreasing hops, so a single worm
    can hold virtual channels in both subnetworks at once — which is exactly
    the cross-class hold-and-wait that wormhole deadlock needs (observed in
    simulation at high fault density). This pass splits each path at every
    label-direction reversal; the tail segments become child packets relayed
    cut-through at the boundary node's NI (the same VCTM-style parent/child
    fork both simulators already implement for DPM's MU re-injection). Every
    resulting worm is label-monotone, so each lives in exactly one VC class
    and the per-class channel dependency graphs are ordered by the
    Hamiltonian label — acyclic, hence deadlock-free at any fault density
    (DESIGN.md §7 has the full argument).

    Deliveries stay where the original path delivered them (a relay boundary
    is an NI absorption, not a multicast delivery); transit segments may
    carry none. Idempotent, and the identity on already-monotone plans.
    """
    segs = [_monotone_runs(g, path.hops) for path in p.paths]
    if all(len(s) <= 1 for s in segs):
        return p
    new_idx: list[list[int]] = []  # original path -> its new segment indices
    base = 0
    for s in segs:
        new_idx.append(list(range(base, base + len(s))))
        base += len(s)

    def _seg_at(op: int, pos: int) -> int:
        """New index of original path ``op``'s segment entering hop ``pos``."""
        for (s, e), ni in zip(segs[op], new_idx[op]):
            if s < pos <= e:
                return ni
        raise ValueError(f"position {pos} outside path {op}")

    out = MulticastPlan(p.algorithm, p.src, list(p.dests))
    for op, path in enumerate(p.paths):
        if len(path.hops) == 1:
            # degenerate source-only path (destination == source, e.g. MU):
            # carries no flits, nothing to segment — pass through verbatim
            parent = (
                None
                if path.parent is None
                else _seg_at(
                    path.parent,
                    p.paths[path.parent].hops.index(path.hops[0], 1),
                )
            )
            out.paths.append(
                PacketPath(list(path.hops), list(path.deliveries), parent=parent)
            )
            continue
        deliver_pos = {path.hops.index(d, 1): d for d in path.deliveries}
        for j, (s, e) in enumerate(segs[op]):
            if j == 0:
                parent = (
                    None
                    if path.parent is None
                    else _seg_at(
                        path.parent,
                        p.paths[path.parent].hops.index(path.hops[0], 1),
                    )
                )
            else:
                parent = new_idx[op][j - 1]
            out.paths.append(
                PacketPath(
                    path.hops[s : e + 1],
                    [d for pos, d in sorted(deliver_pos.items())
                     if s < pos <= e],
                    parent=parent,
                )
            )
    return out


# ---------------------------------------------------------------------------
# Registry-backed cached facade
# ---------------------------------------------------------------------------
register_algorithm(plan_mu, name="MU", tags=("fig",))
register_algorithm(plan_dp, name="DP")
register_algorithm(plan_mp, name="MP", tags=("fig",))
register_algorithm(plan_nmp, name="NMP", tags=("fig",))
register_algorithm(plan_dpm, name="DPM", cost_sensitive=True, tags=("fig",))
register_algorithm(
    plan_dpm_e, name="DPM-E", cost_sensitive=True, default_cost_model="energy"
)


class PlanCacheInfo(NamedTuple):
    """Aggregate plan-cache stats plus the per-(algorithm, cost-model)
    breakdown (``by_key``: ``(algo, cm) -> {hits, misses, evictions}``;
    cost-insensitive algorithms key with ``cm = ""`` — they share one entry
    across models). Field-compatible with ``lru_cache.cache_info()``."""

    hits: int
    misses: int
    maxsize: int
    currsize: int
    by_key: dict[tuple[str, str], dict[str, int]]


# LRU cache over normalized plan keys. A hand-rolled OrderedDict instead of
# functools.lru_cache so the telemetry layer can attribute hits/misses/
# evictions to the (algorithm, cost-model) pair inside each key — the
# signal that says which model's plans are getting recomputed (module-level
# maxsize so tests can shrink it to exercise eviction).
_PLAN_CACHE_MAXSIZE = 200_000
_plan_cache: "OrderedDict[tuple, MulticastPlan]" = OrderedDict()
_plan_hits = 0
_plan_misses = 0
_plan_by_key: dict[tuple[str, str], dict[str, int]] = {}


def _key_stats(algo: str, cost_model: str) -> dict[str, int]:
    st = _plan_by_key.get((algo, cost_model))
    if st is None:
        st = _plan_by_key[(algo, cost_model)] = {
            "hits": 0, "misses": 0, "evictions": 0,
        }
    return st


def _plan_cached(
    kind: str,
    n: int,
    m: int,
    faults: tuple,
    params: tuple,
    algo: str,
    cost_model: str,
    src: Coord,
    dests: tuple[Coord, ...],
):
    global _plan_hits, _plan_misses
    key = (kind, n, m, faults, params, algo, cost_model, src, dests)
    cached = _plan_cache.get(key)
    if cached is not None:
        _plan_cache.move_to_end(key)
        _plan_hits += 1
        _key_stats(algo, cost_model)["hits"] += 1
        return cached
    _plan_misses += 1
    _key_stats(algo, cost_model)["misses"] += 1
    a = get_algorithm(algo)
    topo = make_topology(kind, n, m, faults, params)
    p = a.plan(
        topo, src, list(dests),
        cost_model=get_cost_model(cost_model or a.default_cost_model),
    )
    if faults or getattr(topo, "needs_bfs_routes", False):
        p = segment_plan_for_faults(p, topo)
    _plan_cache[key] = p
    while len(_plan_cache) > _PLAN_CACHE_MAXSIZE:
        evicted, _ = _plan_cache.popitem(last=False)
        _key_stats(evicted[5], evicted[6])["evictions"] += 1
    return p


def plan_cache_info() -> PlanCacheInfo:
    """(hits, misses, maxsize, currsize, by_key) of the shared plan cache."""
    return PlanCacheInfo(
        _plan_hits,
        _plan_misses,
        _PLAN_CACHE_MAXSIZE,
        len(_plan_cache),
        {k: dict(v) for k, v in _plan_by_key.items()},
    )


def plan_cache_clear() -> None:
    global _plan_hits, _plan_misses
    _plan_cache.clear()
    _plan_by_key.clear()
    _plan_hits = 0
    _plan_misses = 0


on_registry_change(plan_cache_clear)


def plan(
    algo: "str | object",
    g: MeshGrid,
    src: Coord,
    dests: list[Coord],
    cost_model: CostModel | str | None = None,
) -> MulticastPlan:
    """Cached planner entry point (plans are deterministic per instance).

    ``algo`` is a registered algorithm name (or a ``RoutingAlgorithm``
    instance); ``cost_model`` a registered model name or instance, defaulting
    to the algorithm's own objective. The cache key is normalized —
    (topology kind, n, m, fault set, extra factory params, algorithm,
    cost-model, src, sorted unique dests) — so grid(8) and grid(8, 8) share
    one entry, mesh/torus plans of the same dimensions never collide, two
    cost models never alias one entry, plans for different broken-link sets
    (``FaultyTopology``) never alias each other or the healthy plan, and
    3-D/chiplet topologies with different depth/weight/boundary params
    (``Topology.params``) key separately. Cost-insensitive algorithms
    share one entry across models. Unregistered algorithm/cost-model
    instances plan uncached (the name key could not be trusted to resolve
    back to them). On a degraded topology — and on any topology whose
    provider routes by BFS (``needs_bfs_routes``), whose unicast hops are
    not label-monotone — every returned plan is segmented into
    label-monotone worms (``segment_plan_for_faults``), the
    deadlock-freedom guarantee of DESIGN.md §7.
    """
    a = get_algorithm(algo)
    if not a.supports(g):
        raise ValueError(
            f"routing algorithm {a.name!r} does not support topology kind "
            f"{g.kind!r} (supports: {', '.join(sorted(a.topologies))}); "
            f"algorithms available here: {', '.join(available_algorithms(g))}"
        )
    cm = get_cost_model(cost_model if cost_model is not None else a.default_cost_model)
    cacheable = is_registered_algorithm(a) and (
        not a.cost_sensitive or is_registered_cost_model(cm)
    )
    faults = getattr(g, "faults", ())
    if not cacheable:
        p = a.plan(g, src, dests, cost_model=cm)
        if faults or getattr(g, "needs_bfs_routes", False):
            p = segment_plan_for_faults(p, g)
        return p
    cm_key = cm.name if a.cost_sensitive else ""
    # the factory's m argument: the y extent where it exists (3-D meshes
    # have rows = m*d), the row count otherwise
    m_key = getattr(g, "m", None)
    if m_key is None:
        m_key = g.rows
    return _plan_cached(
        g.kind, g.n, m_key, faults, getattr(g, "params", ()), a.name, cm_key,
        src, canonical_dests(dests),
    )


class _PlannersView(Mapping):
    """Legacy ``PLANNERS`` mapping, now a live view over the registry.

    Keys are registered algorithm names; values plan through the cached
    facade with the legacy ``f(g, src, dests)`` signature.
    """

    def __getitem__(self, name: str):
        get_algorithm(name)  # unknown names raise, listing what exists
        return functools.partial(plan, name)

    def __iter__(self):
        return iter(available_algorithms())

    def __len__(self) -> int:
        return len(available_algorithms())


PLANNERS = _PlannersView()
