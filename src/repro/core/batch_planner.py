"""Device-side batched planning + the canonical plan arena (DESIGN.md §12).

``plan()`` is a host-side Python loop behind an LRU — fine for one multicast
at a time, not for serving-scale request streams where planning itself is
the hot path. This module plans *batches*: pack B (src, dest-set) instances
into ``(B, NN)`` destination masks, run Algorithm 1 for all of them in one
jitted dispatch (``kernels.dpm_cost.dpm_plan_exact`` — full Definition 2,
C_t and C_p, MU/DP modes, greedy pick order), and decode the resulting
partition tensors into ``MulticastPlan``s only for arena misses.

The correctness contract is **bit-identity with the host planner**: every
decoded plan equals ``plan(algo, topo, src, dests, cost_model=...)`` field
for field. Three things make that hold:

* the decode step rebuilds paths through the exact host construction code
  (``planner._emit_dpm_partition``) from the device-chosen partitions,
  representatives, modes, and pick order;
* a label-chain decomposition prices C_p exactly on device: a label-ordered
  chain is the concatenation of pairwise label routes between consecutive
  members (the dual-path rule never passes a pending member early), so C_p
  reduces to a prefix scan over dense pairwise price matrices;
* ``batch_support`` gates batching on *exactness*: every price must be a
  dyadic rational (multiple of 1/256) small enough that float32 sums stay
  exact, the cost model must price routes edge-additively, and the fabric
  must be healthy (degraded topologies detour through BFS fallback hops
  that break the chain decomposition — those always take the host path).

Anything outside the gate — degraded fabrics, non-dyadic objectives
(energy), unregistered algorithms/models, oversized fabrics — falls back to
the host ``plan()`` transparently; the arena caches either way.
"""
from __future__ import annotations

import functools
import threading
from collections import OrderedDict
from typing import NamedTuple

import numpy as np

from .algo import (
    get_algorithm,
    get_cost_model,
    is_registered_algorithm,
    is_registered_cost_model,
    on_registry_change,
)
from .grid import Coord, MeshGrid
from .partition import candidate_ids_for, wedge_patterns
from .planner import (
    MulticastPlan,
    PacketPath,
    _emit_dpm_partition,
    canonical_dests,
    plan,
    plan_dpm,
    plan_dpm_e,
    segment_plan_for_faults,
)
from .routefn import provider_for, route_cost_matrices
from .routing import label_route, xy_route

# Dense lowering is O(NN^2) host work (once per topology/model, cached);
# cap it so a misconfigured huge fabric degrades to host planning instead
# of stalling on table construction.
MAX_ARENA_NODES = 1024
DEFAULT_ARENA_SIZE = 65_536
# Device dispatch granularity: misses are planned in fixed-size chunks so
# every batch size ≥ CHUNK reuses one compiled shape (smaller batches pad
# to the next power of two — a handful of specializations total), and so
# on multi-core hosts the decode of chunk k overlaps the asynchronously
# dispatched device compute of chunk k+1.
DISPATCH_CHUNK = 512

# Exactness gate: prices must be multiples of 1/SCALE and bounded so that
# any candidate-cost sum stays inside float32's exact-integer range (2^24
# in units of 1/SCALE). 1/256 covers every shipped dyadic model (hops,
# weighted with dyadic link weights, contention on power-of-two extents).
_SCALE = 256.0
_EXACT_LIMIT = float(2**24)


class _Support(NamedTuple):
    ok: bool
    reason: str


class ArenaInfo(NamedTuple):
    """Per-planner arena stats: lookup hits/misses, LRU bounds/evictions,
    and *planning attribution* — how many misses were planned on device
    (``batched_plans``, in ``dispatches`` jitted batches) vs on the host
    fallback path (``host_plans``)."""

    hits: int
    misses: int
    maxsize: int
    currsize: int
    evictions: int
    batched_plans: int
    host_plans: int
    dispatches: int


class ArenaCacheInfo(NamedTuple):
    """Aggregate arena stats across all live planners, mirroring
    ``planner.PlanCacheInfo``: ``by_key`` maps ``(algo, cost-model)`` to
    its hit/miss/eviction counters (cost-insensitive algorithms key with
    ``cm = ""``, as in the plan cache)."""

    hits: int
    misses: int
    maxsize: int
    currsize: int
    by_key: dict[tuple[str, str], dict[str, int]]


# ---------------------------------------------------------------------------
# Dense host tables (cached per topology / cost model)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=256)
def membership_table(topo: MeshGrid) -> np.ndarray:
    """(NN, NN) int32 wedge id of node ``v`` w.r.t. source ``u`` for every
    pair — the all-sources ``partition_membership`` table, built once per
    topology so batch packing is a row gather instead of per-request host
    geometry."""
    from ..kernels.dpm_cost.ops import partition_membership

    return partition_membership(topo, topo.nodes())


@functools.lru_cache(maxsize=256)
def _label_chain_matrices_cached(topo: MeshGrid, cm) -> tuple:
    NN = topo.num_nodes
    nodes = topo.nodes()
    provider = provider_for(topo)
    wh = np.zeros((NN, NN), np.float32)
    wl = np.zeros((NN, NN), np.float32)
    labels = {u: topo.label(*u) for u in nodes}
    # Per target, one label_step call per source plus memoized chain
    # resolution: cost[u] = link_cost(u, step(u)) + cost[step(u)] — O(NN)
    # per target instead of re-walking every route (shared suffixes).
    for v in nodes:
        iv = topo.idx(v)
        for high, w in ((True, wh), (False, wl)):
            srcs = [
                u for u in nodes
                if (labels[u] < labels[v]) == high and u != v
            ]
            nxt = {u: provider.label_step(topo, u, v, high) for u in srcs}
            cost: dict[Coord, float] = {v: 0.0}
            for u in srcs:
                stack = []
                cur = u
                while cur not in cost:
                    stack.append(cur)
                    cur = nxt[cur]
                c = cost[cur]
                for s in reversed(stack):
                    c = cm.link_cost(topo, s, nxt[s]) + c
                    cost[s] = c
                w[topo.idx(u), iv] = cost[u]
    return wh, wl


def label_chain_matrices(topo: MeshGrid, cost_model=None):
    """Dense pairwise label-route prices: ``wh[u, v]`` is the cost of the
    HIGH-subnetwork label route u -> v (defined for label(v) > label(u)),
    ``wl`` the LOW mirror — the tensors ``dpm_plan_exact``'s C_p chain
    scan gathers from. Cached per (topology, model) instance pair."""
    return _label_chain_matrices_cached(topo, get_cost_model(cost_model))


def _dyadic_exact(*arrays) -> bool:
    """True iff every value is a multiple of 1/_SCALE representable and
    summable exactly in float32 (see the exactness gate in batch_support)."""
    for a in arrays:
        q = np.asarray(a, np.float64) * _SCALE
        if not np.all(np.isfinite(q)) or np.any(q != np.round(q)):
            return False
    return True


def batch_support(topo: MeshGrid, algo="DPM", cost_model=None) -> _Support:
    """Can (topo, algo, cost_model) plan on the batched device path with
    the bit-identity guarantee? Returns (ok, reason) — the reason names the
    first failed gate, and callers fall back to host ``plan()`` on any."""
    a = get_algorithm(algo)
    if getattr(a, "_fn", None) not in (plan_dpm, plan_dpm_e):
        return _Support(False, f"algorithm {a.name!r} has no device twin")
    if not is_registered_algorithm(a):
        return _Support(False, f"algorithm {a.name!r} not registered")
    cm = get_cost_model(
        cost_model if cost_model is not None else a.default_cost_model
    )
    if not is_registered_cost_model(cm):
        return _Support(False, f"cost model {cm.name!r} not registered")
    if getattr(topo, "faults", ()):
        # BFS fallback hops on detoured label routes break the chain
        # decomposition; degraded fabrics always plan on the host.
        return _Support(False, "degraded topology (broken links)")
    if topo.num_nodes > MAX_ARENA_NODES:
        return _Support(
            False,
            f"{topo.num_nodes} nodes > MAX_ARENA_NODES ({MAX_ARENA_NODES})",
        )
    dist, w_uni, overhead = route_cost_matrices(topo, cm)
    from ..kernels.dpm_cost.dpm_cost import BIG

    if int(dist.max(initial=0)) * BIG + topo.num_nodes >= 2**31:
        return _Support(False, "route distances overflow the int32 rep key")
    wh, wl = label_chain_matrices(topo, cm)
    if not _dyadic_exact(w_uni, wh, wl, [overhead]):
        return _Support(
            False, f"cost model {cm.name!r} prices are not dyadic (f32-exact)"
        )
    bound = _SCALE * (
        4.0
        * topo.num_nodes
        * (max(w_uni.max(initial=0), wh.max(initial=0), wl.max(initial=0))
           + overhead + 1.0)
    )
    if bound >= _EXACT_LIMIT:
        return _Support(False, "cost magnitudes exceed the f32-exact range")
    # edge-additivity spot check: the chain decomposition (and the per-edge
    # matrix build) assumes route_cost == sum of link_cost over the route
    nodes = topo.nodes()
    for v in nodes[:: max(1, len(nodes) // 8)]:
        if v == nodes[0]:
            continue
        route = provider_for(topo).unicast(topo, nodes[0], v)
        edge_sum = sum(
            cm.link_cost(topo, x, y) for x, y in zip(route, route[1:])
        )
        if abs(cm.route_cost(topo, route) - edge_sum) > 1e-9:
            return _Support(
                False, f"cost model {cm.name!r} is not edge-additive"
            )
    return _Support(True, "")


# ---------------------------------------------------------------------------
# The batched planner + arena
# ---------------------------------------------------------------------------
class _Tables(NamedTuple):
    memb: np.ndarray
    memb_rows: list  # memb as nested python lists (decode-side lookups)
    labels_d: object  # device copies (jax arrays)
    order_d: object
    dist_d: object
    wuni_d: object
    wh_d: object
    wl_d: object
    overhead: float


class BatchPlanner:
    """Batched DPM planner over one (topology, algorithm, cost model) with
    a bounded LRU arena of decoded ``MulticastPlan``s.

    ``plan_many(requests)`` is the entry point: arena lookups first
    (canonical keys — permuted duplicate requests hit one entry), then one
    jitted ``dpm_plan_exact`` dispatch over all unique misses, then host
    decode of the partition tensors. When ``support.ok`` is False every
    miss plans through host ``plan()`` instead (same results, same arena).
    Thread-safe: the plan server and direct callers may share an instance.
    """

    def __init__(self, topo: MeshGrid, algo="DPM", cost_model=None,
                 maxsize: int = DEFAULT_ARENA_SIZE):
        self.topo = topo
        self._algo = get_algorithm(algo)
        self._cm = get_cost_model(
            cost_model if cost_model is not None else
            self._algo.default_cost_model
        )
        self.maxsize = maxsize
        self.np_ = len(wedge_patterns(len(topo.from_idx(0))))
        self._cands = candidate_ids_for(self.np_)
        self.support = batch_support(topo, self._algo, self._cm)
        self._arena: "OrderedDict[tuple, MulticastPlan]" = OrderedDict()
        self._lock = threading.Lock()
        self._tables_cached: _Tables | None = None
        # Route memos for decode: (a, b) -> unicast hops, (a, b, high) ->
        # label-route segment past a. Naturally bounded by NN^2 (resp.
        # 2*NN^2) keys — node-pair tables, same order as the dense price
        # matrices this planner already holds.
        self._uni_memo: dict[tuple, tuple] = {}
        self._seg_memo: dict[tuple, tuple] = {}
        self._labmap: dict[Coord, int] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._batched = 0
        self._host = 0
        self._dispatches = 0

    # ------------------------------------------------------------- public
    def plan_many(self, requests) -> list[MulticastPlan]:
        """Plan ``[(src, dests), ...]``; returns plans in request order,
        each bit-identical to ``plan(algo, topo, src, dests, cost_model)``."""
        with self._lock:
            return self._plan_many_locked(list(requests))

    def plan_one(self, src: Coord, dests) -> MulticastPlan:
        return self.plan_many([(src, dests)])[0]

    def info(self) -> ArenaInfo:
        return ArenaInfo(
            self._hits, self._misses, self.maxsize, len(self._arena),
            self._evictions, self._batched, self._host, self._dispatches,
        )

    def clear(self) -> None:
        with self._lock:
            self._arena.clear()

    # ------------------------------------------------------------ internal
    def _plan_many_locked(self, requests) -> list[MulticastPlan]:
        keys = [
            (tuple(src), canonical_dests(dests)) for src, dests in requests
        ]
        out: list[MulticastPlan | None] = [None] * len(keys)
        missing: list[tuple] = []
        first_at: dict[tuple, int] = {}
        for i, key in enumerate(keys):
            hit = self._arena.get(key)
            if hit is not None:
                self._arena.move_to_end(key)
                self._hits += 1
                out[i] = hit
            else:
                self._misses += 1
                if key not in first_at:
                    first_at[key] = len(missing)
                    missing.append(key)
        if missing:
            if self.support.ok:
                plans = self._plan_batch(missing)
                self._batched += len(missing)
            else:
                plans = [
                    plan(self._algo, self.topo, src, list(dests),
                         cost_model=self._cm)
                    for src, dests in missing
                ]
                self._host += len(missing)
            for key, p in zip(missing, plans):
                self._arena[key] = p
                while len(self._arena) > self.maxsize:
                    self._arena.popitem(last=False)
                    self._evictions += 1
            for i, key in enumerate(keys):
                if out[i] is None:
                    out[i] = plans[first_at[key]]
        return out  # type: ignore[return-value]

    def _tables(self) -> _Tables:
        if self._tables_cached is None:
            import jax.numpy as jnp

            from ..kernels.dpm_cost.ops import snake_labels

            dist, w_uni, overhead = route_cost_matrices(self.topo, self._cm)
            wh, wl = label_chain_matrices(self.topo, self._cm)
            labels = snake_labels(self.topo)
            memb = membership_table(self.topo)
            self._tables_cached = _Tables(
                memb,
                memb.tolist(),
                jnp.asarray(labels),
                jnp.asarray(np.argsort(labels).astype(np.int32)),
                jnp.asarray(dist),
                jnp.asarray(w_uni),
                jnp.asarray(wh),
                jnp.asarray(wl),
                float(overhead),
            )
        return self._tables_cached

    def _dispatch(self, keys: list[tuple]):
        """One jitted ``dpm_plan_exact`` call over ≤ DISPATCH_CHUNK keys,
        padded to a power of two. Returns the device arrays *without*
        synchronizing — JAX dispatch is asynchronous, so the caller can
        keep issuing chunks (and decoding earlier ones) while XLA computes
        this one in its own threadpool."""
        import jax.numpy as jnp

        from ..kernels.dpm_cost.ops import dpm_plan_exact

        t = self._tables()
        g = self.topo
        NN = g.num_nodes
        Bp = 1 << max(0, len(keys) - 1).bit_length()
        mask = np.zeros((Bp, NN), bool)
        sidx = np.zeros(Bp, np.int32)
        for b, (src, dests) in enumerate(keys):
            sidx[b] = g.idx(src)
            for d in dests:
                mask[b, g.idx(d)] = True
        return dpm_plan_exact(
            jnp.asarray(mask),
            jnp.asarray(sidx),
            jnp.asarray(t.memb[sidx]),
            t.labels_d,
            t.order_d,
            t.dist_d,
            t.wuni_d,
            t.wh_d,
            t.wl_d,
            np_=self.np_,
            overhead=t.overhead,
        )

    def _plan_batch(self, keys: list[tuple]) -> list[MulticastPlan]:
        # Issue every chunk's device work first (async dispatch), then
        # decode in order — chunk k's host decode overlaps chunk k+1's
        # device compute where cores allow, so the pipeline costs
        # ~max(device, decode) instead of their sum.
        chunks = [
            keys[i : i + DISPATCH_CHUNK]
            for i in range(0, len(keys), DISPATCH_CHUNK)
        ]
        outs = [self._dispatch(ck) for ck in chunks]
        self._dispatches += len(chunks)
        plans: list[MulticastPlan] = []
        for ck, out in zip(chunks, outs):
            # one bulk device->host sync + python-list conversion per chunk
            # (per-element numpy scalar indexing in decode costs more than
            # the whole transfer)
            chosen, order, reps, modes = (
                np.asarray(x).tolist() for x in out[:4]
            )
            plans.extend(
                self._decode(src, dests, chosen[b], order[b], reps[b],
                             modes[b])
                for b, (src, dests) in enumerate(ck)
            )
        return plans

    def _uni(self, a: Coord, b: Coord) -> list[Coord]:
        """Memoized ``xy_route`` (fresh list per call — plans own their
        hop lists)."""
        r = self._uni_memo.get((a, b))
        if r is None:
            r = self._uni_memo[(a, b)] = tuple(xy_route(self.topo, a, b))
        return list(r)

    def _chain(self, cur: Coord, dests, *, high: bool) -> list[Coord]:
        """Memoized ``path_multicast`` equivalent: the label-ordered chain
        is the concatenation of pairwise label routes between consecutive
        label-sorted members — the same decomposition ``dpm_plan_exact``
        prices C_p with, valid here because the support gate restricts the
        batched path to minimal (label-monotone) route providers, where a
        chain segment never passes a later pending destination early."""
        g = self.topo
        pending = [d for d in dests if d != cur]
        if not pending:
            return [cur]
        if not self._labmap:
            self._labmap.update((u, g.label(*u)) for u in g.nodes())
        pending.sort(key=self._labmap.__getitem__, reverse=not high)
        path = [cur]
        for t in pending:
            key = (path[-1], t, high)
            seg = self._seg_memo.get(key)
            if seg is None:
                seg = self._seg_memo[key] = tuple(
                    label_route(g, path[-1], t, high)[1:]
                )
            path.extend(seg)
        return path

    def _decode(self, src, dests, chosen, order, reps, modes) -> MulticastPlan:
        """Partition tensors -> MulticastPlan, in host emission order:
        merge winners by greedy pick round, then leftover singles by
        ascending candidate index (NO_ORDER sorts them after every round).
        Wedge assignment comes from the cached membership table (the same
        rows the device merge partitioned with), and paths are rebuilt
        through ``_emit_dpm_partition`` with memoized route primitives."""
        g = self.topo
        cands = self._cands
        row = self._tables().memb_rows[g.idx(src)]
        parts: list[list[Coord]] = [[] for _ in range(self.np_)]
        for d in dests:
            parts[row[g.idx(d)]].append(d)
        picked = sorted(
            (ci for ci in range(len(cands)) if chosen[ci]),
            key=lambda ci: (order[ci], ci),
        )
        p = MulticastPlan(self._algo.name, src, list(dests))
        for ci in picked:
            union: list[Coord] = []
            for i in cands[ci]:
                union.extend(parts[i])
            if not union:
                continue
            rep = g.from_idx(reps[ci])
            if len(union) == 1:
                # singleton partition: rep is the lone member, the emission
                # is exactly the S->R head delivering at R (both modes) —
                # skip the general machinery
                p.paths.append(PacketPath(self._uni(src, rep), [rep]))
                continue
            mode = "MU" if modes[ci] else "DP"
            _emit_dpm_partition(
                p, g, src, union, rep, mode,
                unicast=self._uni, chain=self._chain,
            )
        if getattr(g, "needs_bfs_routes", False):
            p = segment_plan_for_faults(p, g)
        return p


# ---------------------------------------------------------------------------
# Module-level planner registry (the bulk-planning backend consumers use)
# ---------------------------------------------------------------------------
_PLANNERS: "OrderedDict[tuple, BatchPlanner]" = OrderedDict()
_MAX_PLANNERS = 64
_PLANNERS_LOCK = threading.Lock()


def planner_for(topo: MeshGrid, algo="DPM", cost_model=None,
                maxsize: int = DEFAULT_ARENA_SIZE) -> BatchPlanner:
    """The shared ``BatchPlanner`` for (topo, algo, cost-model) — one arena
    per combination, so every consumer (simulator drivers, xsim compile,
    dist schedule builders, trace replay, the plan server) reuses plans the
    others already decoded."""
    a = get_algorithm(algo)
    cm = get_cost_model(
        cost_model if cost_model is not None else a.default_cost_model
    )
    key = (topo, a.name, cm.name if a.cost_sensitive else "")
    with _PLANNERS_LOCK:
        pl = _PLANNERS.get(key)
        if pl is not None:
            _PLANNERS.move_to_end(key)
            return pl
        pl = BatchPlanner(topo, a, cm, maxsize=maxsize)
        _PLANNERS[key] = pl
        while len(_PLANNERS) > _MAX_PLANNERS:
            _PLANNERS.popitem(last=False)
        return pl


def bulk_plan(topo: MeshGrid, requests, algo="DPM",
              cost_model=None) -> list[MulticastPlan]:
    """Plan a request list ``[(src, dests), ...]`` through the shared plan
    arena: one jitted device dispatch for all arena misses where the
    batched path is supported, host ``plan()`` otherwise. Always returns
    plans bit-identical to per-request ``plan()`` calls, in request order.

    This is the bulk-planning backend ``WormholeSim.add_requests``,
    ``xsim.compile_workload``, ``dist.schedule_multicasts`` and the trace
    replay drivers route through.
    """
    requests = list(requests)
    if not requests:
        return []
    a = get_algorithm(algo)
    cm = get_cost_model(
        cost_model if cost_model is not None else a.default_cost_model
    )
    if not is_registered_algorithm(a) or (
        a.cost_sensitive and not is_registered_cost_model(cm)
    ):
        # unregistered instances cannot key an arena (the name would not
        # resolve back); plan uncached exactly as plan() itself would
        return [
            plan(a, topo, src, list(dests), cost_model=cm)
            for src, dests in requests
        ]
    return planner_for(topo, a, cm).plan_many(requests)


def arena_info() -> ArenaCacheInfo:
    """Aggregate stats over every live arena, shaped like
    ``planner.plan_cache_info()`` (hits/misses/maxsize/currsize + per-
    (algo, cost-model) attribution)."""
    hits = misses = maxsize = currsize = 0
    by_key: dict[tuple[str, str], dict[str, int]] = {}
    with _PLANNERS_LOCK:
        items = list(_PLANNERS.items())
    for (_, algo, cmk), pl in items:
        i = pl.info()
        hits += i.hits
        misses += i.misses
        maxsize += i.maxsize
        currsize += i.currsize
        st = by_key.setdefault(
            (algo, cmk), {"hits": 0, "misses": 0, "evictions": 0}
        )
        st["hits"] += i.hits
        st["misses"] += i.misses
        st["evictions"] += i.evictions
    return ArenaCacheInfo(hits, misses, maxsize, currsize, by_key)


def arena_clear() -> None:
    """Drop every planner (and its arena). Also the registry-mutation hook:
    arenas key plans by algorithm/cost-model *name*, so a re-registered
    name must not serve stale plans — same contract as the plan cache."""
    with _PLANNERS_LOCK:
        _PLANNERS.clear()


on_registry_change(arena_clear)
