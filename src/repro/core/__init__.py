"""Core contribution of the paper: dynamic partition merging multicast.

Public API:
    MeshGrid, grid                         — mesh geometry + Hamiltonian labels
    Torus, torus, make_topology, Topology  — wraparound torus + the protocol
    basic_partitions, dpm_partition        — Definitions 1-3 + Algorithm 1
    plan / PLANNERS                        — MU / DP / MP / NMP / DPM planners

Every planner and routing function takes any Topology (mesh or torus).
"""
from .grid import Coord, MeshGrid, grid
from .partition import (
    ALL_CANDIDATE_IDS,
    DPMResult,
    PartitionCost,
    basic_partitions,
    brute_force_partition,
    candidate_cost,
    dpm_partition,
    representative,
)
from .planner import (
    PLANNERS,
    MulticastPlan,
    PacketPath,
    plan,
    plan_dp,
    plan_dpm,
    plan_mp,
    plan_mu,
    plan_nmp,
)
from .routing import (
    dual_path_cost,
    greedy_tour,
    label_route,
    multi_unicast_cost,
    path_multicast,
    xy_route,
)
from .topology import Topology, Torus, make_topology, ring_delta, torus

__all__ = [
    "ALL_CANDIDATE_IDS",
    "Coord",
    "DPMResult",
    "MeshGrid",
    "MulticastPlan",
    "PLANNERS",
    "PacketPath",
    "PartitionCost",
    "basic_partitions",
    "brute_force_partition",
    "candidate_cost",
    "dpm_partition",
    "dual_path_cost",
    "greedy_tour",
    "grid",
    "label_route",
    "multi_unicast_cost",
    "path_multicast",
    "plan",
    "plan_dp",
    "plan_dpm",
    "plan_mp",
    "plan_mu",
    "plan_nmp",
    "representative",
    "ring_delta",
    "Topology",
    "Torus",
    "make_topology",
    "torus",
    "xy_route",
]
