"""Core contribution of the paper: dynamic partition merging multicast.

Public API:
    MeshGrid, grid                         — mesh geometry + Hamiltonian labels
    Torus, torus, make_topology, Topology  — wraparound torus + the protocol
    basic_partitions, dpm_partition        — Definitions 1-3 + Algorithm 1
    plan / PLANNERS                        — cached planning facade + legacy view
    RoutingAlgorithm, register_algorithm,  — pluggable algorithm registry
    available_algorithms, get_algorithm      (DESIGN.md §6)
    CostModel, register_cost_model,        — pluggable routing objectives:
    get_cost_model, available_cost_models    hops / contention / energy

Every planner and routing function takes any Topology (mesh or torus).
Algorithms and cost models resolve through the ``repro.core.algo`` registry;
``plan_dpm_e`` (registered as "DPM-E") is DPM optimizing the energy model.
Routes come from the route-provider layer (``repro.core.routefn``,
DESIGN.md §7): ``faulty(topo, broken_links)`` degrades any topology and
every planner/simulator detours around the broken links automatically.
"""
from .algo import (
    CostModel,
    EnergyCost,
    HopCountCost,
    LinkContentionCost,
    RoutingAlgorithm,
    WeightedLinkCost,
    available_algorithms,
    available_cost_models,
    get_algorithm,
    get_cost_model,
    register_algorithm,
    register_cost_model,
    temporary_algorithm,
    unregister_algorithm,
    unregister_cost_model,
)
from .batch_planner import (
    ArenaCacheInfo,
    ArenaInfo,
    BatchPlanner,
    arena_clear,
    arena_info,
    batch_support,
    bulk_plan,
    label_chain_matrices,
    planner_for,
)
from .grid import Coord, MeshGrid, grid
from .partition import (
    ALL_CANDIDATE_IDS,
    DPMResult,
    PartitionCost,
    basic_partitions,
    brute_force_partition,
    candidate_cost,
    candidate_ids_for,
    dpm_partition,
    representative,
    wedge_patterns,
)
from .planner import (
    PLANNERS,
    MulticastPlan,
    PacketPath,
    canonical_dests,
    plan,
    plan_cache_clear,
    plan_cache_info,
    plan_dp,
    plan_dpm,
    plan_dpm_e,
    plan_mp,
    plan_mu,
    plan_nmp,
    segment_plan_for_faults,
)
from .routefn import (
    DisconnectedError,
    FaultAwareProvider,
    FaultyTopology,
    MinimalRouteProvider,
    RouteProvider,
    faulty,
    provider_for,
    route_cost_matrices,
    router_failure,
)
from .routing import (
    dual_path_cost,
    greedy_tour,
    label_route,
    multi_unicast_cost,
    path_multicast,
    xy_route,
)
from .topo3d import (
    ChipletPackage,
    Mesh3D,
    Torus3D,
    chiplet,
    mesh3d,
    torus3d,
)
from .topology import (
    Topology,
    Torus,
    make_topology,
    register_topology,
    registered_topology_kinds,
    ring_delta,
    torus,
)

__all__ = [
    "ALL_CANDIDATE_IDS",
    "ArenaCacheInfo",
    "ArenaInfo",
    "BatchPlanner",
    "ChipletPackage",
    "Coord",
    "CostModel",
    "DPMResult",
    "DisconnectedError",
    "EnergyCost",
    "FaultAwareProvider",
    "FaultyTopology",
    "HopCountCost",
    "LinkContentionCost",
    "Mesh3D",
    "MeshGrid",
    "MinimalRouteProvider",
    "MulticastPlan",
    "PLANNERS",
    "PacketPath",
    "PartitionCost",
    "RouteProvider",
    "RoutingAlgorithm",
    "Topology",
    "Torus",
    "Torus3D",
    "WeightedLinkCost",
    "arena_clear",
    "arena_info",
    "available_algorithms",
    "available_cost_models",
    "basic_partitions",
    "batch_support",
    "brute_force_partition",
    "bulk_plan",
    "candidate_cost",
    "candidate_ids_for",
    "canonical_dests",
    "chiplet",
    "dpm_partition",
    "dual_path_cost",
    "faulty",
    "get_algorithm",
    "get_cost_model",
    "greedy_tour",
    "grid",
    "label_chain_matrices",
    "label_route",
    "make_topology",
    "mesh3d",
    "multi_unicast_cost",
    "path_multicast",
    "plan",
    "plan_cache_clear",
    "plan_cache_info",
    "plan_dp",
    "plan_dpm",
    "plan_dpm_e",
    "plan_mp",
    "plan_mu",
    "plan_nmp",
    "planner_for",
    "provider_for",
    "register_algorithm",
    "register_cost_model",
    "register_topology",
    "registered_topology_kinds",
    "representative",
    "ring_delta",
    "route_cost_matrices",
    "router_failure",
    "segment_plan_for_faults",
    "temporary_algorithm",
    "torus",
    "torus3d",
    "unregister_algorithm",
    "unregister_cost_model",
    "wedge_patterns",
    "xy_route",
]
