"""Unicast and path-based multicast routing functions, topology-generic.

Three routing functions are used by the algorithms in this repo:

* ``xy_route``      — dimension-ordered XY (x first, then y). Used by MU and by
                      the S->R delivery leg of DPM. Each dimension travels its
                      signed shortest leg (``Topology.delta``), so on a torus
                      the route takes the shorter way around each ring and its
                      length always equals ``Topology.distance``.
* ``label_route``   — the Lin–McKinley dual-path routing function: in the
                      high-channel subnetwork move to the neighbor with the
                      largest label that does not exceed the target label; in
                      the low-channel subnetwork the mirror rule. Guarantees
                      progress along the Hamiltonian path with mesh shortcuts;
                      on a torus the wrap links only add shortcuts (the snake
                      successor is still a neighbor), so the same monotone
                      progress argument applies.
* ``greedy_tour``   — NMP's nearest-destination-first tour with XY legs.

All functions return explicit hop sequences (lists of (x, y) coords starting
at the source), which the cycle-level simulator consumes directly and whose
lengths are the hop-count costs used by the planners. ``g`` is any
``Topology`` (MeshGrid or Torus, possibly degraded by ``FaultyTopology``).

Since the route-provider layer (DESIGN.md §7) every function here routes
through ``provider_for(g)``: fault-free topologies resolve to
``MinimalRouteProvider`` (the implementations above, bit-identical to the
pre-provider behaviour) and degraded topologies to ``FaultAwareProvider``,
which detours around broken links — so every caller of these functions
(planners, cost models, simulators, dist schedulers) is fault-aware without
further changes.
"""
from __future__ import annotations

from .grid import Coord, MeshGrid
from .routefn import provider_for


def xy_route(g: MeshGrid, src: Coord, dst: Coord) -> list[Coord]:
    """Dimension-ordered minimal route, inclusive of both endpoints.

    On a degraded topology the provider detours (BFS shortest path) when the
    dimension-ordered route crosses a broken link; the length then equals the
    degraded ``Topology.distance``.
    """
    return provider_for(g).unicast(g, src, dst)


def label_route_step(g: MeshGrid, cur: Coord, target: Coord, high: bool) -> Coord:
    """One hop of the dual-path routing function (see
    ``routefn.MinimalRouteProvider.label_step`` for the rule; the
    fault-aware provider restricts it to live links and falls back to a BFS
    hop when the rule has no live candidate)."""
    return provider_for(g).label_step(g, cur, target, high)


def label_route(g: MeshGrid, src: Coord, dst: Coord, high: bool) -> list[Coord]:
    """Full label-ordered route src -> dst inside one subnetwork."""
    path = [src]
    cur = src
    guard = 4 * g.num_nodes
    while cur != dst:
        cur = label_route_step(g, cur, dst, high)
        path.append(cur)
        guard -= 1
        if guard == 0:
            raise RuntimeError("label_route did not converge")
    return path


def path_multicast(
    g: MeshGrid, src: Coord, dests: list[Coord], high: bool
) -> list[Coord]:
    """Path-based multicast: visit ``dests`` in label order within a subnetwork.

    ``high=True`` visits in ascending label order (all dest labels must be
    > label(src)); ``high=False`` descending. A destination passed through en
    route is considered delivered at that point (wormhole pass-through
    delivery), so the walk always heads for the nearest-in-label-order
    unvisited destination. A destination equal to ``src`` is delivered at
    injection (zero hops) — the same rule ``greedy_tour`` applies.
    Returns the full hop sequence (deliveries are simply path points that are
    destinations).
    """
    pending = [d for d in dests if d != src]
    if not pending:
        return [src]
    pending.sort(key=lambda d: g.label(*d), reverse=not high)
    path = [src]
    cur = src
    while pending:
        target = pending[0]
        cur = label_route_step(g, cur, target, high)
        path.append(cur)
        pending = [d for d in pending if d != cur]
    return path


def greedy_tour(g: MeshGrid, src: Coord, dests: list[Coord]) -> list[Coord]:
    """NMP-style tour: repeatedly go (XY) to the nearest remaining destination.

    Delivery dedup matches ``path_multicast``: a destination equal to ``src``
    is delivered at injection, and a destination is considered delivered at
    the first hop that *enters* it (leg points after the leg's start) —
    whether it was the leg's explicit target or a pass-through. The previous
    rule filtered explicit targets and pass-throughs separately with a set
    built from the whole leg (including its start), which double-counted the
    leg origin and handled src-equal destinations inconsistently.
    """
    path = [src]
    cur = src
    pending = [d for d in dests if d != src]
    while pending:
        nxt = min(pending, key=lambda d: (g.distance(cur, d), g.row_major(*d)))
        leg = xy_route(g, cur, nxt)
        path.extend(leg[1:])
        cur = nxt
        # one rule for target and pass-through deliveries alike: every node
        # the leg entered (leg[1:] — the worm's arrivals) is delivered
        entered = set(leg[1:])
        pending = [d for d in pending if d not in entered]
    return path


def dual_path_cost(g: MeshGrid, src: Coord, dests: list[Coord]) -> int:
    """Hop count of dual-path routing from ``src`` (Definition 2's C_p).

    Destinations with label > label(src) are served by the high-channel chain
    in ascending order; label < label(src) by the low-channel chain in
    descending order.
    """
    ls = g.label(*src)
    d_h = [d for d in dests if g.label(*d) > ls]
    d_l = [d for d in dests if g.label(*d) < ls]
    cost = 0
    if d_h:
        cost += len(path_multicast(g, src, d_h, high=True)) - 1
    if d_l:
        cost += len(path_multicast(g, src, d_l, high=False)) - 1
    return cost


def multi_unicast_cost(g: MeshGrid, src: Coord, dests: list[Coord]) -> int:
    """Definition 2's C_t: sum of minimal distances src -> each destination
    (Manhattan on the mesh, toroidal Manhattan on the torus, BFS shortest
    path on a degraded topology — always the provider route length)."""
    return sum(g.distance(src, d) for d in dests)
