"""Unicast and path-based multicast routing functions, topology-generic.

Three routing functions are used by the algorithms in this repo:

* ``xy_route``      — dimension-ordered XY (x first, then y). Used by MU and by
                      the S->R delivery leg of DPM. Each dimension travels its
                      signed shortest leg (``Topology.delta``), so on a torus
                      the route takes the shorter way around each ring and its
                      length always equals ``Topology.distance``.
* ``label_route``   — the Lin–McKinley dual-path routing function: in the
                      high-channel subnetwork move to the neighbor with the
                      largest label that does not exceed the target label; in
                      the low-channel subnetwork the mirror rule. Guarantees
                      progress along the Hamiltonian path with mesh shortcuts;
                      on a torus the wrap links only add shortcuts (the snake
                      successor is still a neighbor), so the same monotone
                      progress argument applies.
* ``greedy_tour``   — NMP's nearest-destination-first tour with XY legs.

All functions return explicit hop sequences (lists of (x, y) coords starting
at the source), which the cycle-level simulator consumes directly and whose
lengths are the hop-count costs used by the planners. ``g`` is any
``Topology`` (MeshGrid or Torus).
"""
from __future__ import annotations

from .grid import Coord, MeshGrid


def xy_route(g: MeshGrid, src: Coord, dst: Coord) -> list[Coord]:
    """Dimension-ordered minimal route, inclusive of both endpoints."""
    dx, dy = g.delta(src, dst)
    x, y = src
    path = [src]
    step = 1 if dx > 0 else -1
    for _ in range(abs(dx)):
        x, y = g.normalize(x + step, y)
        path.append((x, y))
    step = 1 if dy > 0 else -1
    for _ in range(abs(dy)):
        x, y = g.normalize(x, y + step)
        path.append((x, y))
    return path


def label_route_step(g: MeshGrid, cur: Coord, target: Coord, high: bool) -> Coord:
    """One hop of the dual-path routing function.

    high=True: next = argmax over neighbors of label(v) s.t. label(v) <= label(target)
    high=False: next = argmin over neighbors of label(v) s.t. label(v) >= label(target)
    """
    lt = g.label(*target)
    best = None
    best_lab = None
    for v in g.neighbors(*cur):
        lv = g.label(*v)
        if high:
            if lv <= lt and (best_lab is None or lv > best_lab):
                best, best_lab = v, lv
        else:
            if lv >= lt and (best_lab is None or lv < best_lab):
                best, best_lab = v, lv
    if best is None:  # cannot happen on a connected mesh with valid direction
        raise RuntimeError(f"label_route stuck at {cur} -> {target} (high={high})")
    return best


def label_route(g: MeshGrid, src: Coord, dst: Coord, high: bool) -> list[Coord]:
    """Full label-ordered route src -> dst inside one subnetwork."""
    path = [src]
    cur = src
    guard = 4 * g.num_nodes
    while cur != dst:
        cur = label_route_step(g, cur, dst, high)
        path.append(cur)
        guard -= 1
        if guard == 0:
            raise RuntimeError("label_route did not converge")
    return path


def path_multicast(
    g: MeshGrid, src: Coord, dests: list[Coord], high: bool
) -> list[Coord]:
    """Path-based multicast: visit ``dests`` in label order within a subnetwork.

    ``high=True`` visits in ascending label order (all dest labels must be
    > label(src)); ``high=False`` descending. A destination passed through en
    route is considered delivered at that point (wormhole pass-through
    delivery), so the walk always heads for the nearest-in-label-order
    unvisited destination.
    Returns the full hop sequence (deliveries are simply path points that are
    destinations).
    """
    if not dests:
        return [src]
    remaining = sorted(dests, key=lambda d: g.label(*d), reverse=not high)
    path = [src]
    cur = src
    pending = list(remaining)
    while pending:
        target = pending[0]
        cur = label_route_step(g, cur, target, high)
        path.append(cur)
        pending = [d for d in pending if d != cur]
    return path


def greedy_tour(g: MeshGrid, src: Coord, dests: list[Coord]) -> list[Coord]:
    """NMP-style tour: repeatedly go (XY) to the nearest remaining destination."""
    path = [src]
    cur = src
    pending = list(dests)
    while pending:
        nxt = min(pending, key=lambda d: (g.distance(cur, d), g.row_major(*d)))
        leg = xy_route(g, cur, nxt)
        path.extend(leg[1:])
        cur = nxt
        pending = [d for d in pending if d != cur]
        # pass-through deliveries on the leg
        leg_set = set(leg)
        pending = [d for d in pending if d not in leg_set]
    return path


def dual_path_cost(g: MeshGrid, src: Coord, dests: list[Coord]) -> int:
    """Hop count of dual-path routing from ``src`` (Definition 2's C_p).

    Destinations with label > label(src) are served by the high-channel chain
    in ascending order; label < label(src) by the low-channel chain in
    descending order.
    """
    ls = g.label(*src)
    d_h = [d for d in dests if g.label(*d) > ls]
    d_l = [d for d in dests if g.label(*d) < ls]
    cost = 0
    if d_h:
        cost += len(path_multicast(g, src, d_h, high=True)) - 1
    if d_l:
        cost += len(path_multicast(g, src, d_l, high=False)) - 1
    return cost


def multi_unicast_cost(g: MeshGrid, src: Coord, dests: list[Coord]) -> int:
    """Definition 2's C_t: sum of minimal distances src -> each destination
    (Manhattan on the mesh, toroidal Manhattan on the torus)."""
    return sum(g.distance(src, d) for d in dests)
