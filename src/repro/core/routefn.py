"""Route-provider layer: pluggable routing functions + fault-aware topologies.

Every hop sequence in this repo used to come from three free functions in
``core/routing.py`` (dimension-ordered XY, the Lin-McKinley label rule, NMP's
greedy tour) that silently assumed a *fully working* mesh/torus. This module
lifts that assumption into an explicit layer (DESIGN.md §7):

* ``RouteProvider`` — the protocol the planners, cost models, and both
  simulators route through: ``unicast`` (full hop sequence), ``label_step``
  (one hop of the dual-path rule), and ``link_weights`` (a per-directed-link
  price vector for device-side batched planning).
* ``MinimalRouteProvider`` — the paper's routing functions, verbatim. This is
  the provider every fault-free topology resolves to, so provider-backed
  routes are bit-identical to the legacy ``core/routing.py`` output there.
* ``FaultyTopology`` — any ``MeshGrid``/``Torus`` plus a set of broken
  (bidirectional) links. Geometry (labels, deltas, partitions) delegates to
  the base topology; ``neighbors`` drops broken links and ``distance``
  becomes the BFS shortest-path distance on the degraded graph, so
  Definition 1 representatives and Definition 2 costs adapt to faults.
* ``FaultAwareProvider`` — detours: the dimension-ordered route is kept
  whenever it is clean, otherwise the BFS shortest path on the degraded
  graph is used; the label rule falls back to a BFS hop when every
  label-legal neighbor link is broken. A destination cut off from the
  source raises ``DisconnectedError`` with the offending pair.

``provider_for(topo)`` resolves the provider: plain topologies (and
``faulty(topo, ())``, which returns the base unchanged) get the minimal
provider; degraded topologies get the fault-aware one. ``route_cost_matrices``
lowers a (topology, cost model) pair to the dense per-pair tensors the
weighted Pallas planner kernel (kernels/dpm_cost) consumes.
"""
from __future__ import annotations

import functools
import zlib
from collections import deque
from dataclasses import dataclass

import numpy as np

from .grid import Coord, MeshGrid

Link = tuple[Coord, Coord]

# Directed-link id space shared with noc.xsim: idx(u) * ports + dir(u->v),
# directions ordered +x, -x, +y, -y (+z, -z on the 3-D topologies); each
# topology's ``ports``/``direction`` hooks define the layout.


class DisconnectedError(RuntimeError):
    """A routing destination is unreachable on the degraded topology."""


def _canon(topo: MeshGrid, u: Coord, v: Coord) -> Link:
    """Canonical (sorted) form of an undirected link."""
    u = topo.normalize(*u)
    v = topo.normalize(*v)
    return (u, v) if u <= v else (v, u)


# ---------------------------------------------------------------------------
# Fault model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultyTopology:
    """A mesh/torus with a set of broken bidirectional links.

    Wraps (rather than subclasses) the base topology: labeling, deltas,
    wedges, and coordinate handling are the base's — a fault changes which
    links a worm may cross, not where a node sits — while ``neighbors``
    excludes broken links and ``distance`` is the BFS shortest-path hop
    count on the degraded graph (computed lazily, cached per source).

    ``faults`` is the canonical sorted tuple of broken links; it is the
    component the planner cache keys on (``core.planner.plan``), so plans
    for different fault sets never alias. Instances are interned by the
    ``faulty`` factory, like ``grid``/``torus``.
    """

    base: MeshGrid
    faults: tuple[Link, ...]

    # -- delegated structure -------------------------------------------------
    @property
    def kind(self) -> str:  # algorithms' topology-capability checks pass
        return self.base.kind

    @property
    def wrap(self) -> bool:
        return self.base.wrap

    @property
    def n(self) -> int:
        return self.base.n

    @property
    def m(self) -> int | None:
        return self.base.m

    @property
    def rows(self) -> int:
        return self.base.rows

    @property
    def num_nodes(self) -> int:
        return self.base.num_nodes

    @property
    def ports(self) -> int:
        return getattr(self.base, "ports", 4)

    @property
    def params(self) -> tuple:
        return getattr(self.base, "params", ())

    @property
    def needs_bfs_routes(self) -> bool:
        return getattr(self.base, "needs_bfs_routes", False)

    def label(self, *c) -> int:
        return self.base.label(*c)

    def unlabel(self, lab: int) -> Coord:
        return self.base.unlabel(lab)

    def row_major(self, *c) -> int:
        return self.base.row_major(*c)

    def idx(self, c: Coord) -> int:
        return self.base.idx(c)

    def from_idx(self, i: int) -> Coord:
        return self.base.from_idx(i)

    def in_bounds(self, *c) -> bool:
        return self.base.in_bounds(*c)

    def normalize(self, *c) -> Coord:
        return self.base.normalize(*c)

    def delta(self, a: Coord, b: Coord) -> Coord:
        """Signed geometric displacement of the *base* topology: partition
        membership (Definitions 1-3 wedges) stays geometric under faults."""
        return self.base.delta(a, b)

    def direction(self, u: Coord, v: Coord) -> int:
        return self.base.direction(u, v)

    def dir_delta(self, d: int) -> Coord:
        return self.base.dir_delta(d)

    def link_weight(self, u: Coord, v: Coord) -> float:
        return self.base.link_weight(u, v)

    def nodes(self) -> list[Coord]:
        return self.base.nodes()

    def all_labels(self) -> np.ndarray:
        return self.base.all_labels()

    def label_table(self) -> np.ndarray:
        return self.base.label_table()

    # -- degraded geometry ---------------------------------------------------
    def is_broken(self, u: Coord, v: Coord) -> bool:
        return _canon(self.base, u, v) in self._broken

    @functools.cached_property
    def _broken(self) -> frozenset[Link]:
        return frozenset(self.faults)

    def neighbors(self, *c) -> list[Coord]:
        u = self.base.normalize(*c)
        return [v for v in self.base.neighbors(*u) if not self.is_broken(u, v)]

    def distance(self, a: Coord, b: Coord) -> int:
        """BFS shortest-path hop count on the degraded graph — this is what
        Definition 1 (representative = nearest destination) and the hop cost
        model see, which is how DPM's merge loop adapts to faults."""
        d = _bfs_from(self, self.base.normalize(*a)).get(self.base.normalize(*b))
        if d is None:
            raise DisconnectedError(
                f"{b} unreachable from {a} on {self.base.kind} "
                f"{self.n}x{self.rows} with {len(self.faults)} broken links"
            )
        return d[0]

    def manhattan(self, a: Coord, b: Coord) -> int:
        return self.distance(a, b)


# Bounded (unlike the grid/torus factories): fault sets are combinatorially
# many, so a sweep over random fault sets must not retain every instance
# forever. Eviction is safe — FaultyTopology is a frozen dataclass, so two
# equal instances hash/compare equal everywhere they key caches.
@functools.lru_cache(maxsize=4096)
def _faulty(base: MeshGrid, faults: tuple[Link, ...]) -> FaultyTopology:
    return FaultyTopology(base, faults)


def faulty(base: MeshGrid, broken: tuple | list | set) -> MeshGrid:
    """Interned degraded-topology factory.

    ``broken`` is any iterable of ``(u, v)`` link pairs (order- and
    direction-insensitive; coordinates are normalized). Links that do not
    exist on the base topology raise. An empty set returns the base
    unchanged, so fault-free callers keep the exact legacy routing path.
    """
    if isinstance(base, FaultyTopology):
        broken = set(broken) | set(base.faults)
        base = base.base
    canon = {_canon(base, u, v) for u, v in broken}
    for u, v in canon:
        if v not in base.neighbors(*u):
            raise ValueError(f"({u}, {v}) is not a link of {base}")
    if not canon:
        return base
    return _faulty(base, tuple(sorted(canon)))


def router_failure(topo: MeshGrid, *nodes: Coord) -> tuple[Link, ...]:
    """Clustered fault region: a failed *router* takes down every link
    incident to it (the paper's link-fault model composes — a router fault
    is just the closure of its port links).

    Returns the canonical link tuple, ready for ``faulty(topo, links)`` or
    ``NoCConfig(broken_links=links)``. Composes with an already-degraded
    topology (links broken twice stay broken once). The failed router
    itself becomes unreachable — callers must keep it out of source and
    destination sets (planning to it raises ``DisconnectedError``).
    """
    base = topo.base if isinstance(topo, FaultyTopology) else topo
    links: set[Link] = set()
    for node in nodes:
        u = tuple(node)
        if not base.in_bounds(*u):
            raise ValueError(f"{node} is not a node of {base}")
        for v in base.neighbors(*u):
            links.add(_canon(base, u, v))
    return tuple(sorted(links))


@functools.lru_cache(maxsize=32_768)
def _bfs_from(topo: FaultyTopology, src: Coord) -> dict[Coord, tuple[int, Coord]]:
    """BFS tree over the degraded graph: node -> (distance, predecessor).

    Deterministic: neighbors expand in ``neighbors()`` order and the first
    predecessor found wins, so detoured routes are reproducible.
    """
    out: dict[Coord, tuple[int, Coord]] = {src: (0, src)}
    q = deque([src])
    while q:
        u = q.popleft()
        du = out[u][0]
        for v in topo.neighbors(*u):
            if v not in out:
                out[v] = (du + 1, u)
                q.append(v)
    return out


# ---------------------------------------------------------------------------
# Providers
# ---------------------------------------------------------------------------
class RouteProvider:
    """Produces the hop sequences every cost evaluation and simulator uses.

    ``unicast`` returns the full hop sequence (inclusive of both endpoints);
    ``label_step`` advances one hop of the dual-path (Lin-McKinley) routing
    function; ``link_weights`` prices every directed link for device-side
    batched planning (the weighted dpm_cost kernel).
    """

    name = "abstract"

    def unicast(self, topo: MeshGrid, src: Coord, dst: Coord) -> list[Coord]:
        raise NotImplementedError

    def label_step(
        self, topo: MeshGrid, cur: Coord, target: Coord, high: bool
    ) -> Coord:
        raise NotImplementedError

    def link_weights(self, topo: MeshGrid, cost_model=None) -> np.ndarray:
        """(num_nodes * ports,) float32 price per directed link id (the
        xsim id space ``idx(u) * ports + dir``); absent links hold +inf —
        including broken links on a degraded topology and undeclared
        boundary crossings on a chiplet package."""
        D = getattr(topo, "ports", 4)
        w = np.full(topo.num_nodes * D, np.inf, np.float32)
        for u in topo.nodes():
            base = topo.idx(u) * D
            for v in topo.neighbors(*u):
                w[base + topo.direction(u, v)] = (
                    1.0 if cost_model is None
                    else cost_model.link_cost(topo, u, v)
                )
        return w


class MinimalRouteProvider(RouteProvider):
    """The paper's routing functions, verbatim (fault-free topologies)."""

    name = "minimal"

    def unicast(self, topo: MeshGrid, src: Coord, dst: Coord) -> list[Coord]:
        """Dimension-ordered (XY[Z]) minimal route; each dimension travels
        its signed shortest leg (``Topology.delta``) in dimension order,
        so the length always equals ``Topology.distance``."""
        d = topo.delta(src, dst)
        cur = tuple(src)
        path = [src]
        for axis, leg in enumerate(d):
            step = 1 if leg > 0 else -1
            for _ in range(abs(leg)):
                nxt = list(cur)
                nxt[axis] += step
                cur = topo.normalize(*nxt)
                path.append(cur)
        return path

    def label_step(
        self, topo: MeshGrid, cur: Coord, target: Coord, high: bool
    ) -> Coord:
        """One hop of the dual-path routing function.

        high=True: argmax over neighbors of label(v) s.t. label(v) <= label(target)
        high=False: the mirror rule (argmin s.t. label(v) >= label(target)).
        """
        lt = topo.label(*target)
        best = None
        best_lab = None
        for v in topo.neighbors(*cur):
            lv = topo.label(*v)
            if high:
                if lv <= lt and (best_lab is None or lv > best_lab):
                    best, best_lab = v, lv
            else:
                if lv >= lt and (best_lab is None or lv < best_lab):
                    best, best_lab = v, lv
        if best is None:  # cannot happen on a connected mesh with valid direction
            raise RuntimeError(f"label_route stuck at {cur} -> {target} (high={high})")
        return best


class FaultAwareProvider(RouteProvider):
    """Detours around broken links instead of merely re-pricing them.

    * ``unicast``: the dimension-ordered route when it crosses no broken
      link (bit-identical to the minimal provider — the common case under
      sparse faults), otherwise the BFS shortest path on the degraded graph.
    * ``label_step``: the label rule over *live* neighbors, accepted only
      when it makes strict label progress toward the target without moving
      away from it (BFS distance does not increase); otherwise one hop of
      the BFS shortest path. Every step therefore either strictly decreases
      the BFS distance or keeps it while strictly advancing the label, so
      chain walks are loop-free and terminate (DESIGN.md §7).

    Detours *load-balance*: a BFS tree has one arbitrary predecessor per
    node, so every detour around a fault region funneled through the same
    few links (the first-expanded ones). ``_bfs_path`` instead walks back
    through the full set of equal-length predecessors, tie-breaking with a
    deterministic per-(src, dst) digest — distinct flows spread across the
    equal-cost detours instead of piling onto one, while every route stays
    a BFS-shortest path and is reproducible run to run.
    """

    name = "fault-aware"
    _minimal = MinimalRouteProvider()

    def unicast(self, topo: FaultyTopology, src: Coord, dst: Coord) -> list[Coord]:
        if getattr(topo, "needs_bfs_routes", False):
            # sparse-link base (chiplet package): dimension-ordered routes
            # may cross links that do not exist at all — always BFS
            return self._bfs_path(topo, src, dst)
        path = self._minimal.unicast(topo.base, src, dst)
        if not any(topo.is_broken(u, v) for u, v in zip(path, path[1:])):
            return path
        return self._bfs_path(topo, src, dst)

    @staticmethod
    def _bfs_path(topo: FaultyTopology, src: Coord, dst: Coord) -> list[Coord]:
        src = topo.normalize(*src)
        tree = _bfs_from(topo, src)
        dst = topo.normalize(*dst)
        if dst not in tree:
            raise DisconnectedError(
                f"{dst} unreachable from {src} on degraded {topo.kind} "
                f"({len(getattr(topo, 'faults', ()))} broken links)"
            )
        # stable digest, NOT hash(): str hashing is salted per process
        flow = zlib.crc32(repr((src, dst)).encode())
        path = [dst]
        while path[-1] != src:
            u = path[-1]
            du = tree[u][0]
            preds = [
                v for v in topo.neighbors(*u)
                if tree.get(v, (du,))[0] == du - 1
            ]
            path.append(min(
                preds,
                key=lambda v: zlib.crc32(repr((flow, u, v)).encode()),
            ))
        path.reverse()
        return path

    def label_step(
        self, topo: FaultyTopology, cur: Coord, target: Coord, high: bool
    ) -> Coord:
        dists = _bfs_from(topo, topo.normalize(*target))
        cur_n = topo.normalize(*cur)
        if cur_n not in dists:
            raise DisconnectedError(
                f"{target} unreachable from {cur} on degraded {topo.kind} "
                f"({len(getattr(topo, 'faults', ()))} broken links)"
            )
        dcur = dists[cur_n][0]
        lt = topo.label(*target)
        lc = topo.label(*cur_n)
        best = None
        best_lab = None
        for v in topo.neighbors(*cur_n):  # live links only
            lv = topo.label(*v)
            if dists.get(v, (dcur + 1,))[0] > dcur:
                continue  # never move away from the target
            if high:
                if lc < lv <= lt and (best_lab is None or lv > best_lab):
                    best, best_lab = v, lv
            else:
                if lc > lv >= lt and (best_lab is None or lv < best_lab):
                    best, best_lab = v, lv
        if best is not None:
            return best
        # BFS fallback: the deterministic first neighbor one hop closer.
        for v in topo.neighbors(*cur_n):
            if dists.get(v, (dcur,))[0] == dcur - 1:
                return v
        raise RuntimeError(f"label_step stuck at {cur} -> {target} (high={high})")

    # link_weights is inherited: it already prices only live ``neighbors()``
    # links, so on a FaultyTopology broken links stay +inf and any
    # device-side plan crossing one prices itself out of the comparison.


class BFSRouteProvider(MinimalRouteProvider):
    """Sparse-link topologies (chiplet packages, ``needs_bfs_routes``).

    The label rule is inherited unchanged — its termination argument only
    needs the snake successor to be a neighbor, which the two-level
    chiplet snake guarantees — but dimension-ordered unicast may cross
    links the interposer does not provide, so ``unicast`` is the
    deterministic load-balanced BFS shortest path instead.
    """

    name = "bfs"

    def unicast(self, topo: MeshGrid, src: Coord, dst: Coord) -> list[Coord]:
        return FaultAwareProvider._bfs_path(topo, src, dst)


_MINIMAL = MinimalRouteProvider()
_FAULT_AWARE = FaultAwareProvider()
_BFS = BFSRouteProvider()


def provider_for(topo: MeshGrid) -> RouteProvider:
    """Resolve the route provider for a topology: degraded topologies get
    the detouring provider, sparse-link topologies the BFS one, everything
    else the paper's minimal functions (``faulty(topo, ())`` returns the
    base, so an empty fault set stays on the bit-identical legacy path)."""
    if isinstance(topo, FaultyTopology):
        return _FAULT_AWARE
    if getattr(topo, "needs_bfs_routes", False):
        return _BFS
    return _MINIMAL


# ---------------------------------------------------------------------------
# Dense lowering for the weighted Pallas planner kernel
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=256)
def _route_cost_matrices_cached(topo: MeshGrid, cm) -> tuple:
    NN = topo.num_nodes
    nodes = topo.nodes()
    dist = np.zeros((NN, NN), np.int32)
    weight = np.zeros((NN, NN), np.float32)
    provider = provider_for(topo)
    for u in nodes:
        iu = topo.idx(u)
        for v in nodes:
            if u == v:
                continue
            route = provider.unicast(topo, u, v)
            dist[iu, topo.idx(v)] = len(route) - 1
            weight[iu, topo.idx(v)] = (
                len(route) - 1 if cm is None else cm.route_cost(topo, route)
            )
    overhead = 0.0 if cm is None else float(cm.packet_overhead(topo))
    return dist, weight, overhead


def route_cost_matrices(
    topo: MeshGrid, cost_model=None
) -> tuple[np.ndarray, np.ndarray, float]:
    """Lower (topology, cost model) to the dense tensors the weighted
    ``kernels/dpm_cost`` path batches over:

    * ``dist[u, v]``   int32 provider-route hop count (detours included) —
      the Definition 1 representative-selection metric;
    * ``weight[u, v]`` float32 provider-route price under ``cost_model``
      (hop count when None) — the Definition 2 C_t per-destination term;
    * ``overhead``     the model's per-worm injection price.

    Node indices are row-major (``Topology.idx``), matching the kernel's
    numbering. Results are cached per (topology, model) instance pair — both
    are interned/registered singletons in normal use. Unreachable pairs on a
    degraded topology raise ``DisconnectedError``.
    """
    return _route_cost_matrices_cached(topo, cost_model)
