"""2-D mesh geometry and Hamiltonian (boustrophedon) labeling.

The paper labels each node of an n x n mesh as

    L(x, y) = y*n + x          if y is even
    L(x, y) = y*n + n - x - 1  if y is odd

which traces a Hamiltonian ("snake") path 0, 1, ..., n^2-1 through the mesh.
The dual-path / multipath family of algorithms routes along this label order;
the high-channel subnetwork contains every mesh link directed from a lower to
a higher label and the low-channel subnetwork the reverse direction.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

Coord = tuple[int, int]

# canonical 2-D direction order (+x, -x, +y, -y) — directed-link ids are
# idx(u) * ports + direction, shared by telemetry and the xsim geometry
DIRS2 = ((1, 0), (-1, 0), (0, 1), (0, -1))
_DIR_OF2 = {d: i for i, d in enumerate(DIRS2)}


@dataclass(frozen=True)
class MeshGrid:
    """An n_cols x n_rows 2-D mesh (the paper uses square 8x8).

    This is the mesh instance of the ``Topology`` protocol (see
    core/topology.py); ``Torus`` subclasses it with wraparound geometry.
    """

    n: int  # columns (x in [0, n))
    m: int | None = None  # rows (y in [0, m)); defaults to n

    kind = "mesh"  # topology discriminator (planner cache key)
    wrap = False
    ports = 4  # output ports per router (directed-link ids span idx*ports+dir)
    params = ()  # extra factory args beyond (n, m) — planner cache-key suffix

    @property
    def rows(self) -> int:
        return self.m if self.m is not None else self.n

    @property
    def num_nodes(self) -> int:
        return self.n * self.rows

    # -- labeling ------------------------------------------------------------
    def label(self, x: int, y: int) -> int:
        """Boustrophedon label used by dual-path/MP/DPM."""
        if y % 2 == 0:
            return y * self.n + x
        return y * self.n + self.n - x - 1

    def unlabel(self, lab: int) -> Coord:
        y, r = divmod(lab, self.n)
        x = r if y % 2 == 0 else self.n - r - 1
        return x, y

    def row_major(self, x: int, y: int) -> int:
        """Row-major label L = y*n + x (used by NMP [18])."""
        return y * self.n + x

    def idx(self, c: Coord) -> int:
        """Row-major rank index of a node (the kernels' node numbering)."""
        return c[1] * self.n + c[0]

    # -- geometry ------------------------------------------------------------
    def in_bounds(self, x: int, y: int) -> bool:
        return 0 <= x < self.n and 0 <= y < self.rows

    def normalize(self, x: int, y: int) -> Coord:
        """Canonical coordinates (identity on a mesh, modulo on a torus)."""
        return x, y

    def neighbors(self, x: int, y: int) -> list[Coord]:
        out = []
        for dx, dy in ((1, 0), (-1, 0), (0, 1), (0, -1)):
            nx, ny = x + dx, y + dy
            if self.in_bounds(nx, ny):
                out.append((nx, ny))
        return out

    def delta(self, a: Coord, b: Coord) -> Coord:
        """Signed per-dimension displacement of a minimal route a -> b."""
        return b[0] - a[0], b[1] - a[1]

    def distance(self, a: Coord, b: Coord) -> int:
        """Minimal hop count a -> b (Manhattan; toroidal on a torus)."""
        dx, dy = self.delta(a, b)
        return abs(dx) + abs(dy)

    # -- directed-link geometry (telemetry / xsim port numbering) -----------
    def direction(self, u: Coord, v: Coord) -> int:
        """Port index in [0, ports) of the directed link u -> v."""
        d = _DIR_OF2.get(tuple(self.delta(u, v)))
        if d is None:
            raise ValueError(f"{u}->{v} is not a single-hop link")
        return d

    def dir_delta(self, d: int) -> Coord:
        """Unit displacement of port ``d`` (inverse of ``direction``)."""
        return DIRS2[d]

    def link_weight(self, u: Coord, v: Coord) -> float:
        """Relative price class of link u -> v (1.0 = planar baseline;
        heterogeneous topologies price TSV / interposer links higher)."""
        return 1.0

    def from_idx(self, i: int) -> Coord:
        """Inverse of ``idx`` (the kernels' node numbering)."""
        y, x = divmod(i, self.n)
        return x, y

    def nodes(self) -> list[Coord]:
        """All node coordinates in ``idx`` order."""
        return [self.from_idx(i) for i in range(self.num_nodes)]

    @staticmethod
    def manhattan(a: Coord, b: Coord) -> int:
        return abs(a[0] - b[0]) + abs(a[1] - b[1])

    # -- vectorized helpers (used by the jnp reference / kernels) -----------
    def all_labels(self) -> np.ndarray:
        """(rows, n) array of boustrophedon labels."""
        ys, xs = np.mgrid[0 : self.rows, 0 : self.n]
        even = ys % 2 == 0
        return np.where(even, ys * self.n + xs, ys * self.n + self.n - xs - 1)

    def label_table(self) -> np.ndarray:
        """label -> (x, y), shape (num_nodes, 2)."""
        out = np.zeros((self.num_nodes, 2), dtype=np.int32)
        for y in range(self.rows):
            for x in range(self.n):
                out[self.label(x, y)] = (x, y)
        return out


@functools.lru_cache(maxsize=None)
def _grid(n: int, m: int) -> MeshGrid:
    return MeshGrid(n, m)


def grid(n: int, m: int | None = None) -> MeshGrid:
    """Interned mesh factory. ``m`` is normalized (grid(8) is grid(8, 8)) so
    equivalent geometries share one instance and one planner-cache key."""
    return _grid(n, n if m is None else m)
