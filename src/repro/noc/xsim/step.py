"""One simulated cycle of the vectorized wormhole model, as masked array ops.

The working set is a pool of ``K`` *slots* for in-flight worms, not the full
packet list: packets backlogged in NI lane queues cost nothing until they
reach the front of their lane (the host simulator's queues are modeled by
static per-lane injection orders + one pointer per lane), so the per-cycle
cost is bounded by network capacity — every in-network worm holds at least
one VC or is a lane front, so ``K <= 2*V*L + 2*NN`` always suffices — and is
independent of injection rate or backlog. That inversion is what makes the
scan competitive: the event-ordered Python sim pays per queued event, xsim
pays per (cycle, slot).

State layout (all dense, fixed shape — scan/vmap safe):

* ``sfpos[K, F]`` stage of each flit of the slot's worm: -1 = in the source
  NI, ``s`` in [0, num_stages) = in stage ``s``'s VC FIFO, ``num_stages`` =
  ejected. Flit 0 is the header, flit F-1 the tail; positions are
  non-increasing along the flit axis, and a flit is the *front* of its FIFO
  iff the previous flit has already left its stage.
* ``sp[K]``       packet id occupying the slot (-1 free); ``slot_of[P]``
  the inverse map (set once — a packet is slotted exactly once).
* ``vc_used[2L]`` VCs in use per (directed link, class) — credit state.
* ``ptr``, ``front_slot`` per lane: the static-order injection queues.
* ``crel[C]``     per-child released flag (DPM children release when the
  parent header has entered the representative's stage — read through
  ``slot_of``; a vacated or recycled parent slot means the parent header
  passed everything, so the child is free).
* ``dtime[P, S]`` tail-arrival cycle per delivery stage (-1 = pending).

Per cycle, two ``kernels.noc_step.arbitrate`` segmented-min rounds resolve
the shared resources in the host sim's phase order: FIFO-front flits below
their final stage request the link into their next stage (one winner per
directed link; headers additionally need a free VC of the hop's label
class, body flits a buffer credit), then — on post-move state — flits
fronting their final stage request their node's ejection port. Ages are
(enqueue, pid, fid), the host sim's sort key. The ejection round compacts
to (K,) candidates because at most one flit per slot can front its final
stage.

Fidelity deltas vs the event-ordered host sim (DESIGN.md §5): admissibility
uses start-of-cycle state (a VC freed in cycle t is re-allocable in t+1,
where the host sim's sequential link loop can reuse it within t), and
same-lane DPM children inject in static (enqueue, pid) order rather than
dynamic parent-arrival order. Both shift individual stall cycles only —
delivery sets are unaffected and average latency stays inside the
documented 10% band.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...kernels.noc_step.noc_step import NOC_INF
from ...kernels.noc_step.ops import arbitrate

# counter indices (named after the SimStats fields they feed; see run.py —
# slots_hwm is xsim-only: the in-flight-worm high-water mark, for sizing K)
CTR = (
    "flit_link_traversals", "buffer_writes", "buffer_reads",
    "xbar_traversals", "arbitrations", "ni_flits", "packets_finished",
    "slots_hwm",
)
_I = {name: i for i, name in enumerate(CTR)}


class SlotState(NamedTuple):
    sfpos: jax.Array  # (K, F) int32
    sp: jax.Array  # (K,) int32, -1 free
    slot_of: jax.Array  # (P,) int32, -1 never slotted
    vc_used: jax.Array  # (2L,) int32
    ptr: jax.Array  # (2NN,) int32 — next lane_seq index per lane
    front_slot: jax.Array  # (2NN,) int32, -1 none
    crel: jax.Array  # (C,) bool
    dtime: jax.Array  # (P, S) int32
    ctr: jax.Array  # (len(CTR),) int32
    overflow: jax.Array  # () bool — a lane needed a slot and none was free


def init_state(P: int, F: int, S: int, L: int, NN: int, C: int,
               K: int) -> SlotState:
    return SlotState(
        sfpos=jnp.full((K, F), -1, jnp.int32),
        sp=jnp.full((K,), -1, jnp.int32),
        slot_of=jnp.full((P,), -1, jnp.int32),
        vc_used=jnp.zeros((2 * L,), jnp.int32),
        ptr=jnp.zeros((2 * NN,), jnp.int32),
        front_slot=jnp.full((2 * NN,), -1, jnp.int32),
        crel=jnp.zeros((C,), bool),
        dtime=jnp.full((P, S), -1, jnp.int32),
        ctr=jnp.zeros((len(CTR),), jnp.int32),
        overflow=jnp.zeros((), bool),
    )


def _front(sfpos: jax.Array) -> jax.Array:
    """(K, F) bool: flit is the front of the FIFO it currently occupies."""
    K = sfpos.shape[0]
    return jnp.concatenate(
        [jnp.ones((K, 1), bool), sfpos[:, :-1] > sfpos[:, 1:]], axis=1
    )


def make_step(tr: dict, *, F: int, V: int, BD: int, L: int, NN: int,
              K: int, backend: str):
    """Build the scan body over one compiled-traffic tensor dict ``tr``."""
    enqueue = jnp.asarray(tr["enqueue"], jnp.int32)  # (P,)
    lane = jnp.asarray(tr["lane"], jnp.int32)
    ns = jnp.asarray(tr["num_stages"], jnp.int32)
    eject_node = jnp.asarray(tr["eject_node"], jnp.int32)
    link_t = jnp.asarray(tr["link"], jnp.int32)  # (P, S)
    vcls_t = jnp.asarray(tr["vcls"], jnp.int32)
    deliver_t = jnp.asarray(tr["deliver"], bool)
    lane_seq = jnp.asarray(tr["lane_seq"], jnp.int32)  # (2NN, Q)
    child_ix = jnp.asarray(tr["child_ix"], jnp.int32)  # (P,)
    child_parent = jnp.asarray(tr["child_parent"], jnp.int32)  # (C,)
    child_rs = jnp.asarray(tr["child_rs"], jnp.int32)
    child_enq = jnp.asarray(tr["child_enq"], jnp.int32)

    P, S = link_t.shape
    Q = lane_seq.shape[1]
    C = child_parent.shape[0]
    kid = jnp.arange(K, dtype=jnp.int32)
    fid = jnp.arange(F, dtype=jnp.int32)
    is_hdr = (fid == 0)[None, :]

    def _vci(spc, stage_idx):
        """(link, class) slot index for per-slot stage indices."""
        c = jnp.clip(stage_idx, 0, S - 1)
        return link_t[spc, c] * 2 + vcls_t[spc, c]

    def step(state: SlotState, t: jax.Array) -> tuple[SlotState, None]:
        (sfpos, sp, slot_of, vc_used, ptr, front_slot, crel, dtime, ctr,
         ovf) = state

        # ---- 1. child release (parent header progress, pre-move state) --
        ps = slot_of[jnp.clip(child_parent, 0, P - 1)]
        ps_c = jnp.clip(ps, 0, K - 1)
        # vacated/recycled parent slot => parent header passed everything
        par_head = jnp.where(
            ps < 0, -1,
            jnp.where(sp[ps_c] == child_parent, sfpos[ps_c, 0], NOC_INF),
        )
        crel = crel | ((child_enq <= t) & (par_head >= child_rs))

        # ---- 2. lane fronts + slot allocation ---------------------------
        fs_c = jnp.clip(front_slot, 0, K - 1)
        front_live = (
            (front_slot >= 0) & (sp[fs_c] >= 0) & (sfpos[fs_c, F - 1] == -1)
        )
        need = ~front_live
        cand_pid = jnp.take_along_axis(
            lane_seq, jnp.clip(ptr, 0, Q - 1)[:, None], axis=1
        )[:, 0]
        qp = jnp.clip(cand_pid, 0, P - 1)
        cix = child_ix[qp]
        rel = jnp.where(
            cix < 0, enqueue[qp] <= t, crel[jnp.clip(cix, 0, C - 1)]
        )
        want = need & (ptr < Q) & (cand_pid >= 0) & rel
        free = sp < 0
        fcum = jnp.cumsum(free)
        nfree = fcum[-1]
        wrank = jnp.cumsum(want) - 1
        got = want & (wrank < nfree)
        ovf = ovf | jnp.any(want & ~got)
        # r-th free slot = first index where the running free count hits r+1
        lane_slot = jnp.searchsorted(fcum, wrank + 1).astype(jnp.int32)
        tgt = jnp.where(got, lane_slot, K)
        sp = sp.at[tgt].set(cand_pid, mode="drop")
        sfpos = sfpos.at[tgt].set(-1, mode="drop")
        slot_of = slot_of.at[jnp.where(got, cand_pid, P)].set(
            lane_slot, mode="drop"
        )
        front_slot = jnp.where(need, jnp.where(got, lane_slot, -1),
                               front_slot)
        ptr = ptr + got

        # ---- 3. link arbitration ----------------------------------------
        spc = jnp.clip(sp, 0, P - 1)
        alive = sp >= 0
        ns_s = ns[spc]
        enq_s = enqueue[spc]
        isf = front_slot[lane[spc]] == kid
        front = _front(sfpos)
        to = sfpos + 1
        in_ni = sfpos == -1
        can = front & alive[:, None]
        move_c = can & (to < ns_s[:, None]) & (~in_ni | isf[:, None])
        toc = jnp.clip(to, 0, S - 1)
        lk = link_t[spc[:, None], toc]
        vci_to = lk * 2 + vcls_t[spc[:, None], toc]
        if BD >= F:
            # a VC FIFO only ever holds its owner's flits, so with
            # buffer_depth >= flits_per_packet the credit check cannot fail
            body_ok = True
        else:
            occ_to = jnp.sum(
                sfpos[:, None, :] == to[:, :, None], axis=2, dtype=jnp.int32
            )
            body_ok = occ_to < BD
        adm = move_c & jnp.where(is_hdr, vc_used[vci_to] < V, body_ok)
        # unique age key: (enqueue, pid, fid) lexicographic, int32-safe
        # (compile.py asserts (max_enqueue + 1) * P * F < 2**28 < NOC_INF)
        fkey = (enq_s[:, None] * P + spc[:, None]) * F + fid[None, :]
        mv_win = arbitrate(adm, fkey, lk, L, backend=backend)
        sfpos = sfpos + mv_win.astype(jnp.int32)
        hdr_win = mv_win[:, 0]
        tail_from = sfpos[:, F - 1] - mv_win[:, F - 1]  # pre-move position
        tail_mv = mv_win[:, F - 1] & (tail_from >= 0)

        # tail arrival records deliveries (first visit only, by construction)
        to_tail = jnp.clip(to[:, F - 1], 0, S - 1)
        del_here = mv_win[:, F - 1] & deliver_t[spc, to_tail]
        dtime = dtime.at[jnp.where(del_here, spc, P), to_tail].set(
            t, mode="drop"
        )

        # ---- 4. ejection (post-move state, host-sim phase order) --------
        # at most one flit per slot can front the final stage, so the
        # per-node round compacts to (K,) candidates
        ecand_f = (
            _front(sfpos) & (sfpos == ns_s[:, None] - 1) & alive[:, None]
        )
        has_e = ecand_f.any(axis=1)
        efid = jnp.argmax(ecand_f, axis=1).astype(jnp.int32)
        ekey = (enq_s * P + spc) * F + efid
        e_win = arbitrate(has_e, ekey, eject_node[spc], NN, backend=backend)
        ej_win = ecand_f & e_win[:, None]
        sfpos = sfpos + ej_win.astype(jnp.int32)
        tail_ej = ej_win[:, F - 1]

        # VC accounting: header alloc at `to`; the tail flit leaving a stage
        # frees that stage's VC — both a forward move and a same-cycle
        # ejection from the final stage can fire for one slot
        deltas = jnp.concatenate([
            jnp.where(hdr_win, 1, 0),
            jnp.where(tail_mv, -1, 0),
            jnp.where(tail_ej, -1, 0),
        ]).astype(jnp.int32)
        slots = jnp.concatenate([
            vci_to[:, 0], _vci(spc, tail_from), _vci(spc, ns_s - 1),
        ])
        vc_used = vc_used + jax.ops.segment_sum(
            deltas, slots, num_segments=2 * L
        )

        # slot recycle on full ejection
        finished = alive & (sfpos[:, F - 1] == ns_s)
        sp = jnp.where(finished, -1, sp)

        # ---- counters (same events the host sim counts) -----------------
        n_moves = jnp.sum(mv_win)
        n_inj = jnp.sum(mv_win & in_ni)
        n_ej = jnp.sum(ej_win)
        ctr = ctr + jnp.stack([
            n_moves, n_moves, n_moves - n_inj + n_ej, n_moves,
            jnp.sum(move_c), n_inj + n_ej, jnp.sum(finished),
            jnp.zeros((), jnp.int32),
        ]).astype(jnp.int32)
        ctr = ctr.at[_I["slots_hwm"]].max(jnp.sum(alive))
        return SlotState(sfpos, sp, slot_of, vc_used, ptr, front_slot, crel,
                         dtime, ctr, ovf), None

    return step
