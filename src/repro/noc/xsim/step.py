"""xsim's per-cycle engine — now the fused ``kernels.noc_cycle`` kernel.

The old per-worm slot pool (``SlotState``: ``sfpos[K, F]`` flit stages,
slot allocation by cumsum/searchsorted, two ``kernels.noc_step`` segmented
-min rounds and ~20 masked scatters per cycle) is gone. State lives in
packed router-centric planes — per-(link, VC) FIFO ownership plus NI lane
fronts — where both arbitration rounds are dense masked mins over each
node's static input-port table and the *only* scatter left is the
(L,)-sized delivery recording. See ``kernels/noc_cycle/ref.py`` and
DESIGN.md §8 for the layout and the fusion boundaries.

Consequences surfaced here:

* No slot pool: capacity is structural (a worm in flight holds a VC FIFO
  or an NI lane front), so there is no ``K`` to size, no overflow, and no
  regrow-and-rerun loop in the runner.
* ``backend=`` selects the whole-cycle engine now, not just arbitration:
  ``ref`` (jnp scan — the CPU fast path), ``pallas`` (fused chunk kernel,
  TPU/GPU), ``pallas_interpret`` (kernel semantics on CPU, bit-identical
  to ``ref`` — CI's validation path). It threads from ``NoCConfig.
  xsim_backend`` through ``xsimulate`` down to ``run_cycles``.
* DPM children inject in dynamic parent-arrival order (the host sim's
  release-order queues), closing the old static-order fidelity delta.

This module keeps the xsim-side surface: ``CTR`` counter names and the
``run_cycles`` entry point the batch runner scans with.
"""
from __future__ import annotations

from ...kernels.noc_cycle import (  # noqa: F401  (re-exports)
    CTR,
    CycleState,
    cycle_core,
    init_planes,
    run_cycles,
)

__all__ = ["CTR", "CycleState", "cycle_core", "init_planes", "run_cycles"]
