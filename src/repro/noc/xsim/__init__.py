"""xsim — fixed-shape, array-based NoC simulator for massively parallel
DPM sweeps (compiler -> scan stepper -> vmapped batch runner).

The host ``WormholeSim`` is the event-ordered oracle; xsim trades exact
sequential arbitration order for dense-state purity so that whole (rate,
algorithm, seed) grids batch into one device dispatch. See DESIGN.md §5 for
the state layout and fidelity contract.
"""
from .compile import CompiledTraffic, compile_workload, stack_traffic
from .run import XSimResults, latency_vs_rate_batched, xsimulate

__all__ = [
    "CompiledTraffic",
    "XSimResults",
    "compile_workload",
    "latency_vs_rate_batched",
    "stack_traffic",
    "xsimulate",
]
