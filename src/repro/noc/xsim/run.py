"""Batch runner: compile -> one jitted, vmapped fused-cycle run -> SimStats.

``xsimulate(cfg, workloads, algos)`` lowers every (workload, algorithm) pair
with the compiler, pads the batch to one common (P, S) shape, and runs the
whole grid through a single ``jax.vmap``-ed dispatch of the fused cycle
engine (``kernels.noc_cycle``) — seeds, injection rates, and routing
algorithms all ride the batch axis, and multi-device hosts additionally
pmap-shard it.

The cycle count is fixed (``max horizon + drain_grace``): scans cannot exit
early, so unlike the host sim there is no drain-and-stop — saturation points
cost the same as idle ones, which is exactly why the batched sweep wins.

There is no slot pool anymore: the packed router-centric state is sized by
the network itself (every in-flight worm holds a VC FIFO or an NI lane
front), so per-cycle cost is bounded by ``L * 2V + 2 * NN`` regardless of
injection rate or backlog, and the old overflow/regrow loop is gone.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..config import NoCConfig
from ..simulator import SimStats
from ..traffic import Workload
from ...core.algo import available_algorithms, get_algorithm
from ...core.topology import make_topology
from .compile import (
    CompiledTraffic,
    compile_workload,
    geometry_tables,
    stack_traffic,
)
from .step import CTR, run_cycles


def _run_one(tr: dict, T: int, F: int, V: int, BD: int, L: int, NN: int,
             ND: int, kind: str, n: int, m: int, params: tuple, backend: str,
             epoch_len: int | None = None):
    geom = geometry_tables(kind, n, m, params, V)
    return run_cycles(
        tr, geom, T=T, F=F, V=V, BD=BD, L=L, NN=NN, ND=ND, backend=backend,
        epoch_len=epoch_len,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "T", "F", "V", "BD", "L", "NN", "ND", "kind", "n", "m", "params",
        "backend", "epoch_len",
    ),
)
def _run_batch(stacked: dict, T: int, F: int, V: int, BD: int, L: int,
               NN: int, ND: int, kind: str, n: int, m: int, params: tuple,
               backend: str, epoch_len: int):
    fn = functools.partial(
        _run_one, T=T, F=F, V=V, BD=BD, L=L, NN=NN, ND=ND, kind=kind, n=n,
        m=m, params=params, backend=backend, epoch_len=epoch_len,
    )
    return jax.vmap(fn)(stacked)


def _run_sharded(stacked: dict, **kw):
    """vmap the batch axis; additionally pmap-shard it across host devices
    when more than one is available (e.g. CI/benchmarks force 2+ CPU devices
    via --xla_force_host_platform_device_count) and it divides evenly."""
    B = stacked["link"].shape[0]
    D = jax.local_device_count()
    while D > 1 and B % D:
        D -= 1
    if D <= 1:
        return _run_batch(stacked, **kw)
    fn = jax.pmap(
        jax.vmap(functools.partial(_run_one, **kw)), axis_name="shard"
    )
    shaped = {
        k: jnp.reshape(v, (D, B // D) + v.shape[1:])
        for k, v in stacked.items()
    }
    out = fn(shaped)
    return {k: jnp.reshape(v, (B,) + v.shape[2:]) for k, v in out.items()}


@dataclass
class XSimResults:
    """Batched results over a (workloads x algos) grid.

    ``b = w * len(algos) + a`` indexes the flat batch axis. ``stats(w, a)``
    adapts one cell to the host simulator's ``SimStats`` (same counter
    semantics; ``cycles`` is the fixed scan length, so compare dynamic
    *energy* across simulators, not per-cycle power).
    """

    cfg: NoCConfig
    algos: tuple[str, ...]
    horizons: np.ndarray  # (W,) int
    warmup: int
    cycles: int  # scan length T
    slots: int  # structural worm capacity 2*V*L + 2*NN (informational)
    traffic: dict  # stacked compile tensors, numpy, leading axis B
    dtime: np.ndarray  # (B, P, S) int32
    ctr: np.ndarray  # (B, len(CTR)) int32
    crel: np.ndarray  # (B, C) bool
    wall_s: float  # host compile + device execute, seconds
    epoch_len: int = 0  # telemetry bucket width (cycles)
    lutil: np.ndarray | None = None  # (B, E, L) per-epoch link flits
    rconf: np.ndarray | None = None  # (B, E, NN) per-epoch router conflicts

    def _b(self, w: int, a: int) -> int:
        return w * len(self.algos) + a

    def latencies(self, w: int, a: int) -> list[int]:
        """Per-delivery latencies of measured packets (warmup window)."""
        b = self._b(w, a)
        enq = self.traffic["enqueue"][b]
        measured = (
            self.traffic["valid"][b]
            & (enq >= self.warmup)
            & (enq < self.horizons[w])
        )
        hit = (
            self.traffic["deliver"][b]
            & (self.dtime[b] >= 0)
            & measured[:, None]
        )
        return (self.dtime[b] - enq[:, None])[hit].tolist()

    def avg_latency(self, w: int, a: int) -> float:
        lats = self.latencies(w, a)
        return sum(lats) / max(1, len(lats))

    def avg_latency_matrix(self) -> np.ndarray:
        W = len(self.horizons)
        return np.array(
            [[self.avg_latency(w, a) for a in range(len(self.algos))]
             for w in range(W)]
        )

    def delivered_sets(self, w: int, a: int) -> dict[int, set[int]]:
        """pid -> set of delivered node indices (for host-sim parity)."""
        b = self._b(w, a)
        hit = self.traffic["deliver"][b] & (self.dtime[b] >= 0)
        node = self.traffic["node"][b]
        out: dict[int, set[int]] = {}
        for p in np.flatnonzero(self.traffic["valid"][b]):
            out[int(p)] = {int(n) for n in node[p][hit[p]]}
        return out

    def packets_created(self, w: int, a: int) -> int:
        """Packets that entered an NI lane queue (host-sim semantics: every
        root whose enqueue time fell inside the run, plus released children).
        """
        b = self._b(w, a)
        tr = self.traffic
        roots = (
            tr["valid"][b] & (tr["parent"][b] < 0)
            & (tr["enqueue"][b] < self.cycles)
        )
        return int(roots.sum()) + int(self.crel[b].sum())

    def all_drained(self, w: int, a: int) -> bool:
        st = self.stats(w, a)
        return st.packets_finished == st.packets_created

    def slots_hwm(self) -> int:
        """Max in-flight worms across the batch (diagnostic: how much of the
        structural ``slots`` capacity the sweep actually used)."""
        return int(self.ctr[:, CTR.index("slots_hwm")].max())

    def link_utilization(self, w: int, a: int,
                         epoch: int | None = None) -> np.ndarray:
        """(L,) per-directed-link flit traversals for one grid cell — the
        conserved-event decomposition of ``flit_link_traversals``, exactly
        matching the host sim's ``Telemetry.link_flits`` when delivery sets
        match. ``epoch`` selects one ``epoch_len``-cycle bucket; default
        sums the run."""
        planes = self.lutil[self._b(w, a)]
        return planes.sum(axis=0) if epoch is None else planes[epoch]

    def router_conflicts(self, w: int, a: int,
                         epoch: int | None = None) -> np.ndarray:
        """(NN,) per-router losing arbitration requests (see ``lutil``
        semantics for the ``epoch`` argument)."""
        planes = self.rconf[self._b(w, a)]
        return planes.sum(axis=0) if epoch is None else planes[epoch]

    def link_heatmap(self, w: int, a: int) -> np.ndarray:
        """(rows, n, ports) per-node outgoing-link flit counts (rendering)."""
        util = self.link_utilization(w, a)
        rows = self.cfg.rows
        ports = util.shape[-1] // (rows * self.cfg.n)
        return util.reshape(rows, self.cfg.n, ports)

    def stats(self, w: int, a: int) -> SimStats:
        b = self._b(w, a)
        st = SimStats(latencies=sorted(self.latencies(w, a)))
        for i, name in enumerate(CTR):
            if hasattr(st, name):  # slots_hwm is xsim-only
                setattr(st, name, int(self.ctr[b, i]))
        st.packets_created = self.packets_created(w, a)
        st.cycles = self.cycles
        return st


def _capacity(cfg: NoCConfig, num_nodes: int, num_links: int) -> int:
    """Structural in-flight worm bound: every in-network worm holds >= 1 VC
    FIFO, plus one possible lane front per lane."""
    return 2 * cfg.vcs_per_class * num_links + 2 * num_nodes


def xsimulate(
    cfg: NoCConfig,
    workloads: list[Workload],
    algos: tuple | None = None,
    *,
    cost_model=None,
    warmup: int | None = None,
    drain_grace: int | None = None,
    backend: str | None = None,
    slots: int | None = None,
    pad_packets: int | None = None,
    pad_stages: int | None = None,
    epoch_len: int | None = None,
    broken_links_per_workload: list | None = None,
) -> XSimResults:
    """Simulate every (workload, algo) pair in one vmapped device dispatch.

    ``algos`` entries resolve through the routing-algorithm registry (names
    or ``RoutingAlgorithm`` instances); the default is every registered
    algorithm that supports the configured topology. ``cost_model``
    optionally overrides the planning objective for the whole grid.
    ``backend`` (or ``cfg.xsim_backend``) selects the cycle engine; see
    ``step.py``. ``slots`` is accepted for backwards compatibility and
    ignored — the packed-plane engine has no slot pool to size.
    ``epoch_len`` (default ``cfg.epoch_len``) buckets the telemetry planes.
    ``broken_links_per_workload`` overrides ``cfg.broken_links`` per
    workload (entries may be None = use the config's set) — routes are
    planned on each workload's degraded topology at compile time while the
    whole grid still runs as one batch (the engine itself is
    fault-agnostic; trace replay uses this for mid-run link failures).
    """
    del slots  # legacy slot-pool hint: capacity is structural now
    topo = make_topology(
        cfg.topology, cfg.n, cfg.m, cfg.broken_links, cfg.topology_params
    )
    if algos is None:
        algos = tuple(available_algorithms(topo))
    resolved = [get_algorithm(a) for a in algos]
    warmup = cfg.warmup if warmup is None else warmup
    drain_grace = cfg.drain_grace if drain_grace is None else drain_grace
    epoch_len = cfg.epoch_len if epoch_len is None else int(epoch_len)
    if broken_links_per_workload is not None and len(
        broken_links_per_workload
    ) != len(workloads):
        raise ValueError(
            "broken_links_per_workload needs one entry per workload "
            f"({len(broken_links_per_workload)} != {len(workloads)})"
        )
    from ...kernels.noc_cycle import resolve_backend

    backend = resolve_backend(
        cfg.xsim_backend if backend is None else backend
    )
    t0 = time.monotonic()
    traffics: list[CompiledTraffic] = []
    for wi, wl in enumerate(workloads):
        wcfg = cfg
        if broken_links_per_workload is not None:
            faults = broken_links_per_workload[wi]
            if faults is not None:
                wcfg = dataclasses.replace(
                    cfg, broken_links=tuple(faults)
                )
        for algo in resolved:
            traffics.append(
                compile_workload(
                    wcfg, wl, algo,
                    pad_packets=pad_packets, pad_stages=pad_stages,
                    cost_model=cost_model,
                )
            )
    ref, stacked = stack_traffic(traffics)
    T = max(wl.horizon for wl in workloads) + drain_grace
    ND = int(stacked["dslot"].max()) + 1  # flat delivery-slot space
    # the engine's static F is the largest worm in the batch: it sizes the
    # age-key multiplier and the BD>=F credit shortcut; per-packet lengths
    # ride the compiled ``flits`` table
    F = max(cfg.flits_per_packet, int(stacked["flits"].max()))
    stacked_j = {k: jnp.asarray(v) for k, v in stacked.items()}
    out = _run_sharded(
        stacked_j,
        T=T, F=F, V=cfg.vcs_per_class,
        BD=cfg.buffer_depth, L=ref.num_links, NN=ref.num_nodes, ND=ND,
        kind=ref.kind, n=ref.n, m=ref.m, params=ref.params, backend=backend,
        epoch_len=epoch_len,
    )
    out = jax.tree_util.tree_map(np.asarray, out)  # blocks until ready
    # scatter-compact flat delivery times -> the (B, P, S) view the results
    # object (and the parity tests) consume
    ds = stacked["dslot"]
    B = ds.shape[0]
    dtime = np.where(
        ds >= 0,
        out["dtime"][np.arange(B)[:, None, None], np.clip(ds, 0, ND)],
        -1,
    ).astype(np.int32)
    wall = time.monotonic() - t0
    return XSimResults(
        cfg=cfg,
        algos=tuple(a.name for a in resolved),
        horizons=np.array([wl.horizon for wl in workloads]),
        warmup=warmup,
        cycles=T,
        slots=_capacity(cfg, ref.num_nodes, ref.num_links),
        traffic=stacked,
        dtime=dtime,
        ctr=out["ctr"],
        crel=out["crel"],
        wall_s=wall,
        epoch_len=epoch_len,
        lutil=out["lutil"],
        rconf=out["rconf"],
    )


def latency_vs_rate_batched(
    cfg: NoCConfig,
    rates: list[float],
    algos: tuple | None = None,
    cycles: int = 1500,
    seed: int = 0,
    **kw,
) -> tuple[dict[str, list[tuple[float, float]]], XSimResults]:
    """The fig6 latency-vs-injection-rate sweep as one batched call.

    Returns ``({algo: [(rate, avg_latency), ...]}, results)``. ``algos``
    defaults to every registered algorithm supporting the topology. Unlike
    the host-sim ``latency_vs_rate`` there is no early saturation cut-off:
    every (rate, algo) point costs the same inside the vmapped scan.
    """
    from ..traffic import synthetic_workload

    wls = [synthetic_workload(cfg, r, cycles, seed=seed) for r in rates]
    res = xsimulate(cfg, wls, algos, **kw)
    curves = {
        algo: [(rates[w], res.avg_latency(w, a)) for w in range(len(rates))]
        for a, algo in enumerate(res.algos)
    }
    return curves, res
