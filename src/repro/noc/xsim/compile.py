"""Lower host-side MulticastPlans into the dense tensors xsim steps over.

The compiler mirrors ``WormholeSim.add_plan`` exactly: one row per wormhole
packet (degenerate single-node paths are skipped, DPM child packets keep
their parent linkage), in workload-request order, so packet ids line up 1:1
between the two simulators — the cross-validation tests compare per-pid
delivery sets directly.

Per-packet scalars and per-stage tables (stage ``s`` is the input FIFO at
``hops[s+1]`` fed by directed link ``(hops[s], hops[s+1])``):

* ``link[P, S]``    directed-link id ``idx(u) * ports + direction(u -> v)``
                    (direction order and port count from the topology: the
                    2-D kinds use (+x, -x, +y, -y), the 3-D ones append
                    (+z, -z); torus wrap hops resolve through
                    ``Topology.delta``'s signed shortest step).
* ``vcls[P, S]``    VC class of the hop — HIGH(0) iff the boustrophedon
                    label increases along it (core.grid labeling, the
                    paper's dual-path deadlock rule, same as the host sim).
* ``deliver[P, S]`` tail-flit delivery points (first occurrence per node).
* ``node[P, S]``    row-major index of ``hops[s+1]`` (delivery reporting).
* ``release_stage`` for child packets: the parent stage whose header entry
                    at the representative releases the child (cut-through
                    relay, as in the host sim's ``header_times`` rule).
* ``lane``          NI injection lane ``idx(source) * 2 + is_child`` — child
                    packets use the multicast relay port, fresh traffic the
                    normal injection queue (two lanes per node, as in the
                    host sim's ``src_queues``).

Padding rows have ``enqueue = NEVER`` and are never released; padded stage
entries hold link 0 and are unreachable (``fpos < num_stages`` gating).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from ...core.grid import Coord
from ...core.planner import MulticastPlan
from ...core.topology import make_topology
from ..config import NoCConfig
from ..traffic import Workload

# Enqueue sentinel for padding rows: far beyond any horizon, small enough
# that key arithmetic (enqueue * P * F) stays well inside int32.
NEVER = np.int32(2**20)


@dataclass(frozen=True)
class CompiledTraffic:
    """One workload under one algorithm, lowered to fixed-shape arrays."""

    # static geometry / config
    n: int
    m: int  # the topology factory's m argument (y extent; == rows in 2-D)
    kind: str
    params: tuple  # extra make_topology args (Topology.params)
    ports: int  # output ports per router (4 in 2-D, 6 in 3-D)
    num_nodes: int
    num_links: int  # directed-link id space: num_nodes * ports
    horizon: int
    # per-packet (P,)
    enqueue: np.ndarray  # int32; NEVER on padding rows
    parent: np.ndarray  # int32; -1 = root packet
    release_stage: np.ndarray  # int32; -1 for roots
    lane: np.ndarray  # int32; node * 2 + is_child
    num_stages: np.ndarray  # int32
    flits: np.ndarray  # int32; per-packet worm length (cfg default if unset)
    eject_node: np.ndarray  # int32; row-major index of hops[-1]
    valid: np.ndarray  # bool
    # per-stage (P, S)
    link: np.ndarray  # int32
    vcls: np.ndarray  # int32; 0 HIGH / 1 LOW
    deliver: np.ndarray  # bool
    # compact delivery-slot index: -1 where not a delivery point, else a
    # dense 0..nd-1 id. The engine scatters arrival times into an (nd,)-flat
    # array instead of carrying the full (P, S) dtime plane through the scan
    # (most of which is never written — delivery points are sparse).
    dslot: np.ndarray  # int32
    node: np.ndarray  # int32
    # per-lane static injection order for ROOT lanes (2NN, Q): pids by
    # (enqueue, pid), -1 pad. Child lanes are all -1: children inject in
    # dynamic release order through the per-node ``chl`` table instead.
    lane_seq: np.ndarray
    # child (DPM re-injection) table: (C,) rows + (P,) pid -> row map
    child_ix: np.ndarray  # (P,) int32; -1 = root
    child_pid: np.ndarray  # (C,) int32 — row -> packet id
    child_parent: np.ndarray  # (C,) int32
    child_rs: np.ndarray  # (C,) int32 — parent stage releasing the child
    child_enq: np.ndarray  # (C,) int32
    # (C,) directed link whose header arrival releases the child: the link
    # feeding the parent's ``release_stage`` FIFO (the representative node)
    watch_link: np.ndarray
    # children grouped by injection node (NN, QC) int32 child rows, -1 pad —
    # the relay lane's dynamic-order candidate set
    chl: np.ndarray

    @property
    def num_packets(self) -> int:
        return int(self.valid.sum())

    @property
    def max_stages(self) -> int:
        return self.link.shape[1]


def compile_workload(
    cfg: NoCConfig,
    workload: Workload,
    algo,
    pad_packets: int | None = None,
    pad_stages: int | None = None,
    cost_model=None,
) -> CompiledTraffic:
    """Plan every request and lower the packet set to dense arrays.

    ``algo`` is resolved through the routing-algorithm registry (name or
    ``RoutingAlgorithm`` instance); ``cost_model`` optionally overrides the
    objective cost-sensitive algorithms plan under. With
    ``cfg.broken_links`` set, plans come from the fault-aware route
    provider on the degraded topology, and every lowered hop is re-checked:
    a route crossing a broken link is refused before any tensor is built
    (the same contract as ``WormholeSim.add_plan``).
    """
    g = make_topology(
        cfg.topology, cfg.n, cfg.m, cfg.broken_links, cfg.topology_params
    )
    ports = getattr(g, "ports", 4)
    rows: list[tuple] = []  # (hops, deliveries, enqueue, parent_pid, flits)
    # bulk-plan the whole workload through the shared plan arena: one
    # jitted device dispatch for all arena misses where supported (plans
    # are bit-identical to per-request plan() calls)
    from ...core.batch_planner import bulk_plan

    plans = bulk_plan(
        g, [(r.src, r.dests) for r in workload.requests], algo,
        cost_model=cost_model,
    )
    for r, pl_ in zip(workload.requests, plans):
        nf = cfg.flits_per_packet
        rf = getattr(r, "flits", None)
        if rf is not None:
            nf = int(rf)
        if not 1 <= nf <= 127:  # int8 fhead/fcount/lsent planes
            raise ValueError(f"per-packet flits must be in [1, 127] (got {nf})")
        _lower_plan(pl_, r.time, rows, nf)
    is_broken = getattr(g, "is_broken", None)
    if is_broken is not None:
        for hops, *_ in rows:
            for u, v in zip(hops, hops[1:]):
                if is_broken(u, v):
                    raise ValueError(
                        f"compiled route traverses broken link ({u}, {v}); "
                        f"replan on the degraded topology"
                    )
    P = len(rows)
    S = max((len(h) - 1 for h, *_ in rows), default=1)
    Pp = max(P, 1) if pad_packets is None else pad_packets
    Sp = S if pad_stages is None else pad_stages
    if Pp < P or Sp < S:
        raise ValueError(f"pad ({Pp},{Sp}) smaller than workload ({P},{S})")

    enqueue = np.full(Pp, NEVER, np.int32)
    parent = np.full(Pp, -1, np.int32)
    release_stage = np.full(Pp, -1, np.int32)
    lane = np.zeros(Pp, np.int32)
    num_stages = np.ones(Pp, np.int32)
    flits = np.full(Pp, cfg.flits_per_packet, np.int32)
    eject_node = np.zeros(Pp, np.int32)
    valid = np.zeros(Pp, bool)
    link = np.zeros((Pp, Sp), np.int32)
    vcls = np.zeros((Pp, Sp), np.int32)
    deliver = np.zeros((Pp, Sp), bool)
    node = np.zeros((Pp, Sp), np.int32)

    # per-stage tables, vectorized over one flat hop-pair array; per-packet
    # scalars accumulate in python lists and assign once (scalar numpy
    # writes dominated lowering time on big sweeps)
    n, m = g.n, g.rows
    flat_uv: list[Coord] = []
    lens = np.zeros(P, np.int64)
    enq_l, par_l, lane_l, ej_l, fl_l = [], [], [], [], []
    del_p: list[int] = []
    del_s: list[int] = []
    for pid, (hops, deliveries, t, par, nf) in enumerate(rows):
        ns = len(hops) - 1
        lens[pid] = ns
        flat_uv.extend(hops)
        enq_l.append(t)
        par_l.append(-1 if par is None else par)
        fl_l.append(nf)
        lane_l.append(g.idx(hops[0]) * 2 + (0 if par is None else 1))
        ej_l.append(g.idx(hops[-1]))
        for d in deliveries:
            del_p.append(pid)
            del_s.append(hops.index(d, 1) - 1)
        if par is not None:
            release_stage[pid] = rows[par][0].index(hops[0], 1) - 1
    if P:
        enqueue[:P] = enq_l
        parent[:P] = par_l
        lane[:P] = lane_l
        num_stages[:P] = lens
        flits[:P] = fl_l
        eject_node[:P] = ej_l
        valid[:P] = True
        deliver[del_p, del_s] = True
        if g.kind in ("mesh", "torus"):
            # vectorized 2-D lowering — the hot path on big sweeps, kept
            # bit-identical to the original closed-form snake/direction math
            hv = np.fromiter(
                (c for xy in flat_uv for c in xy), np.int64, 2 * len(flat_uv)
            ).reshape(-1, 2)  # all hops, path-concatenated
            starts = np.cumsum(lens + 1) - (lens + 1)  # offsets incl. hop 0
            total = int(lens.sum())
            pidx = np.repeat(np.arange(P), lens)
            sidx = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
            flat = np.repeat(starts, lens) + sidx  # index of hop u of (pid, s)
            ux, uy = hv[flat, 0], hv[flat, 1]
            vx, vy = hv[flat + 1, 0], hv[flat + 1, 1]
            dx, dy = vx - ux, vy - uy
            if g.wrap:  # signed shortest step (matches Topology.delta)
                dx = (dx + n // 2) % n - n // 2
                dy = (dy + m // 2) % m - m // 2
            dir_ = np.select(
                [dx == 1, dx == -1, dy == 1], [0, 1, 2], default=3
            )
            labu = np.where(uy % 2 == 0, uy * n + ux, uy * n + n - 1 - ux)
            labv = np.where(vy % 2 == 0, vy * n + vx, vy * n + n - 1 - vx)
            link[pidx, sidx] = (uy * n + ux) * 4 + dir_
            vcls[pidx, sidx] = labv < labu  # 0 HIGH (label up), 1 LOW
            node[pidx, sidx] = vy * n + vx
        else:
            # generic lowering through the Topology protocol (3-D, chiplet,
            # any future registered kind): per-hop loops, same semantics
            for pid, (hops, _dv, _t, _par, _nf) in enumerate(rows):
                for s, (u, v) in enumerate(zip(hops, hops[1:])):
                    link[pid, s] = g.idx(u) * ports + g.direction(u, v)
                    vcls[pid, s] = g.label(*v) < g.label(*u)
                    node[pid, s] = g.idx(v)

    # static per-lane injection order for roots: (enqueue, pid) — the host
    # sim's FIFO arrival order (roots enter their queue at enqueue time).
    # Children are NOT in lane_seq: their queue order is dynamic (parent
    # header arrival), modeled through the per-node ``chl`` table below.
    by_lane: dict[int, list[int]] = {}
    order = sorted(
        (p for p in range(P) if parent[p] < 0),
        key=lambda p: (int(enqueue[p]), p),
    )
    for pid in order:
        by_lane.setdefault(int(lane[pid]), []).append(pid)
    Qn = max((len(v) for v in by_lane.values()), default=1)
    lane_seq = np.full((2 * g.num_nodes, Qn), -1, np.int32)
    for ln, pids in by_lane.items():
        lane_seq[ln, : len(pids)] = pids

    child_rows = np.flatnonzero(parent >= 0)
    C = max(1, len(child_rows))
    child_ix = np.full(Pp, -1, np.int32)
    child_pid = np.zeros(C, np.int32)
    child_parent = np.zeros(C, np.int32)
    child_rs = np.full(C, NEVER, np.int32)
    child_enq = np.full(C, NEVER, np.int32)
    watch_link = np.zeros(C, np.int32)
    by_node: dict[int, list[int]] = {}
    for row, pid in enumerate(child_rows):
        child_ix[pid] = row
        child_pid[row] = pid
        child_parent[row] = parent[pid]
        child_rs[row] = release_stage[pid]
        child_enq[row] = enqueue[pid]
        # the parent's header enters stage ``release_stage`` through this
        # link; its arrival event is what releases the child (row order is
        # pid order — the host sim's same-cycle append tie-break)
        watch_link[row] = link[parent[pid], release_stage[pid]]
        by_node.setdefault(int(lane[pid]) // 2, []).append(row)
    QCn = max((len(v) for v in by_node.values()), default=1)
    chl = np.full((g.num_nodes, QCn), -1, np.int32)
    for nd, rws in by_node.items():
        chl[nd, : len(rws)] = rws

    dslot = np.full((Pp, Sp), -1, np.int32)
    dslot.ravel()[np.flatnonzero(deliver.ravel())] = np.arange(
        int(deliver.sum()), dtype=np.int32
    )

    # the (enqueue, pid, fid) age keys must stay strictly below the NOC_INF
    # sentinel (2**30) so a real candidate always beats the no-candidate pad;
    # the key multiplier is the engine's static F = the largest worm length
    max_f = max(cfg.flits_per_packet, int(flits[valid].max(initial=1)))
    max_key = (int(enqueue[valid].max(initial=0)) + 1) * Pp * max_f
    assert max_key < 2**30, f"workload too large for int32 age keys ({max_key})"
    m_fact = getattr(g, "m", None)
    return CompiledTraffic(
        n=g.n, m=g.rows if m_fact is None else m_fact, kind=g.kind,
        params=getattr(g, "params", ()), ports=ports,
        num_nodes=g.num_nodes, num_links=g.num_nodes * ports,
        horizon=workload.horizon,
        enqueue=enqueue, parent=parent, release_stage=release_stage,
        lane=lane, num_stages=num_stages, flits=flits,
        eject_node=eject_node, valid=valid,
        link=link, vcls=vcls, deliver=deliver, dslot=dslot, node=node,
        lane_seq=lane_seq, child_ix=child_ix, child_pid=child_pid,
        child_parent=child_parent, child_rs=child_rs, child_enq=child_enq,
        watch_link=watch_link, chl=chl,
    )


@functools.lru_cache(maxsize=64)
def geometry_tables(
    kind: str, n: int, m: int, params: tuple, V: int
) -> dict[str, np.ndarray]:
    """Static router geometry for the fused cycle kernel (numpy, topology-only).

    The fused engine's candidate space is every VC FIFO plus every NI lane,
    flattened: FIFO ``(l, v)`` is candidate ``l * W + v`` (``W = 2V`` VCs per
    directed link), lane ``q`` is candidate ``L * W + q``, and one trailing
    dummy candidate ``L * W + 2 * NN`` absorbs padding. Arbitration is a
    dense masked min over ``node_ports[v]`` — the FIFOs of the ``D`` links
    *into* node ``v`` (a flit can only request ``v``'s output links from
    there; ``D = Topology.ports``) plus ``v``'s two NI lanes — so each
    candidate appears in exactly one node's port list and winner masks map
    back through the static ``cand_node``/``cand_port`` inverse with a
    gather, never a scatter.

    Tables enumerate the *healthy* topology (``params`` but no faults): the
    cycle engine is fault-agnostic — broken links are excluded at plan time,
    so no compiled route ever requests them.
    """
    g = make_topology(kind, n, m, params=params)
    NN = g.num_nodes
    D = getattr(g, "ports", 4)
    L = NN * D
    W = 2 * V
    PORTS = D * W + 2
    CAND = L * W + 2 * NN
    node_ports = np.full((NN, PORTS), CAND, np.int32)  # CAND = dummy pad
    cand_node = np.zeros(CAND + 1, np.int32)
    cand_port = np.zeros(CAND + 1, np.int32)
    for vc in g.nodes():
        v = g.idx(vc)
        for uc in g.neighbors(*vc):
            d = g.direction(uc, vc)  # incoming link u -> v enters on port d
            link = g.idx(uc) * D + d
            for w in range(W):
                cand = link * W + w
                node_ports[v, d * W + w] = cand
                cand_node[cand] = v
                cand_port[cand] = d * W + w
        for q in range(2):
            cand = L * W + 2 * v + q
            node_ports[v, D * W + q] = cand
            cand_node[cand] = v
            cand_port[cand] = D * W + q
    return {
        "node_ports": node_ports,
        "cand_node": cand_node,
        "cand_port": cand_port,
    }


def _lower_plan(pl_: MulticastPlan, t: int, rows: list, flits: int) -> None:
    """Append one row per packet, matching WormholeSim.add_plan semantics."""
    idx_map: list[int | None] = []  # plan-local path index -> global pid
    for path in pl_.paths:
        if len(path.hops) == 1:
            # degenerate source-only path: delivered instantly, no packet
            # (none of the shipped planners emit one as a parent).
            idx_map.append(None)
            continue
        par = None
        if path.parent is not None:
            par = idx_map[path.parent]
            assert par is not None, "parent path must carry flits"
        # deliveries may be empty: transit segments of a degraded-topology
        # monotone-segmented plan relay the worm without absorbing a copy
        assert path.hops[0] not in path.deliveries
        idx_map.append(len(rows))
        rows.append((path.hops, list(path.deliveries), t, par, flits))


def stack_traffic(
    traffics: list[CompiledTraffic],
) -> tuple[CompiledTraffic, dict[str, np.ndarray]]:
    """Pad a batch to common (P, S) and stack every array on a new axis 0.

    Returns the first (re-padded) element as the shared-static reference plus
    the dict of stacked arrays ``{field: (B, ...)}`` that feeds the vmapped
    runner. All elements must share geometry and id spaces.
    """
    t0 = traffics[0]
    for t in traffics[1:]:
        if (t.n, t.m, t.kind, t.params) != (t0.n, t0.m, t0.kind, t0.params):
            raise ValueError("cannot batch traffic across different topologies")
    Pp = max(t.enqueue.shape[0] for t in traffics)
    Sp = max(t.max_stages for t in traffics)
    Qp = max(t.lane_seq.shape[1] for t in traffics)
    Cp = max(t.child_parent.shape[0] for t in traffics)
    QCp = max(t.chl.shape[1] for t in traffics)

    def pad(t: CompiledTraffic) -> CompiledTraffic:
        dp = Pp - t.enqueue.shape[0]
        ds = Sp - t.max_stages
        pad1 = lambda a, fill: np.pad(a, (0, dp), constant_values=fill)
        pad2 = lambda a, fill=0: np.pad(
            a, ((0, dp), (0, ds)), constant_values=fill
        )
        dc = Cp - t.child_parent.shape[0]
        padc = lambda a, fill: np.pad(a, (0, dc), constant_values=fill)
        return CompiledTraffic(
            n=t.n, m=t.m, kind=t.kind, params=t.params, ports=t.ports,
            num_nodes=t.num_nodes,
            num_links=t.num_links, horizon=t.horizon,
            enqueue=pad1(t.enqueue, NEVER), parent=pad1(t.parent, -1),
            release_stage=pad1(t.release_stage, -1), lane=pad1(t.lane, 0),
            num_stages=pad1(t.num_stages, 1), flits=pad1(t.flits, 1),
            eject_node=pad1(t.eject_node, 0),
            valid=pad1(t.valid, False),
            link=pad2(t.link), vcls=pad2(t.vcls),
            deliver=pad2(t.deliver), dslot=pad2(t.dslot, -1),
            node=pad2(t.node),
            lane_seq=np.pad(
                t.lane_seq, ((0, 0), (0, Qp - t.lane_seq.shape[1])),
                constant_values=-1,
            ),
            child_ix=pad1(t.child_ix, -1),
            child_pid=padc(t.child_pid, 0),
            child_parent=padc(t.child_parent, 0),
            child_rs=padc(t.child_rs, NEVER),
            child_enq=padc(t.child_enq, NEVER),
            watch_link=padc(t.watch_link, 0),
            chl=np.pad(
                t.chl, ((0, 0), (0, QCp - t.chl.shape[1])),
                constant_values=-1,
            ),
        )

    padded = [pad(t) for t in traffics]
    fields = (
        "enqueue", "parent", "release_stage", "lane", "num_stages", "flits",
        "eject_node", "valid", "link", "vcls", "deliver", "dslot", "node",
        "lane_seq", "child_ix", "child_pid", "child_parent", "child_rs",
        "child_enq", "watch_link", "chl",
    )
    stacked = {f: np.stack([getattr(t, f) for t in padded]) for f in fields}
    return padded[0], stacked
