"""Lower host-side MulticastPlans into the dense tensors xsim steps over.

The compiler mirrors ``WormholeSim.add_plan`` exactly: one row per wormhole
packet (degenerate single-node paths are skipped, DPM child packets keep
their parent linkage), in workload-request order, so packet ids line up 1:1
between the two simulators — the cross-validation tests compare per-pid
delivery sets directly.

Per-packet scalars and per-stage tables (stage ``s`` is the input FIFO at
``hops[s+1]`` fed by directed link ``(hops[s], hops[s+1])``):

* ``link[P, S]``    directed-link id ``idx(u) * 4 + direction(u -> v)``
                    (directions: +x, -x, +y, -y; torus wrap hops resolve
                    through ``Topology.delta``'s signed shortest step).
* ``vcls[P, S]``    VC class of the hop — HIGH(0) iff the boustrophedon
                    label increases along it (core.grid labeling, the
                    paper's dual-path deadlock rule, same as the host sim).
* ``deliver[P, S]`` tail-flit delivery points (first occurrence per node).
* ``node[P, S]``    row-major index of ``hops[s+1]`` (delivery reporting).
* ``release_stage`` for child packets: the parent stage whose header entry
                    at the representative releases the child (cut-through
                    relay, as in the host sim's ``header_times`` rule).
* ``lane``          NI injection lane ``idx(source) * 2 + is_child`` — child
                    packets use the multicast relay port, fresh traffic the
                    normal injection queue (two lanes per node, as in the
                    host sim's ``src_queues``).

Padding rows have ``enqueue = NEVER`` and are never released; padded stage
entries hold link 0 and are unreachable (``fpos < num_stages`` gating).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ...core.grid import Coord
from ...core.planner import MulticastPlan, plan
from ...core.topology import make_topology
from ..config import NoCConfig
from ..traffic import Workload

# Enqueue sentinel for padding rows: far beyond any horizon, small enough
# that key arithmetic (enqueue * P * F) stays well inside int32.
NEVER = np.int32(2**20)


@dataclass(frozen=True)
class CompiledTraffic:
    """One workload under one algorithm, lowered to fixed-shape arrays."""

    # static geometry / config
    n: int
    m: int
    kind: str
    num_nodes: int
    num_links: int  # directed-link id space: num_nodes * 4
    horizon: int
    # per-packet (P,)
    enqueue: np.ndarray  # int32; NEVER on padding rows
    parent: np.ndarray  # int32; -1 = root packet
    release_stage: np.ndarray  # int32; -1 for roots
    lane: np.ndarray  # int32; node * 2 + is_child
    num_stages: np.ndarray  # int32
    eject_node: np.ndarray  # int32; row-major index of hops[-1]
    valid: np.ndarray  # bool
    # per-stage (P, S)
    link: np.ndarray  # int32
    vcls: np.ndarray  # int32; 0 HIGH / 1 LOW
    deliver: np.ndarray  # bool
    node: np.ndarray  # int32
    # per-lane static injection order (2NN, Q): pids by (enqueue, pid), -1 pad
    lane_seq: np.ndarray
    # child (DPM re-injection) table: (C,) rows + (P,) pid -> row map
    child_ix: np.ndarray  # (P,) int32; -1 = root
    child_parent: np.ndarray  # (C,) int32
    child_rs: np.ndarray  # (C,) int32 — parent stage releasing the child
    child_enq: np.ndarray  # (C,) int32

    @property
    def num_packets(self) -> int:
        return int(self.valid.sum())

    @property
    def max_stages(self) -> int:
        return self.link.shape[1]


def compile_workload(
    cfg: NoCConfig,
    workload: Workload,
    algo,
    pad_packets: int | None = None,
    pad_stages: int | None = None,
    cost_model=None,
) -> CompiledTraffic:
    """Plan every request and lower the packet set to dense arrays.

    ``algo`` is resolved through the routing-algorithm registry (name or
    ``RoutingAlgorithm`` instance); ``cost_model`` optionally overrides the
    objective cost-sensitive algorithms plan under. With
    ``cfg.broken_links`` set, plans come from the fault-aware route
    provider on the degraded topology, and every lowered hop is re-checked:
    a route crossing a broken link is refused before any tensor is built
    (the same contract as ``WormholeSim.add_plan``).
    """
    g = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
    rows: list[tuple] = []  # (hops, deliveries, enqueue, parent_pid)
    for r in workload.requests:
        pl_ = plan(algo, g, r.src, r.dests, cost_model=cost_model)
        _lower_plan(pl_, r.time, rows)
    is_broken = getattr(g, "is_broken", None)
    if is_broken is not None:
        for hops, *_ in rows:
            for u, v in zip(hops, hops[1:]):
                if is_broken(u, v):
                    raise ValueError(
                        f"compiled route traverses broken link ({u}, {v}); "
                        f"replan on the degraded topology"
                    )
    P = len(rows)
    S = max((len(h) - 1 for h, *_ in rows), default=1)
    Pp = max(P, 1) if pad_packets is None else pad_packets
    Sp = S if pad_stages is None else pad_stages
    if Pp < P or Sp < S:
        raise ValueError(f"pad ({Pp},{Sp}) smaller than workload ({P},{S})")

    enqueue = np.full(Pp, NEVER, np.int32)
    parent = np.full(Pp, -1, np.int32)
    release_stage = np.full(Pp, -1, np.int32)
    lane = np.zeros(Pp, np.int32)
    num_stages = np.ones(Pp, np.int32)
    eject_node = np.zeros(Pp, np.int32)
    valid = np.zeros(Pp, bool)
    link = np.zeros((Pp, Sp), np.int32)
    vcls = np.zeros((Pp, Sp), np.int32)
    deliver = np.zeros((Pp, Sp), bool)
    node = np.zeros((Pp, Sp), np.int32)

    # per-stage tables, vectorized over one flat hop-pair array (the python
    # per-hop loop dominated lowering time on big sweeps)
    n, m = g.n, g.rows
    flat_uv: list[Coord] = []
    lens = np.zeros(P, np.int64)
    for pid, (hops, deliveries, t, par) in enumerate(rows):
        ns = len(hops) - 1
        lens[pid] = ns
        flat_uv.extend(hops)
        enqueue[pid] = t
        parent[pid] = -1 if par is None else par
        lane[pid] = g.idx(hops[0]) * 2 + (0 if par is None else 1)
        num_stages[pid] = ns
        eject_node[pid] = g.idx(hops[-1])
        valid[pid] = True
        for d in deliveries:
            deliver[pid, hops.index(d, 1) - 1] = True
        if par is not None:
            release_stage[pid] = rows[par][0].index(hops[0], 1) - 1
    if P:
        hv = np.array(flat_uv, np.int64)  # all hops, path-concatenated
        starts = np.cumsum(lens + 1) - (lens + 1)  # path offsets incl. hop 0
        total = int(lens.sum())
        pidx = np.repeat(np.arange(P), lens)
        sidx = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens)
        flat = np.repeat(starts, lens) + sidx  # index of hop u of (pid, s)
        ux, uy = hv[flat, 0], hv[flat, 1]
        vx, vy = hv[flat + 1, 0], hv[flat + 1, 1]
        dx, dy = vx - ux, vy - uy
        if g.wrap:  # signed shortest step (matches Topology.delta)
            dx = (dx + n // 2) % n - n // 2
            dy = (dy + m // 2) % m - m // 2
        dir_ = np.select([dx == 1, dx == -1, dy == 1], [0, 1, 2], default=3)
        labu = np.where(uy % 2 == 0, uy * n + ux, uy * n + n - 1 - ux)
        labv = np.where(vy % 2 == 0, vy * n + vx, vy * n + n - 1 - vx)
        link[pidx, sidx] = (uy * n + ux) * 4 + dir_
        vcls[pidx, sidx] = labv < labu  # 0 HIGH (label up), 1 LOW
        node[pidx, sidx] = vy * n + vx

    # static per-lane injection order: (enqueue, pid) — the host sim's FIFO
    # release order for roots; for children an approximation of the dynamic
    # parent-arrival order (see step.py fidelity notes)
    by_lane: dict[int, list[int]] = {}
    order = sorted(range(P), key=lambda p: (int(enqueue[p]), p))
    for pid in order:
        by_lane.setdefault(int(lane[pid]), []).append(pid)
    Qn = max((len(v) for v in by_lane.values()), default=1)
    lane_seq = np.full((2 * g.num_nodes, Qn), -1, np.int32)
    for ln, pids in by_lane.items():
        lane_seq[ln, : len(pids)] = pids

    child_rows = np.flatnonzero(parent >= 0)
    C = max(1, len(child_rows))
    child_ix = np.full(Pp, -1, np.int32)
    child_parent = np.zeros(C, np.int32)
    child_rs = np.full(C, NEVER, np.int32)
    child_enq = np.full(C, NEVER, np.int32)
    for row, pid in enumerate(child_rows):
        child_ix[pid] = row
        child_parent[row] = parent[pid]
        child_rs[row] = release_stage[pid]
        child_enq[row] = enqueue[pid]

    # age-key arithmetic must stay inside int32 (see step.py)
    max_key = (int(enqueue[valid].max(initial=0)) + 1) * Pp * cfg.flits_per_packet
    assert max_key < 2**28, f"workload too large for int32 age keys ({max_key})"
    return CompiledTraffic(
        n=g.n, m=g.rows, kind=g.kind,
        num_nodes=g.num_nodes, num_links=g.num_nodes * 4,
        horizon=workload.horizon,
        enqueue=enqueue, parent=parent, release_stage=release_stage,
        lane=lane, num_stages=num_stages, eject_node=eject_node, valid=valid,
        link=link, vcls=vcls, deliver=deliver, node=node,
        lane_seq=lane_seq, child_ix=child_ix, child_parent=child_parent,
        child_rs=child_rs, child_enq=child_enq,
    )


def _lower_plan(pl_: MulticastPlan, t: int, rows: list) -> None:
    """Append one row per packet, matching WormholeSim.add_plan semantics."""
    idx_map: list[int | None] = []  # plan-local path index -> global pid
    for path in pl_.paths:
        if len(path.hops) == 1:
            # degenerate source-only path: delivered instantly, no packet
            # (none of the shipped planners emit one as a parent).
            idx_map.append(None)
            continue
        par = None
        if path.parent is not None:
            par = idx_map[path.parent]
            assert par is not None, "parent path must carry flits"
        # deliveries may be empty: transit segments of a degraded-topology
        # monotone-segmented plan relay the worm without absorbing a copy
        assert path.hops[0] not in path.deliveries
        idx_map.append(len(rows))
        rows.append((path.hops, list(path.deliveries), t, par))


def stack_traffic(
    traffics: list[CompiledTraffic],
) -> tuple[CompiledTraffic, dict[str, np.ndarray]]:
    """Pad a batch to common (P, S) and stack every array on a new axis 0.

    Returns the first (re-padded) element as the shared-static reference plus
    the dict of stacked arrays ``{field: (B, ...)}`` that feeds the vmapped
    runner. All elements must share geometry and id spaces.
    """
    t0 = traffics[0]
    for t in traffics[1:]:
        if (t.n, t.m, t.kind) != (t0.n, t0.m, t0.kind):
            raise ValueError("cannot batch traffic across different topologies")
    Pp = max(t.enqueue.shape[0] for t in traffics)
    Sp = max(t.max_stages for t in traffics)
    Qp = max(t.lane_seq.shape[1] for t in traffics)
    Cp = max(t.child_parent.shape[0] for t in traffics)

    def pad(t: CompiledTraffic) -> CompiledTraffic:
        dp = Pp - t.enqueue.shape[0]
        ds = Sp - t.max_stages
        pad1 = lambda a, fill: np.pad(a, (0, dp), constant_values=fill)
        pad2 = lambda a: np.pad(a, ((0, dp), (0, ds)))
        dc = Cp - t.child_parent.shape[0]
        padc = lambda a, fill: np.pad(a, (0, dc), constant_values=fill)
        return CompiledTraffic(
            n=t.n, m=t.m, kind=t.kind, num_nodes=t.num_nodes,
            num_links=t.num_links, horizon=t.horizon,
            enqueue=pad1(t.enqueue, NEVER), parent=pad1(t.parent, -1),
            release_stage=pad1(t.release_stage, -1), lane=pad1(t.lane, 0),
            num_stages=pad1(t.num_stages, 1), eject_node=pad1(t.eject_node, 0),
            valid=pad1(t.valid, False),
            link=pad2(t.link), vcls=pad2(t.vcls),
            deliver=pad2(t.deliver), node=pad2(t.node),
            lane_seq=np.pad(
                t.lane_seq, ((0, 0), (0, Qp - t.lane_seq.shape[1])),
                constant_values=-1,
            ),
            child_ix=pad1(t.child_ix, -1),
            child_parent=padc(t.child_parent, 0),
            child_rs=padc(t.child_rs, NEVER),
            child_enq=padc(t.child_enq, NEVER),
        )

    padded = [pad(t) for t in traffics]
    fields = (
        "enqueue", "parent", "release_stage", "lane", "num_stages",
        "eject_node", "valid", "link", "vcls", "deliver", "node",
        "lane_seq", "child_ix", "child_parent", "child_rs", "child_enq",
    )
    stacked = {f: np.stack([getattr(t, f) for t in padded]) for f in fields}
    return padded[0], stacked
