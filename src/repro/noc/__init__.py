"""Cycle-level wormhole NoC simulator, traffic generators and power model."""
from .config import DEST_RANGES, EnergyModel, NoCConfig
from .simulator import SimStats, WormholeSim
from .traffic import (
    PARSEC_PROFILES,
    Request,
    Workload,
    latency_vs_rate,
    parsec_workload,
    simulate,
    synthetic_workload,
)

__all__ = [
    "DEST_RANGES",
    "EnergyModel",
    "NoCConfig",
    "PARSEC_PROFILES",
    "Request",
    "SimStats",
    "Workload",
    "WormholeSim",
    "latency_vs_rate",
    "parsec_workload",
    "simulate",
    "synthetic_workload",
]
