"""Cycle-level wormhole NoC simulator, traffic generators and power model.

Two execution engines share the planners, workloads, and config: the
event-ordered Python ``WormholeSim`` (the fidelity oracle) and the
vectorized ``noc.xsim`` scan/vmap engine for batched sweeps (DESIGN.md §5).
"""
from .config import DEST_RANGES, EnergyModel, NoCConfig
from .simulator import SimStats, WormholeSim
from .telemetry import (
    CalibrationResult,
    LatencyHistogram,
    MeasuredContentionCost,
    MeasuredEnergyCost,
    Telemetry,
    calibrate_cost_model,
    fit_energy_cost,
    link_coords,
    link_index,
)
from .traffic import (
    PARSEC_PROFILES,
    Request,
    Workload,
    latency_vs_rate,
    parsec_workload,
    simulate,
    synthetic_workload,
)
from .trace import (
    ReplayResult,
    Trace,
    TraceEvent,
    TracePhase,
    cross_validate,
    export_timeline,
    replay_host,
    replay_xsim,
)
from .xsim import XSimResults, latency_vs_rate_batched, xsimulate

__all__ = [
    "CalibrationResult",
    "DEST_RANGES",
    "EnergyModel",
    "LatencyHistogram",
    "MeasuredContentionCost",
    "MeasuredEnergyCost",
    "NoCConfig",
    "PARSEC_PROFILES",
    "ReplayResult",
    "Request",
    "SimStats",
    "Telemetry",
    "Trace",
    "TraceEvent",
    "TracePhase",
    "Workload",
    "WormholeSim",
    "XSimResults",
    "calibrate_cost_model",
    "cross_validate",
    "export_timeline",
    "fit_energy_cost",
    "latency_vs_rate",
    "latency_vs_rate_batched",
    "link_coords",
    "link_index",
    "parsec_workload",
    "replay_host",
    "replay_xsim",
    "simulate",
    "synthetic_workload",
    "xsimulate",
]
