"""ML-workload trace capture and replay through both NoC simulators
(DESIGN.md §9).

``ir`` defines the phase-barrier trace IR (JSON round-trippable);
``lower`` captures traces from the repo's real communication code paths
(collective schedules, GPipe handoffs, int8 all-reduce, HLO collective
mixes) plus coherence/serving generators; ``replay`` drives both engines
with barrier semantics and cross-validates them.
"""
from .ir import Trace, TraceEvent, TracePhase, phase, trace
from .lower import (
    coherence_trace,
    compressed_allreduce_trace,
    ep_dispatch_trace,
    from_hlo,
    from_schedule,
    model_collective_mix,
    pipeline_trace,
    serving_trace,
    zero1_gather_trace,
)
from .replay import (
    DEFAULT_FLIT_BYTES,
    DEFAULT_MAX_FLITS,
    ReplayResult,
    cross_validate,
    export_timeline,
    flits_for_bytes,
    replay_host,
    replay_xsim,
)

__all__ = [
    "DEFAULT_FLIT_BYTES",
    "DEFAULT_MAX_FLITS",
    "ReplayResult",
    "Trace",
    "TraceEvent",
    "TracePhase",
    "coherence_trace",
    "compressed_allreduce_trace",
    "cross_validate",
    "ep_dispatch_trace",
    "export_timeline",
    "flits_for_bytes",
    "from_hlo",
    "from_schedule",
    "model_collective_mix",
    "phase",
    "pipeline_trace",
    "replay_host",
    "replay_xsim",
    "serving_trace",
    "trace",
    "zero1_gather_trace",
]
