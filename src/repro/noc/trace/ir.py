"""Trace IR: dependency-ordered phases of timestamped multicast events.

A ``Trace`` is the NoC-facing snapshot of an ML workload: a sequence of
*phases* executed under barrier semantics — every event of phase ``k``
must complete delivery before any event of phase ``k+1`` injects (the
store-and-forward causality of a collective round, a pipeline step, or a
serving batch). Each phase holds timestamped events carrying a source
rank, a destination rank set, and a payload byte count; ranks are
abstract indices in ``[0, num_ranks)`` that the replay drivers embed onto
a mesh/torus in boustrophedon label order (``Topology.unlabel``), the
same rank->node convention ``dist.multicast`` schedules use.

Byte counts stay bytes in the IR — the replay layer converts them to
per-packet flit counts against a flit width (``replay.flits_for_bytes``),
so one captured trace replays faithfully across link-width configs.

Traces serialize to/from JSON (round-trip identity — the artifact-diffing
contract benchmarks rely on).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass(frozen=True)
class TraceEvent:
    """One multicast (or unicast) injection.

    ``time`` is the cycle offset *within the phase*; ``dests`` is the
    ordered destination rank tuple (unicast = one entry); ``payload_bytes``
    is the logical message size before flit conversion.
    """

    time: int
    src: int
    dests: tuple[int, ...]
    payload_bytes: int

    def validate(self, num_ranks: int) -> None:
        if self.time < 0:
            raise ValueError(f"event time must be >= 0 (got {self.time})")
        if not 0 <= self.src < num_ranks:
            raise ValueError(f"src {self.src} outside [0, {num_ranks})")
        if not self.dests:
            raise ValueError("event needs at least one destination")
        for d in self.dests:
            if not 0 <= d < num_ranks:
                raise ValueError(f"dest {d} outside [0, {num_ranks})")
        if self.src in self.dests:
            raise ValueError(f"src {self.src} cannot be its own destination")
        if len(set(self.dests)) != len(self.dests):
            raise ValueError(f"duplicate destinations in {self.dests}")
        if self.payload_bytes < 0:
            raise ValueError(f"negative payload ({self.payload_bytes})")


@dataclass(frozen=True)
class TracePhase:
    """One barrier-delimited batch of events (a collective round, a
    pipeline step, a coherence burst, a serving batch)."""

    name: str
    events: tuple[TraceEvent, ...]

    @property
    def span(self) -> int:
        """Last injection offset within the phase."""
        return max((e.time for e in self.events), default=0)

    @property
    def total_bytes(self) -> int:
        return sum(e.payload_bytes * len(e.dests) for e in self.events)


@dataclass(frozen=True)
class Trace:
    """A named workload trace: phases replay in order, barrier-separated."""

    name: str
    num_ranks: int
    phases: tuple[TracePhase, ...]
    meta: dict = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.num_ranks < 2:
            raise ValueError(f"need >= 2 ranks (got {self.num_ranks})")
        for ph in self.phases:
            for e in ph.events:
                e.validate(self.num_ranks)

    @property
    def num_events(self) -> int:
        return sum(len(ph.events) for ph in self.phases)

    @property
    def total_bytes(self) -> int:
        return sum(ph.total_bytes for ph in self.phases)

    # ---------------------------------------------------------------- JSON
    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(
            {
                "name": self.name,
                "num_ranks": self.num_ranks,
                "meta": self.meta,
                "phases": [
                    {
                        "name": ph.name,
                        "events": [
                            [e.time, e.src, list(e.dests), e.payload_bytes]
                            for e in ph.events
                        ],
                    }
                    for ph in self.phases
                ],
            },
            indent=indent,
        )

    @staticmethod
    def from_json(text: str) -> "Trace":
        d = json.loads(text)
        return Trace(
            name=d["name"],
            num_ranks=int(d["num_ranks"]),
            phases=tuple(
                TracePhase(
                    name=ph["name"],
                    events=tuple(
                        TraceEvent(int(t), int(s), tuple(int(x) for x in ds),
                                   int(b))
                        for t, s, ds, b in ph["events"]
                    ),
                )
                for ph in d["phases"]
            ),
            meta=d.get("meta", {}),
        )


def phase(name: str, events) -> TracePhase:
    """Phase constructor accepting any event iterable."""
    return TracePhase(name=name, events=tuple(events))


def trace(name: str, num_ranks: int, phases, meta: dict | None = None) -> Trace:
    """Trace constructor accepting any phase iterable."""
    return Trace(
        name=name, num_ranks=num_ranks, phases=tuple(phases),
        meta=meta or {},
    )
