"""Lowerers: real workload code paths -> ``Trace`` phase sequences.

Every producer here captures the *communication shape* of a code path that
exists elsewhere in the repo — collective schedules from
``dist.multicast``, GPipe handoffs from ``dist.pipeline``'s step loop, the
int8 RS+AG rounds of ``dist.compress``, HLO collective mixes from
``launch.hlo.collective_bytes`` — plus two synthetic generators (directory
coherence invalidations, Poisson serving arrivals) for traffic classes the
collectives layer does not emit.

Ranks are abstract; the replay drivers embed rank ``r`` at
``topo.unlabel(r)`` (boustrophedon order), matching the 1-D ring embedding
``dist.multicast`` schedules assume.
"""
from __future__ import annotations

import math

import numpy as np

from .ir import Trace, TraceEvent, TracePhase

# Control-message payload (coherence invalidations / acks): header-only.
CTRL_BYTES = 8


# --------------------------------------------------------------------------
# dist.multicast schedules
# --------------------------------------------------------------------------
def from_schedule(
    sched,
    name: str,
    payload_bytes: int,
    req_payload_bytes: dict[int, int] | None = None,
    phase_prefix: str = "round",
    meta: dict | None = None,
) -> Trace:
    """Lower a ``dist.multicast.Schedule`` round-by-round.

    Each ppermute round becomes one phase (the store-and-forward causality
    a round boundary encodes *is* the trace barrier); every transfer is a
    unicast event at offset 0 carrying ``req_payload_bytes[rid]`` (falling
    back to ``payload_bytes``) — the same per-request attribution
    ``Schedule.cost`` uses.
    """
    phases = []
    reqs = sched.round_reqs or [[] for _ in sched.rounds]
    for r, (rnd, rr) in enumerate(zip(sched.rounds, reqs)):
        events = []
        for k, (s, d) in enumerate(rnd):
            b = payload_bytes
            if req_payload_bytes is not None and k < len(rr):
                b = req_payload_bytes.get(rr[k], payload_bytes)
            events.append(TraceEvent(0, s, (d,), b))
        phases.append(TracePhase(f"{phase_prefix}{r}", tuple(events)))
    m = {"schedule_rounds": sched.num_rounds, "schedule_hops": sched.total_hops}
    m.update(meta or {})
    return Trace(name, sched.num_ranks, tuple(phases), m)


def ep_dispatch_trace(
    num_ranks: int, chunk_bytes: int = 256, algo: str = "DPM"
) -> Trace:
    """Expert-parallel all-to-all: dispatch rounds then combine rounds.

    Both halves replay ``dist.multicast.alltoall_schedule`` — the schedule
    ``dist.ep.moe_apply_ep``'s token exchange realizes — with one chunk of
    ``chunk_bytes`` per (src, dst) pair.
    """
    from ...dist.multicast import alltoall_schedule

    sched = alltoall_schedule(num_ranks, algo)
    disp = from_schedule(sched, "ep", chunk_bytes, phase_prefix="dispatch.r")
    comb = from_schedule(sched, "ep", chunk_bytes, phase_prefix="combine.r")
    return Trace(
        f"ep_alltoall.n{num_ranks}.{algo}",
        num_ranks,
        disp.phases + comb.phases,
        {"algo": algo, "chunk_bytes": chunk_bytes, "kind": "ep_alltoall"},
    )


def zero1_gather_trace(
    num_ranks: int, param_bytes: int, algo: str = "DPM"
) -> Trace:
    """ZeRO-1 parameter all-gather over a data axis.

    Each rank owns a ``param_bytes / n`` optimizer shard
    (``dist.sharding.zero1_shardings``) and broadcasts it to every peer;
    the n concurrent broadcasts are packed into ppermute rounds by
    ``schedule_multicasts`` on the rank ring.
    """
    from ...core.topology import torus
    from ...dist.multicast import schedule_multicasts

    ring = torus(num_ranks, 1)
    requests = [
        ((i, 0), [(j, 0) for j in range(num_ranks) if j != i])
        for i in range(num_ranks)
    ]
    shard = max(1, math.ceil(param_bytes / num_ranks))
    sched = schedule_multicasts(ring, requests, algo)
    return from_schedule(
        sched,
        f"zero1_gather.n{num_ranks}.{algo}",
        shard,
        phase_prefix="ag.r",
        meta={"algo": algo, "param_bytes": param_bytes, "kind": "zero1"},
    )


def compressed_allreduce_trace(
    num_ranks: int, grad_bytes: int, algo: str = "DPM"
) -> Trace:
    """int8 compressed gradient all-reduce (``dist.compress``): an int8
    reduce-scatter rendered as the all-to-all chunk exchange it lowers to,
    then the all-gather of re-quantized reduced chunks. Chunks are
    ``grad_bytes / (4 n)`` — f32 gradients quantized 4x, split n ways."""
    from ...core.topology import torus
    from ...dist.multicast import alltoall_schedule, schedule_multicasts

    chunk = max(1, math.ceil(grad_bytes / (4 * num_ranks)))
    rs = from_schedule(
        alltoall_schedule(num_ranks, algo), "rs", chunk, phase_prefix="rs.r"
    )
    ring = torus(num_ranks, 1)
    requests = [
        ((i, 0), [(j, 0) for j in range(num_ranks) if j != i])
        for i in range(num_ranks)
    ]
    ag = from_schedule(
        schedule_multicasts(ring, requests, algo), "ag", chunk,
        phase_prefix="ag.r",
    )
    return Trace(
        f"int8_allreduce.n{num_ranks}.{algo}",
        num_ranks,
        rs.phases + ag.phases,
        {"algo": algo, "grad_bytes": grad_bytes, "chunk_bytes": chunk,
         "kind": "int8_allreduce"},
    )


def pipeline_trace(
    num_stages: int, num_micro: int, activation_bytes: int = 512
) -> Trace:
    """GPipe stage handoffs (``dist.pipeline.pipeline_apply``): the static
    ``M + S - 1`` step loop, one phase per step, stage ``s`` shipping its
    microbatch activation to ``s + 1`` whenever it holds one (the per-step
    ppermute shift). Ranks are pipeline stages."""
    phases = []
    for t in range(num_micro + num_stages - 1):
        events = tuple(
            TraceEvent(0, s, (s + 1,), activation_bytes)
            for s in range(num_stages - 1)
            if 0 <= t - s < num_micro
        )
        if events:
            phases.append(TracePhase(f"step{t}", events))
    return Trace(
        f"gpipe.s{num_stages}.m{num_micro}",
        num_stages,
        tuple(phases),
        {"num_micro": num_micro, "activation_bytes": activation_bytes,
         "kind": "pipeline"},
    )


# --------------------------------------------------------------------------
# HLO collective mixes
# --------------------------------------------------------------------------
def from_hlo(
    hlo_or_collectives,
    num_ranks: int,
    name: str = "hlo",
    algo: str = "DPM",
    scale_to: int | None = None,
) -> Trace:
    """Lower an HLO collective-byte profile onto the rank fabric.

    Accepts HLO text (fed through ``launch.hlo.collective_bytes``) or an
    already-computed ``{kind: bytes}`` dict. Each collective kind maps to
    the phase structure its exchange pattern implies, for a logical buffer
    of ``B`` bytes over ``n`` ranks:

    * ``all-gather``      — each rank broadcasts its ``B/n`` shard
      (``schedule_multicasts`` rounds);
    * ``reduce-scatter``  — all-to-all of ``B/n`` chunks;
    * ``all-reduce``      — reduce-scatter then all-gather of ``B/n``;
    * ``all-to-all``      — all-to-all of ``B/n`` chunks;
    * ``collective-permute`` — one phase, every rank shipping ``B`` to its
      +1 ring neighbor.

    ``scale_to`` rescales the *largest* per-event payload down to that many
    bytes (ratios preserved) so multi-GB training buffers replay as
    NoC-sized worms instead of all clamping at the flit ceiling; the factor
    lands in ``meta["byte_scale"]``.
    """
    from ...core.topology import torus
    from ...dist.multicast import alltoall_schedule, schedule_multicasts

    if isinstance(hlo_or_collectives, str):
        from ...launch.hlo import collective_bytes

        coll = collective_bytes(hlo_or_collectives)
    else:
        coll = dict(hlo_or_collectives)
    kinds = [
        (k, float(coll.get(k, 0.0)))
        for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
    ]
    kinds = [(k, b) for k, b in kinds if b > 0]
    if not kinds:
        raise ValueError(f"no collective bytes in profile {sorted(coll)}")

    per_event = {
        k: b / num_ranks if k != "collective-permute" else b
        for k, b in kinds
    }
    scale = 1.0
    if scale_to is not None:
        scale = scale_to / max(per_event.values())

    def nbytes(k):
        return max(1, math.ceil(per_event[k] * scale))

    ring = torus(num_ranks, 1)
    bcast_reqs = [
        ((i, 0), [(j, 0) for j in range(num_ranks) if j != i])
        for i in range(num_ranks)
    ]
    a2a = alltoall_schedule(num_ranks, algo)

    phases: list[TracePhase] = []

    def add(tr: Trace):
        phases.extend(tr.phases)

    for k, _ in kinds:
        if k == "all-gather":
            add(from_schedule(
                schedule_multicasts(ring, bcast_reqs, algo), k, nbytes(k),
                phase_prefix=f"{k}.r",
            ))
        elif k in ("reduce-scatter", "all-to-all"):
            add(from_schedule(a2a, k, nbytes(k), phase_prefix=f"{k}.r"))
        elif k == "all-reduce":
            add(from_schedule(a2a, k, nbytes(k), phase_prefix=f"{k}.rs.r"))
            add(from_schedule(
                schedule_multicasts(ring, bcast_reqs, algo), k, nbytes(k),
                phase_prefix=f"{k}.ag.r",
            ))
        else:  # collective-permute: +1 ring shift
            phases.append(TracePhase(
                f"{k}.r0",
                tuple(
                    TraceEvent(0, i, ((i + 1) % num_ranks,), nbytes(k))
                    for i in range(num_ranks)
                ),
            ))
    return Trace(
        name, num_ranks, tuple(phases),
        {"algo": algo, "byte_scale": scale, "kind": "hlo_mix",
         "collectives": {k: b for k, b in kinds}},
    )


def model_collective_mix(
    arch_name: str,
    num_ranks: int,
    algo: str = "DPM",
    scale_to: int = 512,
) -> Trace:
    """Per-training-step collective mix of a ``repro.configs`` model.

    Sizes come from ``launch.specs.param_counts`` (abstract init of the
    real model): bf16 gradient all-reduce over the data axis, the ZeRO-1
    bf16 parameter all-gather, and — for MoE archs — the expert-parallel
    token all-to-all (bf16 activations for one ~1k-token microbatch,
    dispatch + combine). ``from_hlo`` then lowers the byte profile with
    payloads rescaled to NoC-sized worms.
    """
    from ...configs import get_arch
    from ...launch.specs import param_counts
    from ...models.config import RunConfig

    cfg = get_arch(arch_name)
    counts = param_counts(cfg, RunConfig())
    coll = {
        "all-reduce": 2.0 * counts["total"],  # bf16 grads over data axis
        "all-gather": 2.0 * counts["total"],  # zero1 param gather
    }
    if cfg.moe:
        # EP dispatch+combine: ~1k tokens of bf16 d_model activations
        coll["all-to-all"] = 2.0 * 2.0 * cfg.d_model * 1024
    return from_hlo(
        coll, num_ranks, f"mix.{arch_name}.n{num_ranks}.{algo}", algo,
        scale_to=scale_to,
    )


# --------------------------------------------------------------------------
# synthetic generators
# --------------------------------------------------------------------------
def coherence_trace(
    num_ranks: int,
    num_bursts: int = 4,
    lines_per_burst: int = 4,
    sharers: int = 4,
    seed: int = 0,
) -> Trace:
    """Directory-coherence invalidation bursts.

    Each burst is a write acquiring exclusive ownership of a few cache
    lines: the line's home node multicasts a header-only invalidation to
    the sharer set (phase ``inv.bK``), and the sharers ack back (phase
    ``ack.bK``) — the ack phase cannot inject before the invalidations
    deliver, which is exactly the trace barrier.
    """
    rng = np.random.default_rng(seed)
    sharers = min(sharers, num_ranks - 1)
    phases = []
    for b in range(num_bursts):
        inv, ack = [], []
        for _ in range(lines_per_burst):
            home = int(rng.integers(num_ranks))
            others = [r for r in range(num_ranks) if r != home]
            dests = tuple(
                int(x) for x in rng.choice(others, size=sharers, replace=False)
            )
            inv.append(TraceEvent(0, home, dests, CTRL_BYTES))
            ack.extend(TraceEvent(0, d, (home,), CTRL_BYTES) for d in dests)
        # acks from one sharer to distinct homes are distinct unicasts;
        # drop exact duplicates (same sharer acking the same home twice in
        # one burst collapses to one message)
        seen, uack = set(), []
        for e in ack:
            key = (e.src, e.dests)
            if key not in seen:
                seen.add(key)
                uack.append(e)
        phases.append(TracePhase(f"inv.b{b}", tuple(inv)))
        phases.append(TracePhase(f"ack.b{b}", tuple(uack)))
    return Trace(
        f"coherence.n{num_ranks}.s{seed}",
        num_ranks,
        tuple(phases),
        {"num_bursts": num_bursts, "lines_per_burst": lines_per_burst,
         "sharers": sharers, "seed": seed, "kind": "coherence"},
    )


def serving_trace(
    num_ranks: int,
    num_requests: int = 24,
    rate: float = 0.02,
    act_bytes: int = 256,
    max_batch: int = 8,
    seed: int = 0,
) -> Trace:
    """Poisson serving arrivals batched ``serve.engine.BatchServer``-style.

    Requests arrive as a Poisson process (exponential inter-arrivals at
    ``rate`` per cycle) on random entry ranks; the server admits up to
    ``max_batch`` in arrival order, and a new batch starts only when the
    previous one retires — so each batch is one phase, with each request's
    activations broadcast to the model-parallel group (all other ranks) at
    its arrival offset within the batch window.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=num_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    entries = rng.integers(num_ranks, size=num_requests)
    phases = []
    for b in range(0, num_requests, max_batch):
        batch = range(b, min(b + max_batch, num_requests))
        t0 = int(arrivals[b])
        events = tuple(
            TraceEvent(
                int(arrivals[i]) - t0,
                int(entries[i]),
                tuple(r for r in range(num_ranks) if r != int(entries[i])),
                act_bytes,
            )
            for i in batch
        )
        phases.append(TracePhase(f"batch{b // max_batch}", events))
    return Trace(
        f"serving.n{num_ranks}.s{seed}",
        num_ranks,
        tuple(phases),
        {"num_requests": num_requests, "rate": rate, "act_bytes": act_bytes,
         "max_batch": max_batch, "seed": seed, "kind": "serving"},
    )
