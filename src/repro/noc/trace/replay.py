"""Dependency-aware trace replay through both NoC simulators.

Phases replay under barrier semantics: phase ``k + 1`` injects only after
every delivery of phase ``k`` has completed. The host driver realizes the
barrier literally — one fresh ``WormholeSim`` per phase, run to drain; the
xsim driver maps phases onto the *workloads* axis of a single
``xsimulate`` batch (one vmapped device dispatch for the whole trace),
which encodes the same semantics because batch cells share nothing.

Payload bytes become per-packet worm lengths here:
``ceil(bytes / flit_bytes)`` flits, clamped to ``[1, max_flits]`` — the
clamp keeps a multi-KB collective worm from monopolizing every VC on its
path while preserving the relative cost of control vs payload traffic.

``cross_validate`` runs both drivers and enforces the simulators' parity
contract on real workload traffic: identical per-packet delivery sets per
phase, end-to-end completion within the documented 10% latency band.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..config import NoCConfig
from ..simulator import WormholeSim
from ..traffic import Request, Workload
from ...core.topology import make_topology
from .ir import Trace

DEFAULT_FLIT_BYTES = 16  # link phit width: one flit moves 16 payload bytes
DEFAULT_MAX_FLITS = 64  # worm-length clamp (int8 xsim planes cap at 127)


def flits_for_bytes(
    nbytes: int,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
) -> int:
    """Payload bytes -> worm length in flits, clamped to [1, max_flits]."""
    if max_flits > 127:
        raise ValueError(f"max_flits {max_flits} exceeds xsim plane cap 127")
    return max(1, min(int(max_flits), -(-int(nbytes) // int(flit_bytes))))


@dataclass
class ReplayResult:
    """Per-phase and end-to-end stats of one trace replay."""

    trace_name: str
    engine: str  # "host" | "xsim"
    algo: str
    phase_names: list[str]
    phase_cycles: list[int]  # per-phase completion (cycles to last tail)
    phase_deliveries: list[dict[int, set[int]]]  # pid -> delivered node idxs

    @property
    def total_cycles(self) -> int:
        """End-to-end completion under barrier semantics: phases are
        serialized, so the trace takes the sum of phase durations."""
        return sum(self.phase_cycles)

    def summary(self) -> dict:
        return {
            "trace": self.trace_name,
            "engine": self.engine,
            "algo": self.algo,
            "phases": len(self.phase_names),
            "total_cycles": self.total_cycles,
            "phase_cycles": list(self.phase_cycles),
        }


def _check_fits(tr: Trace, topo) -> None:
    if tr.num_ranks > topo.num_nodes:
        raise ValueError(
            f"trace {tr.name!r} has {tr.num_ranks} ranks but the "
            f"{topo.num_nodes}-node fabric cannot embed them"
        )


def _phase_requests(ph, topo, flit_bytes: int, max_flits: int):
    """Lower one phase's events to simulator requests (ranks embedded in
    boustrophedon label order, bytes converted to worm lengths)."""
    return [
        Request(
            time=e.time,
            src=topo.unlabel(e.src),
            dests=[topo.unlabel(d) for d in e.dests],
            flits=flits_for_bytes(e.payload_bytes, flit_bytes, max_flits),
        )
        for e in ph.events
    ]


def replay_host(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
) -> ReplayResult:
    """Replay through the flit-level host simulator, one drained
    ``WormholeSim`` per phase (the literal barrier)."""
    topo = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
    _check_fits(tr, topo)
    cycles, deliveries = [], []
    for ph in tr.phases:
        sim = WormholeSim(cfg)
        for r in _phase_requests(ph, topo, flit_bytes, max_flits):
            sim.add_request(
                algo, r.src, r.dests, r.time, cost_model=cost_model,
                flits=r.flits,
            )
        st = sim.run(ph.span + cfg.drain_grace, drain=True)
        if st.packets_finished != st.packets_created:
            raise RuntimeError(
                f"phase {ph.name!r} did not drain within "
                f"{ph.span + cfg.drain_grace} cycles "
                f"({st.packets_finished}/{st.packets_created} finished)"
            )
        last = max(
            (t for p in sim.packets for t in p.delivery_times.values()),
            default=0,
        )
        cycles.append(last + 1)
        deliveries.append(
            {p.pid: {topo.idx(c) for c in p.delivery_times}
             for p in sim.packets}
        )
    return ReplayResult(
        trace_name=tr.name,
        engine="host",
        algo=algo,
        phase_names=[ph.name for ph in tr.phases],
        phase_cycles=cycles,
        phase_deliveries=deliveries,
    )


def replay_xsim(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    backend: str | None = None,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
) -> ReplayResult:
    """Replay through the batched xsim engine: every phase is one cell of
    the workloads axis, so the whole trace runs as a single vmapped device
    dispatch — barrier semantics for free, since batch cells are disjoint
    simulations."""
    from ..xsim import xsimulate

    topo = make_topology(cfg.topology, cfg.n, cfg.m, cfg.broken_links)
    _check_fits(tr, topo)
    workloads = [
        Workload(
            name=ph.name,
            requests=_phase_requests(ph, topo, flit_bytes, max_flits),
            horizon=ph.span + 1,
        )
        for ph in tr.phases
    ]
    res = xsimulate(
        cfg, workloads, (algo,), cost_model=cost_model, warmup=0,
        backend=backend,
    )
    cycles, deliveries = [], []
    for w, ph in enumerate(tr.phases):
        if not res.all_drained(w, 0):
            raise RuntimeError(
                f"phase {ph.name!r} did not drain within {res.cycles} cycles"
            )
        b = res._b(w, 0)
        hit = res.traffic["deliver"][b] & (res.dtime[b] >= 0)
        last = int(res.dtime[b][hit].max(initial=-1))
        cycles.append(last + 1)
        deliveries.append(res.delivered_sets(w, 0))
    return ReplayResult(
        trace_name=tr.name,
        engine="xsim",
        algo=algo,
        phase_names=[ph.name for ph in tr.phases],
        phase_cycles=cycles,
        phase_deliveries=deliveries,
    )


def cross_validate(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    backend: str | None = None,
    latency_rel: float = 0.10,
) -> tuple[ReplayResult, ReplayResult]:
    """Replay through both engines and enforce the parity contract.

    Per phase: identical per-packet delivery sets (the hard contract).
    End-to-end: completion times within ``latency_rel`` (the engines
    resolve switch-allocation ties differently, so exact cycle equality
    is not promised — same band the fig6 parity tests use).
    """
    h = replay_host(tr, cfg, algo, cost_model=cost_model)
    x = replay_xsim(tr, cfg, algo, cost_model=cost_model, backend=backend)
    for name, hd, xd in zip(h.phase_names, h.phase_deliveries,
                            x.phase_deliveries):
        if hd != xd:
            diff = {
                p for p in set(hd) | set(xd)
                if hd.get(p) != xd.get(p)
            }
            raise AssertionError(
                f"delivery sets diverge in phase {name!r} "
                f"of {tr.name!r}: packets {sorted(diff)}"
            )
    ht, xt = h.total_cycles, x.total_cycles
    if abs(ht - xt) > latency_rel * max(ht, xt):
        raise AssertionError(
            f"end-to-end completion diverges on {tr.name!r}: "
            f"host {ht} vs xsim {xt} cycles (> {latency_rel:.0%})"
        )
    return h, x
