"""Dependency-aware trace replay through both NoC simulators.

Phases replay under barrier semantics: phase ``k + 1`` injects only after
every delivery of phase ``k`` has completed. The host driver realizes the
barrier literally — one fresh ``WormholeSim`` per phase, run to drain; the
xsim driver maps phases onto the *workloads* axis of a single
``xsimulate`` batch (one vmapped device dispatch for the whole trace),
which encodes the same semantics because batch cells share nothing.

Payload bytes become per-packet worm lengths here:
``ceil(bytes / flit_bytes)`` flits, clamped to ``[1, max_flits]`` — the
clamp keeps a multi-KB collective worm from monopolizing every VC on its
path while preserving the relative cost of control vs payload traffic.

``cross_validate`` runs both drivers and enforces the simulators' parity
contract on real workload traffic: identical per-packet delivery sets per
phase, end-to-end completion within the documented 10% latency band.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field

import numpy as np

from ..config import NoCConfig
from ..simulator import WormholeSim
from ..traffic import Request, Workload
from ...core.topology import make_topology
from .ir import Trace

DEFAULT_FLIT_BYTES = 16  # link phit width: one flit moves 16 payload bytes
DEFAULT_MAX_FLITS = 64  # worm-length clamp (int8 xsim planes cap at 127)
STRAGGLER_TOP_K = 5  # slowest deliveries reported per phase timeline


def flits_for_bytes(
    nbytes: int,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
) -> int:
    """Payload bytes -> worm length in flits, clamped to [1, max_flits]."""
    if max_flits > 127:
        raise ValueError(f"max_flits {max_flits} exceeds xsim plane cap 127")
    return max(1, min(int(max_flits), -(-int(nbytes) // int(flit_bytes))))


@dataclass
class ReplayResult:
    """Per-phase and end-to-end stats of one trace replay."""

    trace_name: str
    engine: str  # "host" | "xsim"
    algo: str
    phase_names: list[str]
    phase_cycles: list[int]  # per-phase completion (cycles to last tail)
    phase_deliveries: list[dict[int, set[int]]]  # pid -> delivered node idxs
    # telemetry timeline (DESIGN.md §10): per-phase (L,) directed-link flit
    # counts, top-K slowest deliveries, and the fault set each phase ran
    # under (None = the config's own set)
    fabric: tuple[int, int] | None = None  # (n, rows) for heatmap reshape
    phase_link_util: list[np.ndarray] = field(default_factory=list)
    phase_stragglers: list[list[dict]] = field(default_factory=list)
    phase_faults: list[tuple | None] = field(default_factory=list)

    @property
    def total_cycles(self) -> int:
        """End-to-end completion under barrier semantics: phases are
        serialized, so the trace takes the sum of phase durations."""
        return sum(self.phase_cycles)

    def summary(self) -> dict:
        return {
            "trace": self.trace_name,
            "engine": self.engine,
            "algo": self.algo,
            "phases": len(self.phase_names),
            "total_cycles": self.total_cycles,
            "phase_cycles": list(self.phase_cycles),
        }

    def timeline(self) -> dict:
        """JSON-ready per-phase telemetry timeline: phase cycles, per-node
        link heatmaps, peak-link pressure, stragglers, and the fault set in
        force — the artifact ``summarize_repro.py`` renders and CI uploads.
        """
        n, rows = self.fabric if self.fabric else (0, 0)
        phases = []
        for i, name in enumerate(self.phase_names):
            util = (
                self.phase_link_util[i]
                if i < len(self.phase_link_util) else None
            )
            entry = {
                "name": name,
                "cycles": int(self.phase_cycles[i]),
                "deliveries": int(
                    sum(len(s) for s in self.phase_deliveries[i].values())
                ),
                "broken_links": (
                    None if i >= len(self.phase_faults)
                    or self.phase_faults[i] is None
                    else [list(map(list, l)) for l in self.phase_faults[i]]
                ),
                "stragglers": (
                    self.phase_stragglers[i]
                    if i < len(self.phase_stragglers) else []
                ),
            }
            if util is not None and n:
                node_flits = util.reshape(rows * n, 4).sum(axis=1)
                entry["max_link_flits"] = int(util.max(initial=0))
                entry["total_flits"] = int(util.sum())
                entry["link_heatmap"] = (
                    node_flits.reshape(rows, n).tolist()
                )
            phases.append(entry)
        return {
            "trace": self.trace_name,
            "engine": self.engine,
            "algo": self.algo,
            "fabric": {"n": n, "rows": rows},
            "total_cycles": self.total_cycles,
            "phases": phases,
        }


def export_timeline(result: ReplayResult, path) -> dict:
    """Write ``result.timeline()`` as JSON; returns the dict written."""
    tl = result.timeline()
    with open(path, "w") as f:
        json.dump(tl, f, indent=2, sort_keys=True)
        f.write("\n")
    return tl


def _resolve_phase_faults(
    tr: Trace, phase_broken_links
) -> list[tuple | None]:
    """Normalize a per-phase broken-links override into one entry per phase.

    Keys may be phase indices or names; an override stays in force for
    every later phase until the next override (a link that dies mid-trace
    stays dead — pass ``()`` at a later phase to model a repair). ``None``
    entries mean "the config's own fault set"."""
    per_phase: list[tuple | None] = [None] * len(tr.phases)
    if not phase_broken_links:
        return per_phase
    names = [ph.name for ph in tr.phases]
    by_idx: dict[int, tuple] = {}
    for k, v in phase_broken_links.items():
        if isinstance(k, str):
            if k not in names:
                raise KeyError(
                    f"unknown phase {k!r} in phase_broken_links; trace "
                    f"{tr.name!r} has phases: {', '.join(names)}"
                )
            i = names.index(k)
        else:
            i = int(k)
            if not 0 <= i < len(names):
                raise IndexError(
                    f"phase index {i} out of range for trace {tr.name!r} "
                    f"({len(names)} phases)"
                )
        by_idx[i] = tuple(tuple(map(tuple, link)) for link in v)
    current: tuple | None = None
    for i in range(len(names)):
        if i in by_idx:
            current = by_idx[i]
        per_phase[i] = current
    return per_phase


def _check_fits(tr: Trace, topo) -> None:
    if tr.num_ranks > topo.num_nodes:
        raise ValueError(
            f"trace {tr.name!r} has {tr.num_ranks} ranks but the "
            f"{topo.num_nodes}-node fabric cannot embed them"
        )


def _phase_requests(ph, topo, flit_bytes: int, max_flits: int):
    """Lower one phase's events to simulator requests (ranks embedded in
    boustrophedon label order, bytes converted to worm lengths)."""
    return [
        Request(
            time=e.time,
            src=topo.unlabel(e.src),
            dests=[topo.unlabel(d) for d in e.dests],
            flits=flits_for_bytes(e.payload_bytes, flit_bytes, max_flits),
        )
        for e in ph.events
    ]


def replay_host(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
    phase_broken_links: dict | None = None,
) -> ReplayResult:
    """Replay through the flit-level host simulator, one drained
    ``WormholeSim`` per phase (the literal barrier).

    ``phase_broken_links`` injects mid-run link failures: a mapping from
    phase index/name to a broken-link set that overrides
    ``cfg.broken_links`` from that phase onward (``_resolve_phase_faults``)
    — each affected phase plans and runs on its own degraded topology, and
    the telemetry timeline shows the degradation step."""
    topo = make_topology(
        cfg.topology, cfg.n, cfg.m, cfg.broken_links, cfg.topology_params
    )
    _check_fits(tr, topo)
    faults = _resolve_phase_faults(tr, phase_broken_links)
    cycles, deliveries = [], []
    link_util, stragglers = [], []
    for ph, flt in zip(tr.phases, faults):
        pcfg = (
            cfg if flt is None
            else dataclasses.replace(cfg, broken_links=flt)
        )
        ptopo = make_topology(
            pcfg.topology, pcfg.n, pcfg.m, pcfg.broken_links,
            pcfg.topology_params,
        )
        sim = WormholeSim(pcfg)
        # bulk admission: the whole phase plans through the shared plan
        # arena in one device dispatch where the fabric supports it
        sim.add_requests(
            algo, _phase_requests(ph, topo, flit_bytes, max_flits),
            cost_model=cost_model,
        )
        st = sim.run(ph.span + cfg.drain_grace, drain=True)
        if st.packets_finished != st.packets_created:
            raise RuntimeError(
                f"phase {ph.name!r} did not drain within "
                f"{ph.span + cfg.drain_grace} cycles "
                f"({st.packets_finished}/{st.packets_created} finished)"
            )
        last = max(
            (t for p in sim.packets for t in p.delivery_times.values()),
            default=0,
        )
        cycles.append(last + 1)
        deliveries.append(
            {p.pid: {ptopo.idx(c) for c in p.delivery_times}
             for p in sim.packets}
        )
        link_util.append(st.telemetry.link_flits.copy())
        lats = sorted(
            (
                (t - p.enqueue_time, p.pid, ptopo.idx(c))
                for p in sim.packets
                for c, t in p.delivery_times.items()
            ),
            reverse=True,
        )[:STRAGGLER_TOP_K]
        stragglers.append(
            [{"pid": pid, "node": node, "latency": int(lat)}
             for lat, pid, node in lats]
        )
    return ReplayResult(
        trace_name=tr.name,
        engine="host",
        algo=algo,
        phase_names=[ph.name for ph in tr.phases],
        phase_cycles=cycles,
        phase_deliveries=deliveries,
        fabric=(cfg.n, cfg.rows),
        phase_link_util=link_util,
        phase_stragglers=stragglers,
        phase_faults=faults,
    )


def replay_xsim(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    backend: str | None = None,
    flit_bytes: int = DEFAULT_FLIT_BYTES,
    max_flits: int = DEFAULT_MAX_FLITS,
    phase_broken_links: dict | None = None,
) -> ReplayResult:
    """Replay through the batched xsim engine: every phase is one cell of
    the workloads axis, so the whole trace runs as a single vmapped device
    dispatch — barrier semantics for free, since batch cells are disjoint
    simulations. ``phase_broken_links`` (same semantics as
    ``replay_host``) rides ``xsimulate``'s per-workload fault override, so
    a mid-trace link failure still runs in the one batched dispatch."""
    from ..xsim import xsimulate

    topo = make_topology(
        cfg.topology, cfg.n, cfg.m, cfg.broken_links, cfg.topology_params
    )
    _check_fits(tr, topo)
    faults = _resolve_phase_faults(tr, phase_broken_links)
    workloads = [
        Workload(
            name=ph.name,
            requests=_phase_requests(ph, topo, flit_bytes, max_flits),
            horizon=ph.span + 1,
        )
        for ph in tr.phases
    ]
    res = xsimulate(
        cfg, workloads, (algo,), cost_model=cost_model, warmup=0,
        backend=backend,
        broken_links_per_workload=(
            None if phase_broken_links is None else faults
        ),
    )
    cycles, deliveries = [], []
    link_util, stragglers = [], []
    for w, ph in enumerate(tr.phases):
        if not res.all_drained(w, 0):
            raise RuntimeError(
                f"phase {ph.name!r} did not drain within {res.cycles} cycles"
            )
        b = res._b(w, 0)
        hit = res.traffic["deliver"][b] & (res.dtime[b] >= 0)
        last = int(res.dtime[b][hit].max(initial=-1))
        cycles.append(last + 1)
        deliveries.append(res.delivered_sets(w, 0))
        link_util.append(res.link_utilization(w, 0))
        enq = res.traffic["enqueue"][b]
        lat = res.dtime[b] - enq[:, None]
        pidx, sidx = np.nonzero(hit)
        order = np.argsort(lat[pidx, sidx])[::-1][:STRAGGLER_TOP_K]
        stragglers.append(
            [
                {
                    "pid": int(pidx[i]),
                    "node": int(res.traffic["node"][b][pidx[i], sidx[i]]),
                    "latency": int(lat[pidx[i], sidx[i]]),
                }
                for i in order
            ]
        )
    return ReplayResult(
        trace_name=tr.name,
        engine="xsim",
        algo=algo,
        phase_names=[ph.name for ph in tr.phases],
        phase_cycles=cycles,
        phase_deliveries=deliveries,
        fabric=(cfg.n, cfg.rows),
        phase_link_util=link_util,
        phase_stragglers=stragglers,
        phase_faults=faults,
    )


def cross_validate(
    tr: Trace,
    cfg: NoCConfig,
    algo: str = "DPM",
    *,
    cost_model=None,
    backend: str | None = None,
    latency_rel: float = 0.10,
    phase_broken_links: dict | None = None,
) -> tuple[ReplayResult, ReplayResult]:
    """Replay through both engines and enforce the parity contract.

    Per phase: identical per-packet delivery sets (the hard contract).
    End-to-end: completion times within ``latency_rel`` (the engines
    resolve switch-allocation ties differently, so exact cycle equality
    is not promised — same band the fig6 parity tests use).
    """
    h = replay_host(
        tr, cfg, algo, cost_model=cost_model,
        phase_broken_links=phase_broken_links,
    )
    x = replay_xsim(
        tr, cfg, algo, cost_model=cost_model, backend=backend,
        phase_broken_links=phase_broken_links,
    )
    for name, hd, xd in zip(h.phase_names, h.phase_deliveries,
                            x.phase_deliveries):
        if hd != xd:
            diff = {
                p for p in set(hd) | set(xd)
                if hd.get(p) != xd.get(p)
            }
            raise AssertionError(
                f"delivery sets diverge in phase {name!r} "
                f"of {tr.name!r}: packets {sorted(diff)}"
            )
    ht, xt = h.total_cycles, x.total_cycles
    if abs(ht - xt) > latency_rel * max(ht, xt):
        raise AssertionError(
            f"end-to-end completion diverges on {tr.name!r}: "
            f"host {ht} vs xsim {xt} cycles (> {latency_rel:.0%})"
        )
    return h, x
