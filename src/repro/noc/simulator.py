"""Flit-level wormhole NoC simulator with VC-split high/low subnetworks.

Model (cycle-level, matching the paper's setup at the granularity its claims
need — see DESIGN.md §2 fidelity notes):

* A packet with route ``hops = [n0 .. nk]`` is a train of F flits moving
  through *stages*; stage ``i`` is the input FIFO at node ``hops[i+1]`` fed by
  directed link ``(hops[i], hops[i+1])``. Flits enter stage 0 from the source
  NI queue and are consumed by the ejection port after the last stage.
* Wormhole + VCs: the header flit allocates one VC (FIFO of depth
  ``buffer_depth``) per stage; body/tail follow on the same VC; the VC frees
  when the tail flit leaves that FIFO. Each physical directed link carries
  ``vcs_per_class`` high-channel and ``vcs_per_class`` low-channel VCs; a hop
  uses the high class iff the boustrophedon label increases on that hop (the
  paper's deadlock rule, applied to unicast and multicast alike). The rule is
  derived from the topology's label order, so it applies unchanged on a
  torus: wrap hops are classified by their label delta like any other hop
  (the snake's closing wrap link is a LOW hop; see DESIGN.md §3 for the
  deadlock-fidelity caveat on torus XY routes).
* Bandwidth: one flit per directed physical link per cycle, age-based (oldest
  enqueue first) arbitration; one flit per node per cycle ejection.
* Path-based multicast delivery: a copy is absorbed when the **tail** flit
  reaches a delivery node (ejection copies are free — separate port).
* DPM MU-mode children are injected at the representative node R once the
  parent delivers there.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from ..core.grid import Coord, MeshGrid
from ..core.planner import MulticastPlan
from ..core.planner import plan as _registry_plan
from ..core.topology import make_topology
from .config import NoCConfig
from .telemetry import Telemetry, link_index

HIGH, LOW = 0, 1
Link = tuple[Coord, Coord]


@dataclass
class _Pkt:
    pid: int
    hops: list[Coord]
    deliveries: set[Coord]
    enqueue_time: int
    parent: int | None  # global pid; child released when parent delivers at hops[0]
    is_multicast: bool
    flits: int  # worm length — per-packet (trace payloads vary)
    released: bool = False
    flits_sent: int = 0  # flits that left the source NI queue
    head_stage: int = -1  # highest stage the header has entered (-1: in NI)
    vc_held: dict = field(default_factory=dict)  # stage -> vc index
    delivery_times: dict = field(default_factory=dict)  # Coord -> cycle (tail)
    header_times: dict = field(default_factory=dict)  # Coord -> cycle (header)
    done: bool = False

    @property
    def num_stages(self) -> int:
        return len(self.hops) - 1

    def link(self, stage: int) -> Link:
        return (self.hops[stage], self.hops[stage + 1])


@dataclass
class SimStats:
    latencies: list[int] = field(default_factory=list)  # per-dest, measured
    flit_link_traversals: int = 0
    buffer_writes: int = 0
    buffer_reads: int = 0
    xbar_traversals: int = 0
    arbitrations: int = 0
    ni_flits: int = 0
    cycles: int = 0
    packets_created: int = 0
    packets_finished: int = 0
    max_srcq: int = 0
    # structured per-link/per-VC/per-epoch view of the same events (the host
    # sim always attaches one; the flat aggregates above stay the public API
    # and the conservation tests pin the two views equal — DESIGN.md §10)
    telemetry: Telemetry | None = field(
        default=None, compare=False, repr=False
    )

    @property
    def avg_latency(self) -> float:
        return sum(self.latencies) / max(1, len(self.latencies))

    def dyn_energy_pj(self, e) -> float:
        return (
            self.buffer_writes * e.e_buffer_write
            + self.buffer_reads * e.e_buffer_read
            + self.xbar_traversals * e.e_xbar
            + self.arbitrations * e.e_arbiter
            + self.flit_link_traversals * e.e_link
            + self.ni_flits * e.e_ni
        )

    def dyn_power(self, e) -> float:
        """Average dynamic power (pJ/cycle) over the simulated window."""
        return self.dyn_energy_pj(e) / max(1, self.cycles)


class WormholeSim:
    def __init__(self, cfg: NoCConfig, measure_window: tuple[int, int] | None = None):
        self.cfg = cfg
        self.g: MeshGrid = make_topology(
            cfg.topology, cfg.n, cfg.m, cfg.broken_links, cfg.topology_params
        )
        self.packets: list[_Pkt] = []
        self.fifos: dict[Link, list[deque]] = {}  # link -> per-VC FIFOs
        self.vc_owner: dict[tuple[Link, int], int] = {}
        self.src_queues: dict[tuple[Coord, int], deque] = {}
        self.stats = SimStats(
            telemetry=Telemetry(
                self.g.num_nodes, cfg.vcs_per_class, cfg.epoch_len,
                ports=getattr(self.g, "ports", 4),
            )
        )
        self._lids: dict[Link, int] = {}  # link -> directed-link id memo
        self.time = 0
        self._measure = measure_window
        self._pending: set[int] = set()
        self._active: set[int] = set()

    # ------------------------------------------------------------- helpers
    def _fifo(self, link: Link) -> list[deque]:
        f = self.fifos.get(link)
        if f is None:
            f = [deque() for _ in range(2 * self.cfg.vcs_per_class)]
            self.fifos[link] = f
        return f

    def _class(self, link: Link) -> int:
        return HIGH if self.g.label(*link[1]) > self.g.label(*link[0]) else LOW

    def _lid(self, link: Link) -> int:
        lid = self._lids.get(link)
        if lid is None:
            lid = self._lids[link] = link_index(self.g, *link)
        return lid

    # ----------------------------------------------------------- admission
    def add_request(
        self,
        algo,
        src: Coord,
        dests: list[Coord],
        enqueue_time: int,
        cost_model=None,
        flits: int | None = None,
    ) -> list[int]:
        """Plan one multicast via the algorithm registry and ingest it.

        ``algo`` is a registered name or ``RoutingAlgorithm`` instance;
        unknown names raise listing what is registered, and algorithms that
        do not support this simulator's topology kind are rejected before
        any packet is admitted. ``flits`` overrides the per-packet worm
        length (default ``cfg.flits_per_packet``).
        """
        return self.add_plan(
            _registry_plan(algo, self.g, src, dests, cost_model=cost_model),
            enqueue_time,
            flits=flits,
        )

    def add_requests(self, algo, requests, cost_model=None) -> list[list[int]]:
        """Bulk admission: plan every request through the shared plan arena
        (``core.batch_planner.bulk_plan`` — one jitted device dispatch for
        all arena misses where the fabric supports it, host planning
        otherwise) and ingest each plan at its request time.

        ``requests`` is an iterable of ``noc.traffic.Request``-likes
        (``.src``, ``.dests``, ``.time``, optional ``.flits``). Plans are
        bit-identical to per-request ``add_request`` calls; returns the
        per-request packet-id lists in order.
        """
        from ..core.batch_planner import bulk_plan

        reqs = list(requests)
        plans = bulk_plan(
            self.g, [(r.src, r.dests) for r in reqs], algo,
            cost_model=cost_model,
        )
        return [
            self.add_plan(p, r.time, flits=getattr(r, "flits", None))
            for r, p in zip(reqs, plans)
        ]

    def add_plan(
        self, plan: MulticastPlan, enqueue_time: int, flits: int | None = None
    ) -> list[int]:
        """Ingest a pre-planned multicast.

        On a degraded topology (``cfg.broken_links``) every path is checked
        hop by hop: a plan that would push a flit across a broken link is
        refused outright — routes must come from the fault-aware provider
        (``add_request`` does), not from a healthy-topology plan.
        """
        is_broken = getattr(self.g, "is_broken", None)
        if is_broken is not None:
            for path in plan.paths:
                for u, v in zip(path.hops, path.hops[1:]):
                    if is_broken(u, v):
                        raise ValueError(
                            f"plan {plan.algorithm!r} traverses broken link "
                            f"({u}, {v}); replan on the degraded topology"
                        )
        flits = self.cfg.flits_per_packet if flits is None else int(flits)
        if flits < 1:
            raise ValueError(f"packet needs at least one flit (got {flits})")
        base = len(self.packets)
        pids = []
        for path in plan.paths:
            if len(path.hops) == 1:
                # degenerate: source is the only "delivery" (can happen for
                # a representative == destination plan); deliver instantly
                continue
            pid = len(self.packets)
            parent = None if path.parent is None else base + path.parent
            self.packets.append(
                _Pkt(
                    pid,
                    path.hops,
                    set(path.deliveries),
                    enqueue_time,
                    parent,
                    is_multicast=len(plan.dests) > 1,
                    flits=flits,
                )
            )
            self._pending.add(pid)
            pids.append(pid)
        return pids

    def _release_ready(self, now: int) -> None:
        for pid in list(self._pending):
            p = self.packets[pid]
            if p.enqueue_time > now:
                continue
            if p.parent is not None:
                # Cut-through relay: the NI at R forks/re-injects as soon as
                # the parent's HEADER arrives (payload flits stream behind).
                t = self.packets[p.parent].header_times.get(p.hops[0])
                if t is None or t >= now:
                    continue
            p.released = True
            # Relayed children (DPM re-injection at R) use the NI's multicast
            # relay port, not the node's normal injection queue: the router's
            # multicast unit forks locally instead of queuing behind fresh
            # traffic (VCTM-style NI support). Link bandwidth is still shared.
            lane = (p.hops[0], 1 if p.parent is not None else 0)
            self.src_queues.setdefault(lane, deque()).append(pid)
            self.stats.packets_created += 1
            self._pending.discard(pid)
            self._active.add(pid)

    # ------------------------------------------------------------ delivery
    def _tail_arrived(self, p: _Pkt, stage: int, now: int) -> None:
        node = p.hops[stage + 1]
        if node in p.deliveries and node not in p.delivery_times:
            p.delivery_times[node] = now
            lat = now - p.enqueue_time
            if self._measure is None or (
                self._measure[0] <= p.enqueue_time < self._measure[1]
            ):
                self.stats.latencies.append(lat)
                self.stats.telemetry.latency(lat, now)

    def _maybe_finish(self, p: _Pkt) -> None:
        if not p.vc_held and p.flits_sent >= p.flits and (
            p.head_stage == p.num_stages - 1
        ):
            if not p.done:
                p.done = True
                self._active.discard(p.pid)
                self.stats.packets_finished += 1

    # ------------------------------------------------------------ main loop
    def run(self, max_cycles: int, drain: bool = True, watchdog: int = 50_000):
        B = self.cfg.buffer_depth
        V = self.cfg.vcs_per_class
        last_progress = self.time
        end = self.time + max_cycles
        while self.time < end:
            now = self.time
            self._release_ready(now)
            progressed = False

            # ---- 1. gather candidates per target link -------------------
            # candidate: (age key, pid, fid, from_stage) wanting to enter
            # stage = from_stage + 1's FIFO (or stage 0 from the NI).
            cand: dict[Link, list] = {}
            for lane, q in self.src_queues.items():
                if not q:
                    continue
                pid = q[0]
                p = self.packets[pid]
                if p.flits_sent < p.flits:
                    link = p.link(0)
                    cand.setdefault(link, []).append(
                        (p.enqueue_time, pid, p.flits_sent, -1)
                    )
            for link, fifos in self.fifos.items():
                for vc, fifo in enumerate(fifos):
                    if not fifo:
                        continue
                    pid, fid, stage = fifo[0]
                    p = self.packets[pid]
                    if stage + 1 >= p.num_stages:
                        continue  # at final stage: ejection handles it
                    nxt = p.link(stage + 1)
                    cand.setdefault(nxt, []).append((p.enqueue_time, pid, fid, stage))

            # ---- 2. per-link arbitration: one flit crosses each link ----
            tm = self.stats.telemetry
            for link, reqs in cand.items():
                reqs.sort(key=lambda c: (c[0], c[1], c[2]))
                self.stats.arbitrations += len(reqs)
                lid = self._lid(link)
                if len(reqs) > 1:  # everyone but one winner loses this cycle
                    tm.conflicts(lid, len(reqs) - 1)
                fifos = self._fifo(link)
                for age, pid, fid, from_stage in reqs:
                    p = self.packets[pid]
                    to_stage = from_stage + 1
                    cls = self._class(link)
                    if fid == 0:  # header: allocate a VC of the hop's class
                        lo = 0 if cls == HIGH else V
                        vc = next(
                            (
                                i
                                for i in range(lo, lo + V)
                                if (link, i) not in self.vc_owner
                            ),
                            None,
                        )
                        if vc is None:
                            tm.stall(lid)  # no free VC in the hop's class
                            continue
                        self.vc_owner[(link, vc)] = pid
                        p.vc_held[to_stage] = vc
                        p.head_stage = to_stage
                    else:
                        vc = p.vc_held.get(to_stage)
                        if vc is None or len(fifos[vc]) >= B:
                            tm.stall(lid)  # no credit (or header still queued)
                            continue  # header not yet there / no credit
                    # move the flit
                    if from_stage == -1:
                        p.flits_sent += 1
                        self.stats.ni_flits += 1
                        if p.flits_sent == p.flits:
                            lane0 = (p.hops[0], 1 if p.parent is not None else 0)
                            self.src_queues[lane0].popleft()
                    else:
                        src_vc = p.vc_held[from_stage]
                        self._fifo(p.link(from_stage))[src_vc].popleft()
                        self.stats.buffer_reads += 1
                        if fid == p.flits - 1:  # tail left from_stage: free its VC
                            self.vc_owner.pop((p.link(from_stage), src_vc), None)
                            del p.vc_held[from_stage]
                    fifos[vc].append((pid, fid, to_stage))
                    self.stats.buffer_writes += 1
                    self.stats.xbar_traversals += 1
                    self.stats.flit_link_traversals += 1
                    tm.flit(lid, cls, now)
                    tm.occupancy(lid, vc, len(fifos[vc]))
                    if fid == 0:
                        # first header arrival per node: releases relayed
                        # children (DPM MU re-injection and the degraded-
                        # topology monotone segments) at any hop, delivery
                        # or not
                        node = p.hops[to_stage + 1]
                        if node not in p.header_times:
                            p.header_times[node] = now
                    if fid == p.flits - 1:
                        self._tail_arrived(p, to_stage, now)
                    progressed = True
                    break  # one flit per link per cycle

            # ---- 3. ejection: one flit per node per cycle ----------------
            ej: dict[Coord, list] = {}
            for link, fifos in self.fifos.items():
                for vc, fifo in enumerate(fifos):
                    if not fifo:
                        continue
                    pid, fid, stage = fifo[0]
                    p = self.packets[pid]
                    if stage + 1 == p.num_stages:
                        ej.setdefault(link[1], []).append(
                            (p.enqueue_time, pid, fid, stage, link, vc)
                        )
            for node, reqs in ej.items():
                reqs.sort(key=lambda c: (c[0], c[1], c[2]))
                age, pid, fid, stage, link, vc = reqs[0]
                p = self.packets[pid]
                self._fifo(link)[vc].popleft()
                self.stats.buffer_reads += 1
                self.stats.ni_flits += 1
                progressed = True
                if fid == p.flits - 1:  # tail ejected: packet complete
                    self.vc_owner.pop((link, vc), None)
                    p.vc_held.pop(stage, None)
                    self._maybe_finish(p)

            if progressed:
                last_progress = now
            elif now - last_progress > watchdog:
                raise RuntimeError(f"simulator wedged at cycle {now}")
            for q in self.src_queues.values():
                if len(q) > self.stats.max_srcq:
                    self.stats.max_srcq = len(q)
            self.time += 1
            if drain and not self._pending and not self._active:
                break

        self.stats.cycles = self.time
        return self.stats
