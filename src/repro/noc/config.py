"""NoC simulation configuration — Table I of the paper + Orion-style energies."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class EnergyModel:
    """Per-event dynamic energies (pJ), Orion-2.0-class 45 nm ballpark.

    Absolute values are calibration constants; the benchmarks report *relative*
    power (as the paper does: % improvement vs MU / MP).
    """

    e_buffer_write: float = 1.20  # pJ / flit buffer write
    e_buffer_read: float = 1.10  # pJ / flit buffer read
    e_xbar: float = 1.70  # pJ / flit crossbar traversal
    e_arbiter: float = 0.24  # pJ / arbitration
    e_link: float = 1.90  # pJ / flit link traversal (1 mm)
    e_ni: float = 0.80  # pJ / flit injected or ejected


@dataclass(frozen=True)
class NoCConfig:
    """Network parameters (paper Table I defaults)."""

    n: int = 8  # 8x8 mesh
    m: int | None = None
    topology: str = "mesh"  # any registered kind (core.topology.make_topology)
    # Extra factory arguments beyond (n, m) — empty for mesh/torus; e.g.
    # (d, z_weight) for mesh3d/torus3d, the chiplet-grid/boundary tuple for
    # "chiplet" (core.topo3d). Threaded verbatim into make_topology.
    topology_params: tuple = ()
    # Broken bidirectional links ((u, v) coordinate pairs): both simulators
    # build a FaultyTopology, plan detours through the route-provider layer
    # (core.routefn), and refuse plans that traverse a broken link.
    broken_links: tuple = ()
    vcs_per_class: int = 2  # 4 VCs total: 2 high-channel + 2 low-channel
    buffer_depth: int = 4  # flits per VC FIFO
    flits_per_packet: int = 4
    multicast_fraction: float = 0.10
    dest_range: tuple[int, int] = (4, 8)  # paper sweeps (2-5),(4-8),(7-10),(10-16)
    energy: EnergyModel = field(default_factory=EnergyModel)
    # measurement window shared by both simulators (traffic.simulate and
    # noc.xsim): packets enqueued in [warmup, horizon) are measured, and the
    # run extends drain_grace cycles past the last injection to let in-flight
    # packets deliver.
    warmup: int = 200
    drain_grace: int = 3000
    # telemetry time-bucket width (cycles) shared by both engines: the host
    # sim's Telemetry epochs and xsim's per-link utilization / per-router
    # conflict planes both bucket on cycle // epoch_len (DESIGN.md §10)
    epoch_len: int = 128
    # xsim cycle-engine backend: None/"auto" picks "ref" on CPU and
    # "pallas" (the fused chunk kernel) on TPU/GPU; "pallas_interpret"
    # runs the kernel path on CPU for validation. An explicit ``backend=``
    # argument to ``xsimulate`` overrides this.
    xsim_backend: str | None = None

    def make_topology(self):
        """The (possibly degraded) topology instance this config describes."""
        from ..core.topology import make_topology

        return make_topology(
            self.topology, self.n, self.m, self.broken_links,
            self.topology_params,
        )

    @property
    def rows(self) -> int:
        if self.topology_params:  # e.g. mesh3d: rows = m * d, not m
            return self.make_topology().rows
        return self.m if self.m is not None else self.n

    @property
    def num_nodes(self) -> int:
        return self.n * self.rows


DEST_RANGES: list[tuple[int, int]] = [(2, 5), (4, 8), (7, 10), (10, 16)]
