"""Structured NoC observability shared by both engines (DESIGN.md §10).

Three layers, host to device:

* ``Telemetry`` — the host simulator's time-and-space-resolved counter
  store: per-directed-link / per-VC-class flit traversals, per-(link, VC)
  buffer-occupancy high-water marks, per-link arbitration conflicts and
  credit stalls, a log2-bucketed per-packet latency histogram, and
  epoch-bucketed time series (``cycle // epoch_len``). ``WormholeSim``
  records into it on every event; the flat ``SimStats`` aggregates stay
  the public API and the conservation tests pin the two views equal.
* The xsim engine accumulates the same per-link utilization (and
  per-router arbitration-conflict) planes inside ``kernels.noc_cycle`` —
  epoch-bucketed with the identical ``cycle // epoch_len`` index, jnp and
  Pallas bit-identical — surfaced through ``XSimResults.link_utilization``
  / ``router_conflicts``. Per-link flit totals are conserved events: they
  match the host counters exactly whenever delivery sets match.
* ``calibrate_cost_model`` — the closed loop the analytic cost models
  can't provide: run xsim, fit per-link contention weights (and measured
  ``EnergyCost`` constants) from the telemetry planes, re-register the
  calibrated model, replan, iterate to a fixed point.

Directed-link ids use the engines' shared convention
``idx(u) * ports + direction(u -> v)`` — the direction order and port count
come from the topology (4 on the 2-D kinds with (+x, -x, +y, -y), 6 on the
3-D ones with (+z, -z) appended); ``link_index``/``link_coords`` convert
both ways.
"""
from __future__ import annotations

import numpy as np

from ..core.grid import Coord, MeshGrid

LATENCY_BINS = 21  # log2 buckets: [1,2), [2,4), ... [2^19, 2^20), overflow


def link_index(g: MeshGrid, u: Coord, v: Coord) -> int:
    """Directed-link id of u -> v: ``idx(u) * ports + direction``.

    Shared with the xsim compiler and the fused-cycle geometry tables, so
    host telemetry rows and device utilization planes index identically.
    Torus wrap hops resolve through ``Topology.delta``'s signed shortest
    step, like every other consumer of the convention; non-links (including
    undeclared chiplet-boundary crossings) raise ValueError.
    """
    return g.idx(u) * getattr(g, "ports", 4) + g.direction(u, v)


def link_coords(g: MeshGrid, link_id: int) -> tuple[Coord, Coord]:
    """Inverse of ``link_index`` (canonical coordinates on a torus)."""
    node, d = divmod(int(link_id), getattr(g, "ports", 4))
    u = g.from_idx(node)
    dd = g.dir_delta(d)
    return u, g.normalize(*(c + e for c, e in zip(u, dd)))


class LatencyHistogram:
    """Per-packet latency histogram over log2 buckets.

    Bucket ``i`` holds latencies in ``[2**i, 2**(i+1))``; the last bucket
    absorbs overflow. Latencies below 1 clamp into bucket 0 (a delivery
    takes at least one cycle in both engines, so the clamp is defensive).
    """

    def __init__(self) -> None:
        self.counts = np.zeros(LATENCY_BINS, np.int64)

    def add(self, latency: int) -> None:
        self.counts[min(max(int(latency), 1).bit_length() - 1,
                        LATENCY_BINS - 1)] += 1

    @classmethod
    def from_latencies(cls, latencies) -> "LatencyHistogram":
        h = cls()
        for lat in latencies:
            h.add(lat)
        return h

    @property
    def total(self) -> int:
        return int(self.counts.sum())

    def quantile(self, q: float) -> int:
        """Upper edge of the bucket holding the q-quantile (0 if empty)."""
        if not 0 <= q <= 1:
            raise ValueError(f"quantile must be in [0, 1] (got {q})")
        total = self.total
        if total == 0:
            return 0
        cum = np.cumsum(self.counts)
        return 2 ** (int(np.searchsorted(cum, q * total)) + 1)

    def to_dict(self) -> dict:
        return {"bins_log2": self.counts.tolist(), "total": self.total}


class Telemetry:
    """Per-link / per-VC event counters + epoch time series (host engine).

    All arrays index directed links by ``link_index``. ``epoch_len`` sets
    the time-bucket width; epoch rows grow on demand (a drained run is a
    handful of rows, never the dense cycle axis).
    """

    def __init__(self, num_nodes: int, vcs_per_class: int,
                 epoch_len: int = 128, ports: int = 4) -> None:
        if epoch_len < 1:
            raise ValueError(f"epoch_len must be >= 1 (got {epoch_len})")
        self.num_nodes = num_nodes
        self.ports = ports
        self.num_links = num_nodes * ports
        self.vcs = 2 * vcs_per_class
        self.vcs_per_class = vcs_per_class
        self.epoch_len = epoch_len
        L, W = self.num_links, self.vcs
        self.link_flits = np.zeros(L, np.int64)  # flit traversals per link
        self.vc_class_flits = np.zeros((L, 2), np.int64)  # HIGH(0) / LOW(1)
        self.occupancy_hwm = np.zeros((L, W), np.int32)  # per-(link, VC)
        self.link_conflicts = np.zeros(L, np.int64)  # losing arbitration reqs
        self.credit_stalls = np.zeros(L, np.int64)  # admissions blocked on
        #                                             credit / free-VC
        self.latency_hist = LatencyHistogram()
        self._epoch_link: list[np.ndarray] = []  # per-epoch (L,) flit counts
        self._epoch_lat: list[list[int]] = []  # per-epoch [count, sum]

    # ------------------------------------------------------------- recording
    def _epoch(self, cycle: int) -> int:
        e = cycle // self.epoch_len
        while len(self._epoch_link) <= e:
            self._epoch_link.append(np.zeros(self.num_links, np.int64))
            self._epoch_lat.append([0, 0])
        return e

    def flit(self, link_id: int, vcls: int, cycle: int) -> None:
        self.link_flits[link_id] += 1
        self.vc_class_flits[link_id, vcls] += 1
        self._epoch_link[self._epoch(cycle)][link_id] += 1

    def occupancy(self, link_id: int, vc: int, depth: int) -> None:
        if depth > self.occupancy_hwm[link_id, vc]:
            self.occupancy_hwm[link_id, vc] = depth

    def conflicts(self, link_id: int, losers: int) -> None:
        self.link_conflicts[link_id] += losers

    def stall(self, link_id: int) -> None:
        self.credit_stalls[link_id] += 1

    def latency(self, lat: int, cycle: int) -> None:
        self.latency_hist.add(lat)
        row = self._epoch_lat[self._epoch(cycle)]
        row[0] += 1
        row[1] += lat

    # --------------------------------------------------------------- reading
    @property
    def num_epochs(self) -> int:
        return len(self._epoch_link)

    def epoch_link_flits(self) -> np.ndarray:
        """(E, L) per-epoch per-link flit traversals (E = epochs touched)."""
        if not self._epoch_link:
            return np.zeros((0, self.num_links), np.int64)
        return np.stack(self._epoch_link)

    def epoch_series(self) -> list[dict]:
        """Per-epoch aggregate rows for timeline rendering."""
        out = []
        for e, (lnk, (cnt, tot)) in enumerate(
            zip(self._epoch_link, self._epoch_lat)
        ):
            out.append({
                "epoch": e,
                "cycle_start": e * self.epoch_len,
                "flits": int(lnk.sum()),
                "deliveries": cnt,
                "avg_latency": round(tot / cnt, 3) if cnt else None,
            })
        return out

    def router_conflicts(self) -> np.ndarray:
        """(NN,) conflicts per router (a link arbitrates at its source)."""
        return self.link_conflicts.reshape(self.num_nodes, self.ports).sum(axis=1)

    def heatmap(self, g: MeshGrid) -> np.ndarray:
        """(rows, n, ports) per-node outgoing-link flit counts for rendering."""
        return self.link_flits.reshape(g.rows, g.n, self.ports).copy()

    def to_dict(self) -> dict:
        """JSON-ready snapshot (timeline artifacts, benchmark exports)."""
        return {
            "epoch_len": self.epoch_len,
            "link_flits": self.link_flits.tolist(),
            "vc_class_flits": self.vc_class_flits.tolist(),
            "occupancy_hwm_max": int(self.occupancy_hwm.max(initial=0)),
            "conflicts_total": int(self.link_conflicts.sum()),
            "credit_stalls_total": int(self.credit_stalls.sum()),
            "latency_hist": self.latency_hist.to_dict(),
            "epochs": self.epoch_series(),
        }


# ---------------------------------------------------------------------------
# Calibrated cost models (closed loop over measured telemetry)
# ---------------------------------------------------------------------------
from ..core.algo import (  # noqa: E402  (after Telemetry: no cycle — algo
    CostModel,  # imports core only)
    EnergyCost,
    get_cost_model,
    register_cost_model,
    unregister_cost_model,
)


class MeasuredContentionCost(CostModel):
    """Per-directed-link weights fitted from measured utilization.

    ``link_cost(u, v) = weights[link_index(u, v)]`` with weights
    ``1 + lam * util / max(util)`` — the empirical replacement for
    ``LinkContentionCost``'s analytic bisection argument. Weights quantize
    to ``1/QUANT`` steps, with hysteresis against ``prev`` (the previous
    calibration iterate): a link keeps its old weight unless the raw value
    moved more than ``STICK`` quanta away from it. Plans are therefore a
    *step* function of utilization with dead zones around every step edge —
    measurement movement below the dead zone cannot flip a merge decision,
    which is what lets the calibration loop reach an exact fixed point.
    Weights are tied to one fabric; pricing a different geometry raises.
    """

    name = "calibrated"
    QUANT = 8  # weight resolution: 1/8-hop steps
    STICK = 0.75  # hysteresis half-width, in quanta

    def __init__(self, g: MeshGrid, utilization: np.ndarray,
                 lam: float = 1.0,
                 prev: "MeasuredContentionCost | None" = None):
        util = np.asarray(utilization, np.float64)
        ports = getattr(g, "ports", 4)
        if util.shape != (g.num_nodes * ports,):
            raise ValueError(
                f"utilization must be ({g.num_nodes * ports},) directed-link "
                f"flit counts (got {util.shape})"
            )
        peak = float(util.max(initial=0.0))
        self.lam = float(lam)
        self.fabric = (g.kind, g.n, g.rows, getattr(g, "params", ()))
        raw = (
            1.0 + self.lam * util / peak if peak > 0
            else np.ones_like(util)
        )
        self.weights = np.round(raw * self.QUANT) / self.QUANT
        if prev is not None and prev.fabric == self.fabric:
            keep = np.abs(raw - prev.weights) < self.STICK / self.QUANT
            self.weights = np.where(keep, prev.weights, self.weights)

    def _check(self, g: MeshGrid) -> None:
        fab = (g.kind, g.n, g.rows, getattr(g, "params", ()))
        if fab != self.fabric:
            raise ValueError(
                f"cost model calibrated for {self.fabric} cannot price {fab}"
            )

    def link_cost(self, g: MeshGrid, u: Coord, v: Coord) -> float:
        self._check(g)
        return float(self.weights[link_index(g, u, v)])


class MeasuredEnergyCost(EnergyCost):
    """EnergyCost with per-hop / per-worm constants fitted from counters.

    The analytic model assumes every worm-hop performs exactly F buffer
    writes/reads/crossbar/link events plus one arbitration; measured runs
    differ (ejection reads, lost arbitrations, relay re-injections).
    ``fit_energy_cost`` computes the measured pJ-per-worm-hop and
    pJ-per-worm from a run's event counters and builds this model.
    """

    name = "energy-calibrated"

    def __init__(self, per_hop_pj: float, per_packet_pj: float,
                 energy, flits_per_packet: int):
        # bypass EnergyCost.__init__'s analytic derivation: the measured
        # constants ARE the model
        self.energy = energy
        self.flits_per_packet = flits_per_packet
        self._per_hop = float(per_hop_pj)
        self._per_packet = float(per_packet_pj)


def fit_energy_cost(counters, energy, flits_per_packet: int,
                    ) -> MeasuredEnergyCost:
    """Fit EnergyCost constants from measured event counters.

    ``counters`` maps the SimStats counter names (``flit_link_traversals``,
    ``buffer_writes``, ``buffer_reads``, ``xbar_traversals``,
    ``arbitrations``, ``ni_flits``, ``packets_finished``) to totals — a
    ``SimStats``, an xsim ``ctr`` row dict, or any mapping-like object.
    """
    get = (
        counters.get if hasattr(counters, "get")
        else lambda k, d=0: getattr(counters, k, d)
    )
    e = energy
    hops = max(1.0, get("flit_link_traversals", 0) / flits_per_packet)
    packets = max(1, get("packets_finished", 0))
    per_hop = (
        get("buffer_writes", 0) * e.e_buffer_write
        + get("buffer_reads", 0) * e.e_buffer_read
        + get("xbar_traversals", 0) * e.e_xbar
        + get("arbitrations", 0) * e.e_arbiter
        + get("flit_link_traversals", 0) * e.e_link
    ) / hops
    per_packet = get("ni_flits", 0) * e.e_ni / packets
    return MeasuredEnergyCost(per_hop, per_packet, e, flits_per_packet)


# ---------------------------------------------------------------------------
# The calibration loop
# ---------------------------------------------------------------------------
def _plan_signature(topo, workload, algo, cost_model):
    """Hashable route set of every request's plan under one model."""
    from ..core.planner import plan

    out = []
    for r in workload.requests:
        p = plan(algo, topo, r.src, r.dests, cost_model=cost_model)
        out.append(tuple(tuple(path.hops) for path in p.paths))
    return tuple(out)


def _register_as(name: str, model: CostModel) -> CostModel:
    """(Re-)register ``model`` under ``name``, flushing name-keyed caches.

    ``unregister_cost_model`` fires the registry invalidation hooks, so a
    re-registration can never serve plans cached under the previous
    iterate's weights (the PR 4 aliasing contract).
    """
    unregister_cost_model(name)
    register_cost_model(model, name=name)
    return get_cost_model(name)


class CalibrationResult:
    """Outcome of one ``calibrate_cost_model`` loop."""

    def __init__(self, name: str, model: CostModel,
                 energy: MeasuredEnergyCost, iterations: list[dict],
                 best_iter: int, converged: bool):
        self.name = name
        self.model = model  # the registered instance `name` resolves to
        self.energy = energy
        self.iterations = iterations  # [0] is the uncalibrated baseline
        self.best_iter = best_iter
        self.converged = converged

    @property
    def baseline_latency(self) -> float:
        return self.iterations[0]["avg_latency"]

    @property
    def calibrated_latency(self) -> float:
        return self.iterations[self.best_iter]["avg_latency"]

    @property
    def plans_changed(self) -> int:
        """Requests whose routes differ, calibrated vs baseline."""
        return self.iterations[self.best_iter]["plans_changed_vs_baseline"]

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "converged": self.converged,
            "best_iter": self.best_iter,
            "baseline_latency": self.baseline_latency,
            "calibrated_latency": self.calibrated_latency,
            "plans_changed": self.plans_changed,
            "iterations": [
                {k: v for k, v in it.items() if k != "signature"}
                for it in self.iterations
            ],
        }


def calibrate_cost_model(
    cfg,
    workload,
    algo: str = "DPM",
    *,
    name: str = "calibrated",
    base_cost_model=None,
    lam: float = 1.0,
    max_iters: int = 6,
    damping: float = 0.5,
    backend: str | None = None,
) -> CalibrationResult:
    """Close the loop: measure -> fit -> re-register -> replan -> repeat.

    Iteration 0 runs xsim under ``base_cost_model`` (default: the
    algorithm's own objective) and records measured per-link utilization.
    Each following iteration fits ``MeasuredContentionCost`` weights from
    the utilization measured so far, registers it under ``name`` (flushing
    the plan cache), replans the whole workload, and re-measures. The loop
    stops at a *fixed point* — an iteration whose plans equal the previous
    iteration's; the runs are deterministic, so equal plans reproduce the
    exact utilization (and weights) that produced them — or after
    ``max_iters``.

    Raw replanning oscillates (moving load off a hot link makes the old
    route look attractive again next round), so the fitted utilization
    damps the measurements with a geometrically decaying step: ``u <- u +
    step * (measured - u)`` with ``step = damping ** i``. Oscillation
    between route sets is bounded, so per-round movement of ``u`` shrinks
    geometrically; once it drops below ``MeasuredContentionCost``'s
    hysteresis dead band the quantized weights — and therefore the plans —
    stop changing *exactly*, which is the fixed point the stop rule
    detects (in O(log(1/band)) iterations even with hundreds of plans).

    The registered model is the best iterate by measured average latency;
    when no calibrated iterate beats the baseline, uniform weights are
    registered instead (identical costs to hop counting, hence identical
    plans and latency to a hop-objective baseline) — calibration never
    regresses the calibration scenario. ``result.energy`` carries
    measured ``EnergyCost`` constants fitted from the same run's event
    counters (``fit_energy_cost``).
    """
    from .xsim import xsimulate

    topo = cfg.make_topology()

    def run(cost_model):
        res = xsimulate(
            cfg, [workload], (algo,), cost_model=cost_model, backend=backend
        )
        util = res.link_utilization(0, 0)
        return {
            "avg_latency": float(res.avg_latency(0, 0)),
            "util": util,
            "max_link_flits": int(util.max(initial=0)),
            "ctr": dict(zip(
                ("flit_link_traversals", "buffer_writes", "buffer_reads",
                 "xbar_traversals", "arbitrations", "ni_flits",
                 "packets_finished", "slots_hwm"),
                res.ctr[0].tolist(),
            )),
        }

    base = run(base_cost_model)
    base_sig = _plan_signature(topo, workload, algo, base_cost_model)
    iterations = [{
        "iter": 0, "model": "baseline",
        "avg_latency": base["avg_latency"],
        "max_link_flits": base["max_link_flits"],
        "plans_changed_vs_baseline": 0,
        "plans_changed_vs_prev": 0,
        "signature": base_sig,
    }]
    models: list[MeasuredContentionCost | None] = [None]
    util = base["util"].astype(np.float64)
    converged = False
    last_ctr = base["ctr"]
    for i in range(1, max_iters + 1):
        model = MeasuredContentionCost(topo, util, lam=lam, prev=models[-1])
        registered = _register_as(name, model)
        sig = _plan_signature(topo, workload, algo, registered)
        prev = iterations[-1]
        changed_prev = sum(
            1 for a, b in zip(sig, prev["signature"]) if a != b
        )
        meas = run(registered)
        iterations.append({
            "iter": i, "model": name,
            "avg_latency": meas["avg_latency"],
            "max_link_flits": meas["max_link_flits"],
            "plans_changed_vs_baseline": sum(
                1 for a, b in zip(sig, base_sig) if a != b
            ),
            "plans_changed_vs_prev": changed_prev,
            "signature": sig,
        })
        models.append(model)
        step = damping ** i
        util = util + step * (meas["util"] - util)
        last_ctr = meas["ctr"]
        if changed_prev == 0:
            converged = True  # weights reproduce the plans that made them
            break

    best = min(
        range(1, len(iterations)),
        key=lambda i: iterations[i]["avg_latency"],
    )
    if iterations[best]["avg_latency"] > iterations[0]["avg_latency"]:
        # fall back to uniform weights: cost-equal to hop counting, so a
        # hop-objective baseline's plans (and latency) are reproduced
        best = 0
        model = MeasuredContentionCost(
            topo, np.zeros(topo.num_nodes * getattr(topo, "ports", 4)),
            lam=lam,
        )
    else:
        model = models[best]
    registered = _register_as(name, model)
    energy = fit_energy_cost(last_ctr, cfg.energy, cfg.flits_per_packet)
    return CalibrationResult(
        name=name, model=registered, energy=energy, iterations=iterations,
        best_iter=best, converged=converged,
    )
