"""Workload generators + simulation runner for the NoC benchmarks.

Synthetic traffic reproduces the paper's setup: uniform-random sources and
destinations, Bernoulli injection per node per cycle, 10 % of packets are
multicast with a destination-set size drawn uniformly from the configured
range. PARSEC-like traces are synthesized per-benchmark (Netrace is not
available offline — see DESIGN.md §2): each benchmark keys a (relative load,
multicast %, destination-size distribution, burstiness) tuple chosen to match
the published workload characteristics.
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass, field

from ..core.grid import Coord
from ..core.topology import make_topology
from .config import NoCConfig
from .simulator import SimStats, WormholeSim


@dataclass
class Request:
    time: int
    src: Coord
    dests: list[Coord]
    # per-packet worm length; None = cfg.flits_per_packet. Trace replays
    # (noc.trace) carry heterogeneous payloads; synthetic traffic leaves it
    # unset, so existing workloads stay bit-identical.
    flits: int | None = None


@dataclass
class Workload:
    name: str
    requests: list[Request]
    horizon: int  # last injection cycle


def synthetic_workload(
    cfg: NoCConfig,
    injection_rate: float,  # packets / node / cycle
    cycles: int,
    seed: int = 0,
    multicast_fraction: float | None = None,
    dest_range: tuple[int, int] | None = None,
) -> Workload:
    mc = cfg.multicast_fraction if multicast_fraction is None else multicast_fraction
    lo, hi = cfg.dest_range if dest_range is None else dest_range
    rng = random.Random(seed)
    g = make_topology(cfg.topology, cfg.n, cfg.m, params=cfg.topology_params)
    nodes = g.nodes()  # idx order == the legacy 2-D row-major enumeration
    reqs: list[Request] = []
    for t in range(cycles):
        for src in nodes:
            if rng.random() >= injection_rate:
                continue
            if rng.random() < mc:
                k = rng.randint(lo, hi)
                dests = rng.sample([d for d in nodes if d != src], k)
            else:
                dests = [rng.choice([d for d in nodes if d != src])]
            reqs.append(Request(t, src, dests))
    return Workload(f"uniform-{injection_rate:.4f}", reqs, cycles)


# ---------------------------------------------------------------------------
# PARSEC-like synthesized traces.
# Tuples: (rel_load, multicast_pct, dest_size_range, burst_on_prob, burst_len)
# chosen to match the published characteristics of each workload's coherence
# traffic (multicast % within 5-15 % per [4]; fluidanimate is the most
# multicast-heavy, canneal the most memory-bound / bursty).
# ---------------------------------------------------------------------------
PARSEC_PROFILES: dict[str, tuple[float, float, tuple[int, int], float, int]] = {
    "blackscholes": (0.30, 0.05, (2, 4), 0.05, 8),
    "bodytrack": (0.45, 0.07, (2, 6), 0.10, 10),
    "canneal": (0.70, 0.08, (2, 8), 0.25, 16),
    "dedup": (0.50, 0.06, (2, 6), 0.15, 12),
    "ferret": (0.55, 0.08, (3, 8), 0.15, 12),
    "fluidanimate": (0.60, 0.15, (6, 16), 0.20, 14),
    "freqmine": (0.40, 0.06, (2, 5), 0.10, 8),
    "swaptions": (0.35, 0.05, (2, 4), 0.05, 6),
    "vips": (0.50, 0.09, (3, 8), 0.12, 10),
    "x264": (0.55, 0.10, (4, 10), 0.18, 12),
}


def parsec_workload(
    cfg: NoCConfig,
    benchmark: str,
    cycles: int,
    base_rate: float = 0.05,
    seed: int = 0,
) -> Workload:
    rel_load, mc, dr, burst_p, burst_len = PARSEC_PROFILES[benchmark]
    # stable digest, NOT hash(): str hashing is salted per process
    # (PYTHONHASHSEED), which made fig8 traces irreproducible across runs.
    rng = random.Random(seed ^ zlib.crc32(benchmark.encode()) & 0xFFFF)
    g = make_topology(cfg.topology, cfg.n, cfg.m, params=cfg.topology_params)
    nodes = g.nodes()  # idx order == the legacy 2-D row-major enumeration
    rate = base_rate * rel_load
    reqs: list[Request] = []
    burst_remaining = {n: 0 for n in nodes}
    for t in range(cycles):
        for src in nodes:
            if burst_remaining[src] > 0:
                burst_remaining[src] -= 1
                eff = min(1.0, rate * 6.0)  # ON phase
            else:
                if rng.random() < burst_p * rate:
                    burst_remaining[src] = burst_len
                eff = rate
            if rng.random() >= eff:
                continue
            if rng.random() < mc:
                k = rng.randint(*dr)
                dests = rng.sample([d for d in nodes if d != src], k)
            else:
                dests = [rng.choice([d for d in nodes if d != src])]
            reqs.append(Request(t, src, dests))
    return Workload(benchmark, reqs, cycles)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
def simulate(
    cfg: NoCConfig,
    workload: Workload,
    algo: str,
    warmup: int | None = None,
    drain_grace: int | None = None,
    cost_model=None,
) -> SimStats:
    """Run one workload under one algorithm; measure post-warmup packets.

    ``algo`` is any registered routing algorithm (``repro.core.algo``);
    ``cost_model`` optionally overrides the objective cost-sensitive
    algorithms plan under. ``warmup``/``drain_grace`` default from ``cfg`` —
    NoCConfig is the single source of truth for the measurement window
    shared with ``noc.xsim``.
    """
    warmup = cfg.warmup if warmup is None else warmup
    drain_grace = cfg.drain_grace if drain_grace is None else drain_grace
    sim = WormholeSim(cfg, measure_window=(warmup, workload.horizon))
    for r in workload.requests:
        sim.add_request(
            algo, r.src, r.dests, r.time, cost_model=cost_model, flits=r.flits
        )
    sim.run(workload.horizon + drain_grace, drain=True)
    return sim.stats


def latency_vs_rate(
    cfg: NoCConfig,
    rates: list[float],
    algo: str,
    cycles: int = 1500,
    seed: int = 0,
    saturation_cap: float = 400.0,
) -> list[tuple[float, float]]:
    """Average latency per injection rate; stops once saturated (latency cap)."""
    out = []
    for rate in rates:
        wl = synthetic_workload(cfg, rate, cycles, seed=seed)
        st = simulate(cfg, wl, algo)
        lat = st.avg_latency
        out.append((rate, lat))
        if lat > saturation_cap:
            break
    return out
