"""Serving: batched prefill + decode with greedy/temperature sampling, and a
queue-based batch server (deliverable b's serving example uses this)."""
from __future__ import annotations

import queue
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, RunConfig
from ..models.model import decode_step, prefill


@dataclass
class GenResult:
    tokens: np.ndarray  # (B, steps)
    prefill_ms: float
    decode_ms_per_token: float


def generate(
    params,
    cfg: ArchConfig,
    run: RunConfig,
    prompts: jax.Array,  # (B, S) int32 (or frames (B, S, d))
    steps: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> GenResult:
    B = prompts.shape[0]
    S = prompts.shape[1]
    key_name = "tokens" if cfg.embed_input == "tokens" else "frames"

    pf = jax.jit(
        lambda p, b: prefill(p, b, cfg, run, cache_len=S + steps),
        static_argnames=(),
    )
    dec = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg, run))

    t0 = time.monotonic()
    logits, caches = pf(params, {key_name: prompts})
    logits.block_until_ready()
    prefill_ms = (time.monotonic() - t0) * 1e3

    out = np.zeros((B, steps), np.int32)
    key = jax.random.PRNGKey(seed)
    t1 = time.monotonic()
    tok = None
    for t in range(steps):
        lg = logits[:, -1, : cfg.vocab]
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            tok = jnp.argmax(lg, axis=-1)
        out[:, t] = np.asarray(tok)
        if t == steps - 1:
            break
        batch = {"pos": jnp.int32(S + t)}
        if cfg.embed_input == "tokens":
            batch["tokens"] = tok[:, None].astype(jnp.int32)
        else:  # frame models feed back an embedding stub
            batch["frames"] = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
        logits, caches = dec(params, caches, batch)
    decode_ms = (time.monotonic() - t1) * 1e3 / max(1, steps - 1)
    return GenResult(out, prefill_ms, decode_ms)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_tokens: int
    submitted: float = field(default_factory=time.monotonic)


@dataclass
class Response:
    rid: int
    tokens: np.ndarray
    latency_s: float


class BatchServer:
    """Collect requests into fixed-size batches (pad to the longest prompt),
    run generate(), return per-request responses. Continuous-batching-lite:
    a new batch is admitted as soon as the previous one retires."""

    def __init__(self, params, cfg: ArchConfig, run: RunConfig,
                 max_batch: int = 8, max_wait_s: float = 0.05):
        self.params, self.cfg, self.run = params, cfg, run
        self.max_batch, self.max_wait_s = max_batch, max_wait_s
        self.queue: queue.Queue[Request] = queue.Queue()
        self.stats = {"batches": 0, "requests": 0, "tokens": 0}

    def submit(self, req: Request):
        self.queue.put(req)

    def _take_batch(self) -> list[Request]:
        reqs = [self.queue.get()]
        deadline = time.monotonic() + self.max_wait_s
        while len(reqs) < self.max_batch and time.monotonic() < deadline:
            try:
                reqs.append(self.queue.get(timeout=max(0, deadline - time.monotonic())))
            except queue.Empty:
                break
        return reqs

    def serve_once(self) -> list[Response]:
        reqs = self._take_batch()
        S = max(len(r.prompt) for r in reqs)
        steps = max(r.max_tokens for r in reqs)
        B = len(reqs)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):  # left-pad to align last token
            prompts[i, S - len(r.prompt):] = r.prompt
        res = generate(
            self.params, self.cfg, self.run, jnp.asarray(prompts), steps
        )
        now = time.monotonic()
        self.stats["batches"] += 1
        self.stats["requests"] += B
        self.stats["tokens"] += B * steps
        return [
            Response(r.rid, res.tokens[i, : r.max_tokens], now - r.submitted)
            for i, r in enumerate(reqs)
        ]
