"""Serving: batched prefill + decode with greedy/temperature sampling, and a
queue-based batch server (deliverable b's serving example uses this)."""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, RunConfig
from ..models.model import decode_step, prefill


@dataclass
class GenResult:
    tokens: np.ndarray  # (B, steps)
    prefill_ms: float
    decode_ms_per_token: float


def generate(
    params,
    cfg: ArchConfig,
    run: RunConfig,
    prompts: jax.Array,  # (B, S) int32 (or frames (B, S, d))
    steps: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> GenResult:
    B = prompts.shape[0]
    S = prompts.shape[1]
    key_name = "tokens" if cfg.embed_input == "tokens" else "frames"

    pf = jax.jit(
        lambda p, b: prefill(p, b, cfg, run, cache_len=S + steps),
        static_argnames=(),
    )
    dec = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg, run))

    t0 = time.monotonic()
    logits, caches = pf(params, {key_name: prompts})
    logits.block_until_ready()
    prefill_ms = (time.monotonic() - t0) * 1e3

    out = np.zeros((B, steps), np.int32)
    key = jax.random.PRNGKey(seed)
    t1 = time.monotonic()
    tok = None
    for t in range(steps):
        lg = logits[:, -1, : cfg.vocab]
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, lg / temperature, axis=-1)
        else:
            tok = jnp.argmax(lg, axis=-1)
        out[:, t] = np.asarray(tok)
        if t == steps - 1:
            break
        batch = {"pos": jnp.int32(S + t)}
        if cfg.embed_input == "tokens":
            batch["tokens"] = tok[:, None].astype(jnp.int32)
        else:  # frame models feed back an embedding stub
            batch["frames"] = jnp.zeros((B, 1, cfg.d_model), jnp.float32)
        logits, caches = dec(params, caches, batch)
    decode_ms = (time.monotonic() - t1) * 1e3 / max(1, steps - 1)
    return GenResult(out, prefill_ms, decode_ms)


_POLL_S = 0.05  # stop-event poll interval while blocked on an empty queue


def take_batch(q: queue.Queue, max_batch: int, max_wait_s: float,
               stop: threading.Event | None = None) -> list:
    """Deadline batching over any queue: block for the first item, then
    admit more until the batch is full or ``max_wait_s`` has elapsed since
    the first arrival.

    The shared batching primitive of ``BatchServer`` and the plan server's
    streaming driver (``serve.planserve``). With ``stop`` given, the
    blocking wait polls the event and returns ``[]`` once it fires and the
    queue is empty — the clean-shutdown path ``close()`` relies on; queued
    items are still drained into batches first.
    """
    first = None
    while first is None:
        if stop is None:
            first = q.get()
            break
        try:
            first = q.get(timeout=_POLL_S)
        except queue.Empty:
            if stop.is_set():
                return []
    out = [first]
    deadline = time.monotonic() + max_wait_s
    while len(out) < max_batch:
        left = deadline - time.monotonic()
        if left <= 0:
            break
        try:
            out.append(q.get(timeout=left))
        except queue.Empty:
            break
    return out


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_tokens: int
    submitted: float = field(default_factory=time.monotonic)


@dataclass
class Response:
    rid: int
    tokens: np.ndarray
    latency_s: float


class BatchServer:
    """Collect requests into fixed-size batches (pad to the longest prompt),
    run generate(), return per-request responses. Continuous-batching-lite:
    a new batch is admitted as soon as the previous one retires.

    ``close()`` stops admission (further ``submit`` raises) and unblocks
    any ``serve_once`` waiting on an empty queue; with ``drain=True`` it
    serves out whatever was already queued first. ``queue_depth`` reports
    the requests waiting for admission."""

    def __init__(self, params, cfg: ArchConfig, run: RunConfig,
                 max_batch: int = 8, max_wait_s: float = 0.05):
        self.params, self.cfg, self.run = params, cfg, run
        self.max_batch, self.max_wait_s = max_batch, max_wait_s
        self.queue: queue.Queue[Request] = queue.Queue()
        self.stats = {"batches": 0, "requests": 0, "tokens": 0}
        self._closed = threading.Event()

    @property
    def queue_depth(self) -> int:
        return self.queue.qsize()

    @property
    def closed(self) -> bool:
        return self._closed.is_set()

    def submit(self, req: Request):
        if self._closed.is_set():
            raise RuntimeError("BatchServer is closed")
        self.queue.put(req)

    def close(self, drain: bool = True) -> list[Response]:
        """Stop admitting requests. With ``drain`` (default), serve every
        already-queued request to completion and return those responses;
        without, queued requests are dropped."""
        self._closed.set()
        out: list[Response] = []
        if drain:
            while not self.queue.empty():
                out.extend(self.serve_once())
        else:
            while True:
                try:
                    self.queue.get_nowait()
                except queue.Empty:
                    break
        return out

    def _take_batch(self) -> list[Request]:
        return take_batch(
            self.queue, self.max_batch, self.max_wait_s, stop=self._closed
        )

    def serve_once(self) -> list[Response]:
        reqs = self._take_batch()
        if not reqs:  # closed and drained
            return []
        S = max(len(r.prompt) for r in reqs)
        steps = max(r.max_tokens for r in reqs)
        B = len(reqs)
        prompts = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):  # left-pad to align last token
            prompts[i, S - len(r.prompt):] = r.prompt
        res = generate(
            self.params, self.cfg, self.run, jnp.asarray(prompts), steps
        )
        now = time.monotonic()
        self.stats["batches"] += 1
        self.stats["requests"] += B
        self.stats["tokens"] += B * steps
        return [
            Response(r.rid, res.tokens[i, : r.max_tokens], now - r.submitted)
            for i, r in enumerate(reqs)
        ]
