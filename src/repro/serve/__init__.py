"""Serving: batched generate + queue-based batch server."""
from .engine import BatchServer, GenResult, Request, Response, generate

__all__ = ["BatchServer", "GenResult", "Request", "Response", "generate"]
