"""Serving: batched generate + queue-based batch server + the streaming
plan server over the device plan arena (``planserve``)."""
from .engine import BatchServer, GenResult, Request, Response, generate, take_batch
from .planserve import PlanServer

__all__ = [
    "BatchServer",
    "GenResult",
    "PlanServer",
    "Request",
    "Response",
    "generate",
    "take_batch",
]
