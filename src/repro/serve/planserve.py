"""Streaming plan server over the device plan arena (DESIGN.md §12).

``PlanServer`` is the serving front-end to ``core.batch_planner``: callers
``submit()`` (src, dest-set) instances and get ``Future``s back; a
background thread gathers arrivals with the same deadline batching
``BatchServer`` uses (``engine.take_batch``) and plans each batch through
the shared ``BatchPlanner`` — one jitted device dispatch per batch of arena
misses. ``prefetch()`` enqueues fire-and-forget requests so a simulation
driver can overlap the planning of its next phase with the simulation of
the current one; by the time it asks for those plans they are arena hits.

Plans returned are bit-identical to host ``plan()`` (the batched planner's
contract); fabrics or objectives outside ``batch_support`` transparently
plan on the host path, same arena, same futures.
"""
from __future__ import annotations

import queue
import threading
from concurrent.futures import Future

from ..core.batch_planner import DISPATCH_CHUNK, ArenaInfo, planner_for
from ..core.planner import MulticastPlan
from .engine import take_batch


class PlanServer:
    """Deadline-batched asynchronous planning service.

    One background thread per server; ``max_wait_s`` trades per-request
    latency for batch size exactly as in ``BatchServer``. Thread-safe:
    any number of producers may ``submit``/``prefetch`` concurrently.
    Usable as a context manager (``with PlanServer(topo) as ps: ...``) —
    exit closes with drain.
    """

    def __init__(self, topo, algo="DPM", cost_model=None, *,
                 max_batch: int = DISPATCH_CHUNK, max_wait_s: float = 0.002,
                 planner=None):
        self.planner = (
            planner if planner is not None
            else planner_for(topo, algo, cost_model)
        )
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.queue: queue.Queue[tuple] = queue.Queue()
        self.stats = {"batches": 0, "requests": 0}
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="planserve", daemon=True
        )
        self._thread.start()

    # ---------------------------------------------------------------- API
    @property
    def queue_depth(self) -> int:
        """Requests waiting for admission into a planning batch."""
        return self.queue.qsize()

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def submit(self, src, dests) -> "Future[MulticastPlan]":
        """Enqueue one instance; the Future resolves to its plan."""
        if self._stop.is_set():
            raise RuntimeError("PlanServer is closed")
        fut: "Future[MulticastPlan]" = Future()
        self.queue.put((src, dests, fut))
        return fut

    def prefetch(self, requests) -> None:
        """Fire-and-forget arena warming: enqueue ``[(src, dests), ...]``
        without futures. Later ``submit``/``plan`` calls (or direct
        ``bulk_plan`` consumers sharing the arena) hit the decoded plans."""
        if self._stop.is_set():
            raise RuntimeError("PlanServer is closed")
        for src, dests in requests:
            self.queue.put((src, dests, None))

    def plan(self, src, dests) -> MulticastPlan:
        """Synchronous convenience wrapper: submit and wait."""
        return self.submit(src, dests).result()

    def info(self) -> ArenaInfo:
        return self.planner.info()

    def close(self, drain: bool = True) -> None:
        """Shut the worker down. With ``drain`` (default) every queued
        request is still planned (pending futures resolve); without,
        pending futures are cancelled and the queue is dropped."""
        if not drain:
            while True:
                try:
                    _, _, fut = self.queue.get_nowait()
                except queue.Empty:
                    break
                if fut is not None:
                    fut.cancel()
        self._stop.set()
        self._thread.join(timeout=60.0)

    def __enter__(self) -> "PlanServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            batch = take_batch(
                self.queue, self.max_batch, self.max_wait_s, stop=self._stop
            )
            if not batch:  # stopped and drained
                return
            try:
                plans = self.planner.plan_many(
                    [(src, dests) for src, dests, _ in batch]
                )
            except Exception as e:  # propagate to every waiter, keep serving
                for _, _, fut in batch:
                    if fut is not None:
                        fut.set_exception(e)
                continue
            self.stats["batches"] += 1
            self.stats["requests"] += len(batch)
            for (_, _, fut), p in zip(batch, plans):
                if fut is not None:
                    fut.set_result(p)
