"""Jit'd SSD wrapper: Pallas intra-chunk + lax.scan inter-chunk recurrence.

Drop-in replacement for repro.models.ssm.ssd_scan (same signature subset)
selected by RunConfig.use_pallas on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import ssd_intra_chunk

MIN_LOG = -30.0


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, S, G, N)
    Cm: jax.Array,  # (B, S, G, N)
    chunk: int = 256,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = _on_cpu()
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        dt = jnp.pad(dt, [(0, 0), (0, pad), (0, 0)])
        Bm = jnp.pad(Bm, [(0, 0), (0, pad), (0, 0), (0, 0)])
        Cm = jnp.pad(Cm, [(0, 0), (0, pad), (0, 0), (0, 0)])
    Sp = S + pad
    nc = Sp // L

    # (BH, nc, L, ...) layout for the kernel; groups expanded to heads
    xk = x.transpose(0, 2, 1, 3).reshape(B_ * H, nc, L, P)
    dtk = dt.transpose(0, 2, 1).reshape(B_ * H, nc, L)
    Bh = jnp.repeat(Bm, hpg, axis=2).transpose(0, 2, 1, 3).reshape(B_ * H, nc, L, N)
    Ch = jnp.repeat(Cm, hpg, axis=2).transpose(0, 2, 1, 3).reshape(B_ * H, nc, L, N)
    Ak = jnp.broadcast_to(A[None, :], (B_, H)).reshape(B_ * H, 1)

    y_intra, sc, dec, cum = ssd_intra_chunk(
        xk, dtk, Ak, Bh, Ch, interpret=interpret
    )

    # inter-chunk recurrence over nc (sequential, small state)
    def step(h, inp):
        sc_c, dec_c = inp  # (BH, N, P), (BH,)
        h_new = h * dec_c[:, None, None] + sc_c
        return h_new, h

    h0 = jnp.zeros((B_ * H, N, P), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step, h0, (sc.transpose(1, 0, 2, 3), dec.transpose(1, 0))
    )
    h_in = h_in.transpose(1, 0, 2, 3)  # (BH, nc, N, P) state entering chunk

    inter_decay = jnp.exp(jnp.maximum(cum, MIN_LOG))  # (BH, nc, L)
    y_inter = jnp.einsum("bcln,bcnp,bcl->bclp", Ch, h_in, inter_decay)
    y = (y_intra + y_inter).reshape(B_, H, Sp, P).transpose(0, 2, 1, 3)
    if pad:
        y = y[:, :S]
    h_last = h_last.reshape(B_, H, N, P)
    return y, h_last
