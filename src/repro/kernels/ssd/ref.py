"""Pure-jnp oracle for the SSD kernel: the naive per-step recurrence."""
from repro.models.ssm import ssd_reference, ssd_scan  # noqa: F401
