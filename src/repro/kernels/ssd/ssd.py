"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

Per grid cell (batch x head x chunk) the kernel computes, over one chunk of
length L with head_dim P and state N (VMEM tiles):

    y_intra = (tril(exp(cum_i - cum_j)) * (C B^T) * dt_j) X      (L, P)
    sc      = sum_j exp(cum_L - cum_j) dt_j B_j x_j^T            (N, P)
    dec     = exp(cum_L)                                         (1, 1)

The inter-chunk recurrence (a lax.scan over sc/dec) and the final
y += C h_in exp(cum) term stay in ops.py — they are O(S N P / L) and
bandwidth-bound, while the O(S L P + S L N) intra work lives here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MIN_LOG = -30.0


def _kernel(
    x_ref,  # (1, 1, L, P)
    dt_ref,  # (1, 1, L)
    a_ref,  # (1, 1)
    b_ref,  # (1, 1, L, N)
    c_ref,  # (1, 1, L, N)
    y_ref,  # (1, 1, L, P)
    sc_ref,  # (1, 1, N, P)
    dec_ref,  # (1, 1)
    cum_ref,  # (1, 1, L)
    *,
    L: int,
):
    x = x_ref[0, 0].astype(jnp.float32)  # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (L,)
    A = a_ref[0, 0].astype(jnp.float32)  # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (L, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (L, N)

    la = dt * A  # per-step log decay, negative
    cum = jnp.cumsum(la)  # (L,)
    cb = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, L): C_i . B_j
    dmat = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.iota(jnp.int32, L)[:, None] >= jax.lax.iota(jnp.int32, L)[None, :]
    )
    m = jnp.where(tri, jnp.exp(jnp.maximum(dmat, MIN_LOG)), 0.0)
    m = m * cb * dt[None, :]
    y_ref[0, 0] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)

    tail = jnp.exp(jnp.maximum(cum[L - 1] - cum, MIN_LOG)) * dt  # (L,)
    sc_ref[0, 0] = jax.lax.dot_general(
        Bm * tail[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(sc_ref.dtype)  # (N, P)
    dec_ref[0, 0] = jnp.exp(jnp.maximum(cum[L - 1], MIN_LOG))
    cum_ref[0, 0] = cum.astype(cum_ref.dtype)


def ssd_intra_chunk(
    x: jax.Array,  # (BH, nc, L, P)
    dt: jax.Array,  # (BH, nc, L)
    A: jax.Array,  # (BH, 1) per-(batch*head) decay rate
    Bm: jax.Array,  # (BH, nc, L, N)
    Cm: jax.Array,  # (BH, nc, L, N)
    *,
    interpret: bool = False,
):
    BH, nc, L, P = x.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_kernel, L=L)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, L, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, c)),
            pl.BlockSpec((1, 1, L), lambda b, c: (b, c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, L, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, L), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
