"""Jit'd public wrapper: (B, S, H, D) layout, CPU interpret fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D) — model layout
    k: jax.Array,  # (B, S, KH, D)
    v: jax.Array,  # (B, S, KH, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _on_cpu()
    out = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)
