"""Pallas TPU flash attention (causal GQA, optional sliding window).

Tiling: grid (B, H, n_q_blocks, n_k_blocks); the last grid dim is sequential
on TPU so the online-softmax state (m, l, acc) lives in VMEM scratch and
persists across k blocks. Blocks are (block_q x head_dim) / (block_k x
head_dim) VMEM tiles; MXU work is the two (block_q, head_dim) x (head_dim,
block_k) / (block_q, block_k) x (block_k, head_dim) dots in fp32.

Causal block skipping: k blocks strictly above the diagonal are skipped with
pl.when (no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    m_scr,  # (bq,) f32
    l_scr,  # (bq,) f32
    acc_scr,  # (bq, D) f32
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int | None,
    q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + iq * block_q + jax.lax.iota(jnp.int32, block_q)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)

    # block-level skip: fully-masked k blocks issue no compute
    first_q = q_offset + iq * block_q
    last_q = first_q + block_q - 1
    first_k = ik * block_k
    live = first_k < seq_k
    if causal:
        live &= first_k <= last_q
    if window is not None:
        live &= (ik * block_k + block_k - 1) > first_q - window

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        mask = (k_pos[None, :] < seq_k) & jnp.ones((block_q, 1), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KH, Sk, D)
    v: jax.Array,  # (B, KH, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    pad_q, pad_k = (-Sq) % bq, (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, pad_q), (0, 0)])
    if pad_k:
        k = jnp.pad(k, [(0, 0), (0, 0), (0, pad_k), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, pad_k), (0, 0)])
    nq, nk = (Sq + pad_q) // bq, (Sk + pad_k) // bk

    kernel = functools.partial(
        _kernel,
        scale=D**-0.5,
        block_q=bq,
        block_k=bk,
        seq_q=Sq,
        seq_k=Sk,
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pl.MemorySpace.ANY if False else pltpu_scratch((bq,)),
            pltpu_scratch((bq,)),
            pltpu_scratch((bq, D)),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out


def pltpu_scratch(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
