"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KH, Sk, D)
    v: jax.Array,  # (B, KH, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    kk = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk) * D**-0.5
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)
