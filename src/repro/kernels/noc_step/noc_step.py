"""Pallas TPU kernel: segmented-min arbitration for the xsim NoC stepper.

One simulated cycle of ``repro.noc.xsim`` resolves two resource-arbitration
rounds (per-directed-link flit grants, per-node ejection grants). Both reduce
to the same primitive: given a flat vector of candidate *age keys* and the
resource id each candidate contends for, find the minimum key per resource —
the winner is then the candidate whose key equals its resource's minimum
(keys are unique by construction: (enqueue_cycle, packet, flit)).

This file holds the Pallas implementation of that primitive. The grid is
2-D: ``(resource tiles, candidate tiles)``; each program compares its
candidate tile's segment ids against its resource tile's ids (broadcasted
iota) and min-accumulates into the output block, which is revisited across
the candidate dimension (j == 0 initializes). Integer/VPU work only — the
(RT, CT) compare/select tile is the whole kernel.

``ref.py`` is the jnp oracle (``jax.ops.segment_min``); ``ops.py`` picks the
backend and derives winner masks. Parity is pinned by tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Large sentinel: above every real key, far from int32 overflow when compared.
# A plain Python int so kernels can close over it without a captured constant.
NOC_INF = 2**30


def _kernel(keys_ref, segs_ref, out_ref, *, rt: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NOC_INF)

    keys = keys_ref[0, :]  # (CT,)
    segs = segs_ref[0, :]
    ct = keys.shape[0]
    # resource ids covered by this output tile, one per sublane row
    res = i * rt + jax.lax.broadcasted_iota(jnp.int32, (rt, ct), 0)
    hit = jnp.where(segs[None, :] == res, keys[None, :], NOC_INF)  # (RT, CT)
    out_ref[0, :] = jnp.minimum(out_ref[0, :], jnp.min(hit, axis=1))


def segmented_min(
    keys: jax.Array,  # (N,) int32 candidate age keys (NOC_INF = no candidate)
    segs: jax.Array,  # (N,) int32 resource id per candidate in [0, num_segments)
    num_segments: int,
    *,
    res_tile: int = 128,
    cand_tile: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Per-resource minimum key, shape ``(num_segments,)`` int32.

    Resources with no candidate hold ``NOC_INF``. Out-of-range segment ids
    must carry ``NOC_INF`` keys (the padding convention of the stepper).
    """
    (N,) = keys.shape
    rpad = (-num_segments) % res_tile
    cpad = (-N) % cand_tile
    keys = jnp.pad(keys, (0, cpad), constant_values=NOC_INF)
    segs = jnp.pad(segs, (0, cpad), constant_values=-1)
    Rp, Np = num_segments + rpad, N + cpad
    kernel = functools.partial(_kernel, rt=res_tile)
    out = pl.pallas_call(
        kernel,
        grid=(Rp // res_tile, Np // cand_tile),
        in_specs=[
            pl.BlockSpec((1, cand_tile), lambda i, j: (0, j)),
            pl.BlockSpec((1, cand_tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, res_tile), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Rp), jnp.int32),
        interpret=interpret,
    )(keys.reshape(1, Np).astype(jnp.int32), segs.reshape(1, Np).astype(jnp.int32))
    return out[0, :num_segments]
