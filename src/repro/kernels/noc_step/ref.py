"""Pure-jnp oracle for the noc_step segmented-min kernel (same contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .noc_step import NOC_INF


def segmented_min_ref(
    keys: jax.Array, segs: jax.Array, num_segments: int
) -> jax.Array:
    """Per-resource minimum key via scatter-min; NOC_INF where no candidate.

    Out-of-range segment ids (the stepper's padding) are clamped to segment 0
    — harmless because the padding convention gives them NOC_INF keys.
    """
    segs = jnp.clip(segs, 0, num_segments - 1)
    out = jax.ops.segment_min(
        keys, segs, num_segments=num_segments, indices_are_sorted=False
    )
    # segment_min's identity for empty segments is iinfo.max; normalize to the
    # kernel's NOC_INF so both backends are bit-identical.
    return jnp.minimum(out, NOC_INF).astype(jnp.int32)
