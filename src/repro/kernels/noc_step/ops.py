"""Backend dispatch + winner derivation for xsim's arbitration rounds.

``arbitrate`` turns a (mask, key, resource-id) candidate set into the winner
mask of one arbitration round: per resource, the admissible candidate with
the smallest age key wins (keys are unique, so at most one winner per
resource). The segmented-min reduction runs either through the Pallas kernel
(``noc_step.py`` — TPU, or interpret mode for validation) or the jnp oracle
(``ref.py`` — the default on CPU, where it lowers to a native scatter-min).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .noc_step import NOC_INF, segmented_min
from .ref import segmented_min_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def resolve_backend(backend: str | None) -> str:
    """``None``/"auto" -> "ref" on CPU, "pallas" on TPU/GPU."""
    if backend in (None, "auto"):
        return "ref" if _on_cpu() else "pallas"
    if backend not in ("ref", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown noc_step backend: {backend!r}")
    return backend


# Below this many (candidate x segment) cells the broadcast-compare min-
# reduction beats XLA:CPU's serialized scatter-min (measured ~2.5x on the
# ejection round); above it the scatter wins on memory traffic.
_DENSE_CELLS = 65536


def segmin(
    keys: jax.Array,  # (...,) int32; NOC_INF = no candidate
    segs: jax.Array,  # (...,) int32 resource ids in [0, num_segments)
    num_segments: int,
    backend: str = "ref",
) -> jax.Array:
    """Per-resource minimum key, (num_segments,); NOC_INF where empty."""
    flat_k = keys.reshape(-1).astype(jnp.int32)
    flat_s = segs.reshape(-1).astype(jnp.int32)
    if backend == "ref":
        if flat_k.shape[0] * num_segments <= _DENSE_CELLS:
            hit = flat_s[:, None] == jnp.arange(num_segments)[None, :]
            return jnp.min(
                jnp.where(hit, flat_k[:, None], NOC_INF), axis=0
            ).astype(jnp.int32)
        return segmented_min_ref(flat_k, flat_s, num_segments)
    return segmented_min(
        flat_k, flat_s, num_segments,
        interpret=(backend == "pallas_interpret"),
    )


def arbitrate(
    adm: jax.Array,  # (...,) bool — admissible candidates
    keys: jax.Array,  # (...,) int32 age keys, unique among admissible
    segs: jax.Array,  # (...,) int32 resource ids in [0, num_segments)
    num_segments: int,
    backend: str = "ref",
) -> jax.Array:
    """Winner mask, same shape as ``adm`` (one winner max per resource)."""
    mkeys = jnp.where(adm, keys, NOC_INF).astype(jnp.int32)
    seg_min = segmin(mkeys, segs, num_segments, backend=backend)
    won = mkeys == seg_min[jnp.clip(segs, 0, num_segments - 1)]
    return adm & won & (mkeys < NOC_INF)
