"""Fused Pallas wormhole-cycle kernel: a chunk of cycles in one launch.

One ``pallas_call`` advances the simulator ``Tc`` cycles: every state plane
is loaded from its ref once, carried through an in-kernel ``fori_loop`` as
VMEM-resident values (never round-tripping per cycle), and stored back once
at the chunk boundary. The loop body is ``ref.cycle_core`` — the exact jnp
function the reference backend scans — so the two paths are bit-identical
by construction; this file only adds the ref plumbing and the packed
arrival-event log.

Delivery times are the one non-dense update in the engine, so they stay
out of the kernel: each cycle writes one packed int32 row ``ev[t, link] =
1 + (pid * S + stage) * 4 + is_tail * 2 + is_header`` (0 = no arrival; at
most one flit arrives per directed link per cycle), and the host-side
wrapper in ``ops.py`` turns the chunk's log into ``dtime`` scatters between
kernel launches.

The static router geometry (``node_ports`` and friends) and the compiled-
traffic tables are explicit kernel operands (``pallas_call`` kernels may
not capture array constants), so the whole runner stays vmap/pmap-able
over the sweep batch axis. On CPU the kernel runs under ``interpret=True``
(the validation path CI exercises); on TPU/GPU it compiles via Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CTR, TABLE_FIELDS, CycleState, cycle_core

_NPLANES = len(CycleState._fields)
_GEOM_FIELDS = ("node_ports", "cand_node", "cand_port")


def make_chunk_runner(geom: dict, *, F: int, V: int, BD: int, L: int,
                      NN: int, S: int, Tc: int, interpret: bool,
                      EPL: int = 1 << 30):
    """Build ``run(planes, tb, t0) -> (planes', ev[Tc, L])`` for one chunk
    length. ``t0`` is the absolute cycle of the chunk's first iteration."""
    params = dict(F=F, V=V, BD=BD, L=L, NN=NN, EPL=EPL)

    n_in = _NPLANES + len(TABLE_FIELDS) + len(_GEOM_FIELDS) + 1

    def kernel(*refs):
        plane_refs = refs[:_NPLANES]
        table_refs = refs[_NPLANES:_NPLANES + len(TABLE_FIELDS)]
        geom_refs = refs[_NPLANES + len(TABLE_FIELDS):n_in - 1]
        t0_ref = refs[n_in - 1]
        out_refs = refs[n_in:-1]
        ev_ref = refs[-1]
        tb = {f: r[...] for f, r in zip(TABLE_FIELDS, table_refs)}
        gm = {f: r[...] for f, r in zip(_GEOM_FIELDS, geom_refs)}
        planes = [r[...] for r in plane_refs]
        planes[-2] = planes[-2][0]  # inflight rides as (1,) around the call
        state = CycleState(*planes)
        t0 = t0_ref[0]

        def body(i, st):
            st, (aval, apid, astage, afid) = cycle_core(
                st, tb, t0 + i, gm, **params
            )
            # tail bit is per-packet: a trace worm may be shorter/longer
            # than the config default (heterogeneous payloads)
            nf = tb["flits"][jnp.clip(apid, 0, tb["flits"].shape[0] - 1)]
            ev = jnp.where(
                aval,
                1 + ((apid * S + astage) * 4
                     + (afid == nf - 1).astype(jnp.int32) * 2
                     + (afid == 0).astype(jnp.int32)),
                0,
            )
            ev_ref[pl.dslice(i, 1), :] = ev[None, :]
            return st

        out = jax.lax.fori_loop(0, Tc, body, state)
        for r, v in zip(out_refs, out):
            r[...] = v if v.ndim else v[None]

    def run(planes: CycleState, tb: dict, t0) -> tuple[CycleState, jax.Array]:
        flat = [
            p if p.ndim else p[None]  # scalar inflight -> (1,)
            for p in planes
        ]
        tables = [tb[f] for f in TABLE_FIELDS]
        gtabs = [jnp.asarray(geom[f]) for f in _GEOM_FIELDS]
        t0a = jnp.asarray(t0, jnp.int32)[None]
        out_shape = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat]
        out_shape.append(jax.ShapeDtypeStruct((Tc, L), jnp.int32))
        outs = pl.pallas_call(
            kernel,
            out_shape=out_shape,
            interpret=interpret,
        )(*flat, *tables, *gtabs, t0a)
        ev = outs[-1]
        new = list(outs[:-1])
        new[-2] = new[-2][0]  # (1,) -> scalar inflight
        return CycleState(*new), ev

    return run
