"""Backend dispatch for the fused wormhole cycle.

``run_cycles`` advances the packed-plane engine ``T`` cycles and returns
the simulation outputs (``dtime``, counters, released-children mask):

* ``ref`` — one ``lax.scan`` of ``ref.cycle_core`` with the (L,)-sized
  delivery scatter inline. The CPU default: XLA fuses the dense cycle well,
  and per-cycle state stays registers/cache-resident inside the scan.
* ``pallas`` / ``pallas_interpret`` — chunks of ``chunk`` cycles per fused
  kernel launch (``noc_cycle.make_chunk_runner``); state planes round-trip
  HBM only at chunk boundaries, and the packed arrival-event logs are
  decoded into ``dtime`` between launches. ``pallas_interpret`` is the
  CPU-validation flavor (bit-identical to ``ref`` — CI enforces it).

Backend names resolve through ``kernels.noc_step.ops.resolve_backend``
(``None``/``"auto"`` picks ``ref`` on CPU, ``pallas`` on TPU/GPU), so the
whole xsim stack shares one switch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..noc_step.ops import resolve_backend  # noqa: F401  (re-export)
from .noc_cycle import make_chunk_runner
from .ref import CTR, TABLE_FIELDS, CycleState, cycle_core, init_planes

__all__ = [
    "CTR", "CycleState", "init_planes", "resolve_backend", "run_cycles",
]


def run_cycles(tr: dict, geom: dict, *, T: int, F: int, V: int, BD: int,
               L: int, NN: int, ND: int, backend: str,
               chunk: int = 32, epoch_len: int | None = None) -> dict:
    """Run ``T`` cycles over one compiled-traffic tensor dict ``tr``.

    Returns ``{"dtime": (ND + 1,), "ctr": (len(CTR),), "crel": (C,),
    "lutil": (E, L), "rconf": (E, NN)}`` — ``dtime`` is the *flat*
    delivery-time array indexed by the compiler's ``dslot`` table (slot
    ``ND`` is the discard slot); the runner rebuilds the (P, S) view.
    Carrying only the sparse delivery slots through the scan keeps the
    per-cycle state small — the dense (P, S) plane would dominate the
    carry at scale. ``lutil``/``rconf`` are the telemetry planes
    (per-epoch per-link flit traversals / per-router arbitration
    conflicts) bucketed on ``cycle // epoch_len`` with ``E =
    ceil(T / epoch_len)`` (``epoch_len=None``: one epoch spanning the
    run). vmap/pmap-safe: fixed shapes, no host callbacks, all backends.
    """
    P, S = tr["link"].shape
    C = tr["child_parent"].shape[0]
    W = 2 * V
    # int32 headroom for the packed keys/events (compile.py guards the
    # (enqueue, pid, fid) age keys separately)
    assert (T + 2) * max(C, 1) < 2**31, "child release keys exceed int32"
    assert P * S * 4 + 1 < 2**31, "arrival events exceed int32"
    if "flits" not in tr:  # legacy/minimal table dicts: uniform worm length
        tr = dict(tr)
        tr["flits"] = jnp.full((P,), F, jnp.int32)
    tb = {f: jnp.asarray(tr[f]) for f in TABLE_FIELDS}
    dslot = jnp.asarray(tr["dslot"], jnp.int32)
    EPL = T if epoch_len is None else int(epoch_len)
    EPL = max(EPL, 1)
    E = max(1, -(-T // EPL))
    planes0 = init_planes(L, W, NN, C, E)
    dtime0 = jnp.full((ND + 1,), -1, jnp.int32)
    params = dict(F=F, V=V, BD=BD, L=L, NN=NN, EPL=EPL)

    def record(dtime, aval, apid, astage, tail, t):
        """The engine's one scatter: tail arrivals at delivery stages."""
        sc = jnp.clip(astage, 0, S - 1)
        ds = dslot[jnp.clip(apid, 0, P - 1), sc]  # -1 = not a delivery
        hit = aval & tail & (ds >= 0)
        return dtime.at[jnp.where(hit, ds, ND)].set(t, mode="drop")

    if backend == "ref":
        def body(carry, t):
            planes, dtime = carry
            planes, (aval, apid, astage, afid) = cycle_core(
                planes, tb, t, geom, **params
            )
            tail = afid == tb["flits"][jnp.clip(apid, 0, P - 1)] - 1
            return (planes, record(dtime, aval, apid, astage, tail, t)), None

        (planes, dtime), _ = jax.lax.scan(
            body, (planes0, dtime0), jnp.arange(T, dtype=jnp.int32)
        )
    else:
        interpret = backend == "pallas_interpret"

        def apply_events(dtime, ev, t0):
            Tc = ev.shape[0]
            flat = ev.reshape(-1)
            code = jnp.maximum(flat - 1, 0)
            tail = (code % 4) >= 2
            ps = code // 4
            stage, pid = ps % S, ps // S
            aval = flat > 0
            times = t0 + jnp.repeat(jnp.arange(Tc, dtype=jnp.int32), L)
            return record(dtime, aval, pid, stage, tail, times)

        carry = (planes0, dtime0)
        full, rem = divmod(T, chunk)
        if full:
            runner = make_chunk_runner(
                geom, S=S, Tc=chunk, interpret=interpret, **params
            )

            def body(carry, i):
                planes, dtime = carry
                t0 = i * chunk
                planes, ev = runner(planes, tb, t0)
                return (planes, apply_events(dtime, ev, t0)), None

            carry, _ = jax.lax.scan(
                body, carry, jnp.arange(full, dtype=jnp.int32)
            )
        if rem:
            runner = make_chunk_runner(
                geom, S=S, Tc=rem, interpret=interpret, **params
            )
            planes, ev = runner(carry[0], tb, full * chunk)
            carry = (planes, apply_events(carry[1], ev, full * chunk))
        planes, dtime = carry

    crel = (planes.crtime >= 0) & (planes.crtime < T)
    return {
        "dtime": dtime, "ctr": planes.ctr, "crel": crel,
        "lutil": planes.lutil, "rconf": planes.rconf,
    }
