"""Reference (jnp) fused wormhole cycle over packed router-centric planes.

This is the single source of truth for one simulated NoC cycle — the Pallas
kernel in ``noc_cycle.py`` runs the *same* ``cycle_core`` inside its inner
``fori_loop``, so the two backends are bit-identical by construction.

State layout (DESIGN.md §8). Instead of the old per-worm slot pool
(``SlotState``: ``sfpos[K, F]`` + two segmented-min scatter rounds per
cycle), state lives where the hardware keeps it — in the routers:

* ``fowner[L, W]``  packet id owning VC FIFO ``(link, vc)`` (-1 free);
                    ``W = 2V`` VCs per directed link, vcs ``[0, V)`` are
                    class HIGH(0), ``[V, 2V)`` class LOW(1).
* ``fstage[L, W]``  int16 — the owner's route stage this FIFO serves.
* ``fhead[L, W]``   int8 — flit id of the FIFO's front (FIFOs hold the
                    contiguous flit run ``[fhead, fhead + fcount)``).
* ``fcount[L, W]``  int8 — flits resident (0 while the run is in transit).
* ``lpid/lsent/lptr[2NN]`` NI lane fronts: current injecting packet, flits
                    already injected, and the root-lane static-order cursor.
* ``crtime[C]``     cycle each DPM child becomes releasable (-1 pending) —
                    set by the parent header's arrival event on the child's
                    ``watch_link``; ``ctaken`` marks consumed children.
* ``inflight/ctr``  scalar counters (same event semantics as the host sim).

Why this layout is fast *and* fuses: every flit that can move is the front
of exactly one FIFO (or NI lane), and the flits competing for node ``v``'s
output links all sit in ``v``'s input FIFOs — a static ``node_ports[NN,
4W+2]`` table. Both arbitration rounds therefore reduce to a dense masked
min over that table, and winner masks map *back* to FIFO planes through the
static ``cand_node``/``cand_port`` inverse — gathers only, no scatters, no
segmented-min, no slot allocation (capacity is structural: a worm holds a
VC or a lane front). The only scatter left in the whole engine is the
(L,)-sized delivery-time recording, which the Pallas backend moves out of
the kernel entirely via a packed per-cycle arrival-event row.

Decision rules are the host simulator's, unchanged from the old engine:
admissibility from start-of-cycle state, (enqueue, pid, fid) age keys, one
winner per directed link, ejection arbitrated per node on post-move state,
a freed VC re-allocable the next cycle. One fidelity *upgrade* over the
old engine: same-lane DPM children now inject in dynamic parent-arrival
order — ``(crtime, pid)`` priority over the per-node ``chl`` candidate
table — exactly the host sim's release-order queue, instead of the old
static (enqueue, pid) approximation (DESIGN.md §5/§8).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..noc_step.noc_step import NOC_INF

# counter indices (named after the SimStats fields they feed; slots_hwm is
# xsim-only: the in-flight-worm high-water mark)
CTR = (
    "flit_link_traversals", "buffer_writes", "buffer_reads",
    "xbar_traversals", "arbitrations", "ni_flits", "packets_finished",
    "slots_hwm",
)
_I = {name: i for i, name in enumerate(CTR)}

# table fields cycle_core reads (the kernel passes them as explicit refs)
TABLE_FIELDS = (
    "enqueue", "lane", "num_stages", "flits", "link", "vcls", "lane_seq",
    "chl", "child_pid", "child_parent", "child_rs", "child_enq", "watch_link",
)


class CycleState(NamedTuple):
    fowner: jax.Array  # (L, W) int32
    fstage: jax.Array  # (L, W) int16
    fhead: jax.Array  # (L, W) int8
    fcount: jax.Array  # (L, W) int8
    fdvc: jax.Array  # (L, W) int8 — downstream VC the front worm's header
    #                  allocated at its next link (valid once fhead > 0)
    freq: jax.Array  # (L, W) int32 — the owner's next-hop link (-1 = this
    #                  FIFO serves the final stage), cached at header arrival
    fkey: jax.Array  # (L, W) int32 — owner's age-key base (enq*P+pid)*F
    fcls: jax.Array  # (L, W) int8 — owner's VC class at the next hop
    ffin: jax.Array  # (L, W) bool — FIFO serves the owner's final stage
    fnf: jax.Array  # (L, W) int8 — owner's worm length (per-packet flits),
    #                  cached at header arrival like fkey/ffin
    lpid: jax.Array  # (2NN,) int32
    lsent: jax.Array  # (2NN,) int8
    lptr: jax.Array  # (2NN,) int32
    ldvc: jax.Array  # (2NN,) int8 — lane-front worm's VC at its first link
    crtime: jax.Array  # (C,) int32, -1 = not yet releasable
    ctaken: jax.Array  # (C,) bool — consumed by its lane front
    lutil: jax.Array  # (E, L) int32 — per-epoch per-link flit traversals
    #                  (telemetry; epoch = min(t // EPL, E-1), DESIGN.md §10)
    rconf: jax.Array  # (E, NN) int32 — per-epoch per-router arbitration
    #                  conflicts (losing requests across the 4 output links)
    inflight: jax.Array  # () int32 — worms between lane-front and finish
    ctr: jax.Array  # (len(CTR),) int32


def init_planes(L: int, W: int, NN: int, C: int, E: int = 1) -> CycleState:
    return CycleState(
        fowner=jnp.full((L, W), -1, jnp.int32),
        fstage=jnp.zeros((L, W), jnp.int16),
        fhead=jnp.zeros((L, W), jnp.int8),
        fcount=jnp.zeros((L, W), jnp.int8),
        fdvc=jnp.zeros((L, W), jnp.int8),
        freq=jnp.full((L, W), -1, jnp.int32),
        fkey=jnp.zeros((L, W), jnp.int32),
        fcls=jnp.zeros((L, W), jnp.int8),
        ffin=jnp.zeros((L, W), bool),
        fnf=jnp.ones((L, W), jnp.int8),
        lpid=jnp.full((2 * NN,), -1, jnp.int32),
        lsent=jnp.zeros((2 * NN,), jnp.int8),
        lptr=jnp.zeros((2 * NN,), jnp.int32),
        ldvc=jnp.zeros((2 * NN,), jnp.int8),
        crtime=jnp.full((C,), -1, jnp.int32),
        ctaken=jnp.zeros((C,), bool),
        lutil=jnp.zeros((E, L), jnp.int32),
        rconf=jnp.zeros((E, NN), jnp.int32),
        inflight=jnp.zeros((), jnp.int32),
        ctr=jnp.zeros((len(CTR),), jnp.int32),
    )


def cycle_core(state: CycleState, tb: dict, t: jax.Array, geom: dict, *,
               F: int, V: int, BD: int, L: int, NN: int,
               EPL: int = 1 << 30):
    """One wormhole cycle. Pure jnp, no scatters — runs under lax.scan (ref
    backend) and inside the Pallas kernel's fori_loop unchanged.

    ``tb`` holds the compiled-traffic tables (traced), ``geom`` the static
    numpy router geometry from ``compile.geometry_tables``. Returns the new
    state plus the per-link arrival events ``(aval, apid, astage, afid)``
    the caller turns into delivery times (the one scatter, kept outside).
    """
    (fowner, fstage, fhead, fcount, fdvc, freq, fkey, fcls, ffin, fnf, lpid,
     lsent, lptr, ldvc, crtime, ctaken, lutil, rconf, inflight, ctr) = state
    enqueue = tb["enqueue"]
    ns = tb["num_stages"]
    flits_t = tb["flits"]
    link_t = tb["link"]
    vcls_t = tb["vcls"]
    lane_seq = tb["lane_seq"]
    chl = tb["chl"]
    child_pid = tb["child_pid"]
    P, S = link_t.shape
    Q = lane_seq.shape[1]
    C = crtime.shape[0]
    W = 2 * V
    LW = L * W
    INF = jnp.int32(NOC_INF)
    node_ports = geom["node_ports"]  # (NN, 4W+2) static
    cand_node = geom["cand_node"]  # (CAND+1,) static
    cand_port = geom["cand_port"]
    crow_ids = jnp.arange(C, dtype=jnp.int32)

    # ---- 1. NI lane refill ------------------------------------------------
    # root lanes (even): static (enqueue, pid) cursor; child lanes (odd):
    # dynamic (release-cycle, pid) priority — the host sim's queue order
    cand_root = jnp.take_along_axis(
        lane_seq, jnp.clip(lptr, 0, Q - 1)[:, None], axis=1
    )[:, 0]
    root_ok = (
        (lptr < Q) & (cand_root >= 0)
        & (enqueue[jnp.clip(cand_root, 0, P - 1)] <= t)
    )
    released = (crtime >= 0) & (crtime <= t) & ~ctaken
    ckey = jnp.where(released, crtime * C + crow_ids, INF)
    ktab = jnp.where(
        chl >= 0, ckey[jnp.clip(chl, 0, C - 1)], INF
    )  # (NN, QC)
    cargm = jnp.argmin(ktab, axis=1).astype(jnp.int32)
    child_ok = jnp.min(ktab, axis=1) < INF
    crow = jnp.take_along_axis(chl, cargm[:, None], axis=1)[:, 0]  # (NN,)
    cpid = child_pid[jnp.clip(crow, 0, C - 1)]
    lane_cand = jnp.stack(
        [cand_root.reshape(NN, 2)[:, 0], cpid], axis=1
    ).reshape(2 * NN)
    lane_ok = jnp.stack(
        [root_ok.reshape(NN, 2)[:, 0], child_ok], axis=1
    ).reshape(2 * NN)
    need = (lpid < 0) | (
        lsent.astype(jnp.int32) >= flits_t[jnp.clip(lpid, 0, P - 1)]
    )
    got = need & lane_ok
    lpid = jnp.where(got, lane_cand, jnp.where(need, -1, lpid))
    lsent = jnp.where(got, jnp.int8(0), lsent)
    is_root_lane = (jnp.arange(2 * NN) % 2) == 0
    lptr = lptr + (got & is_root_lane)
    got_child = got.reshape(NN, 2)[:, 1]  # (NN,)
    cnode = tb["lane"][jnp.clip(child_pid, 0, P - 1)] // 2  # (C,)
    ctaken = ctaken | (got_child[cnode] & (crow[cnode] == crow_ids))
    inflight = inflight + jnp.sum(got, dtype=jnp.int32)
    ctr = ctr.at[_I["slots_hwm"]].max(inflight)

    # ---- 2. link-round candidates (start-of-cycle admissibility) ----------
    # the per-worm route lookups (next link / VC class / age-key base /
    # final-stage flag) were cached into planes at header arrival, so this
    # phase reads no (P, S) table — at scale those random gathers into the
    # multi-MB compiled tables dominate the cycle
    fp = jnp.clip(fowner, 0, P - 1)
    occ = (fowner >= 0) & (fcount > 0)  # front flit present
    fs32 = fstage.astype(jnp.int32)
    fh32 = fhead.astype(jnp.int32)
    to_f = fs32 + 1
    req_f = jnp.where(occ, freq, -1)  # (L, W); freq = -1 at final stage
    req_fc = jnp.clip(req_f, 0, L - 1)
    key_f = fkey + fh32
    cls_f = fcls.astype(jnp.int32)
    is_hdr_f = fh32 == 0
    freev = fowner < 0  # (L, W) start-of-cycle free VCs
    free_cls = jnp.stack(
        [freev[:, :V].any(axis=1), freev[:, V:].any(axis=1)], axis=1
    )  # (L, 2)
    # first free VC per (link, class) — headers claim the lowest free one
    hvc_cls = jnp.stack(
        [jnp.argmax(freev[:, :V], axis=1),
         V + jnp.argmax(freev[:, V:], axis=1)], axis=1
    ).astype(jnp.int32)  # (L, 2)
    hdr_ok_f = free_cls[req_fc, cls_f]
    hvc_f = hvc_cls[req_fc, cls_f]
    # body flits advance into the FIFO their header allocated at `to` —
    # recorded in ``fdvc`` the cycle the header won (a worm allocates one
    # FIFO per stage, so this equals the old owner/stage search)
    dv_f = fdvc.astype(jnp.int32)
    if BD >= F:
        body_ok_f = True  # a FIFO holds one worm: credit cannot run out
    else:
        body_ok_f = fcount[req_fc, dv_f].astype(jnp.int32) < BD
    adm_f = (req_f >= 0) & jnp.where(is_hdr_f, hdr_ok_f, body_ok_f)
    tvc_f = jnp.where(is_hdr_f, hvc_f, dv_f)

    # NI lane candidates: the front worm's next flit targets stage 0
    lp = jnp.clip(lpid, 0, P - 1)
    ls32 = lsent.astype(jnp.int32)
    lvalid = (lpid >= 0) & (ls32 < flits_t[lp])
    req_l = jnp.where(lvalid, link_t[lp, 0], -1)  # (2NN,)
    req_lc = jnp.clip(req_l, 0, L - 1)
    key_l = (enqueue[lp] * P + lp) * F + ls32
    cls_l = vcls_t[lp, 0]
    is_hdr_l = ls32 == 0
    hdr_ok_l = free_cls[req_lc, cls_l]
    hvc_l = hvc_cls[req_lc, cls_l]
    dv_l = ldvc.astype(jnp.int32)
    if BD >= F:
        body_ok_l = True
    else:
        body_ok_l = fcount[req_lc, dv_l].astype(jnp.int32) < BD
    adm_l = lvalid & jnp.where(is_hdr_l, hdr_ok_l, body_ok_l)
    tvc_l = jnp.where(is_hdr_l, hvc_l, dv_l)

    # flatten candidates: FIFOs, lanes, one trailing dummy (pad target)
    pad1 = lambda v, fill: jnp.concatenate(
        [v, jnp.full((1,), fill, v.dtype)]
    )
    req = pad1(jnp.concatenate([req_f.reshape(LW), req_l]), -1)
    key = pad1(jnp.concatenate([key_f.reshape(LW), key_l]), NOC_INF)
    adm = pad1(jnp.concatenate([adm_f.reshape(LW), adm_l]), False)
    pid_c = pad1(jnp.concatenate([fp.reshape(LW), lp]), 0)
    to_c = pad1(
        jnp.concatenate([to_f.reshape(LW), jnp.zeros_like(req_l)]), 0
    )
    fid_c = pad1(jnp.concatenate([fh32.reshape(LW), ls32]), 0)
    tvc_c = pad1(jnp.concatenate([tvc_f.reshape(LW), tvc_l]), 0)

    # ---- 3. link arbitration: dense masked min over each node's ports -----
    req_np = req[node_ports]  # (NN, PORTS)
    key_np = key[node_ports]
    adm_np = adm[node_ports]
    D = L // NN  # output ports per router (4 in 2-D, 6 in 3-D)
    out_link = (
        jnp.arange(NN, dtype=jnp.int32)[:, None] * D
        + jnp.arange(D, dtype=jnp.int32)[None, :]
    )  # (NN, D) == link-id layout
    m = adm_np[:, None, :] & (req_np[:, None, :] == out_link[:, :, None])
    kk = jnp.where(m, key_np[:, None, :], INF)  # (NN, D, PORTS)
    wport = jnp.argmin(kk, axis=2).astype(jnp.int32)
    aval = (
        jnp.take_along_axis(kk, wport[:, :, None], axis=2)[:, :, 0] < INF
    ).reshape(L)  # winner per link
    rows = jnp.arange(NN)[:, None]
    # winner candidate id per link, then (L,)-sized attribute gathers
    wcand = jnp.asarray(node_ports)[rows, wport].reshape(L)
    apid = pid_c[wcand]
    astage = to_c[wcand]
    afid = fid_c[wcand]
    avc = tvc_c[wcand]
    from_lane = (wport >= D * W).reshape(L) & aval
    # map winners back to candidates through the static inverse (gather)
    won = (
        adm & (req >= 0)
        & aval[jnp.clip(req, 0, L - 1)]
        & (wport.reshape(L)[jnp.clip(req, 0, L - 1)] == cand_port)
    )
    won_f = won[:LW].reshape(L, W)
    won_l = won[LW:LW + 2 * NN]

    # ---- 4. apply moves ---------------------------------------------------
    # a winning header pins the VC it was granted for its body flits
    fdvc = jnp.where(won_f & is_hdr_f, tvc_f.astype(jnp.int8), fdvc)
    ldvc = jnp.where(won_l & is_hdr_l, tvc_l.astype(jnp.int8), ldvc)
    dep_tail = won_f & (fhead == fnf - 1)
    fhead = fhead + won_f.astype(jnp.int8)
    fcount = fcount - won_f.astype(jnp.int8)
    fowner = jnp.where(dep_tail, -1, fowner)
    lsent = lsent + won_l.astype(jnp.int8)
    arr1h = aval[:, None] & (avc[:, None] == jnp.arange(W))  # (L, W)
    hdr1h = arr1h & (afid[:, None] == 0)
    fowner = jnp.where(hdr1h, apid[:, None], fowner)
    fstage = jnp.where(hdr1h, astage[:, None].astype(jnp.int16), fstage)
    fhead = jnp.where(hdr1h, jnp.int8(0), fhead)
    fcount = fcount + arr1h.astype(jnp.int8)
    # cache the arriving worm's route lookups in the FIFO planes — (L,)
    # gathers once per arrival replace (L, W) gathers every cycle
    a_ns = ns[apid]  # (L,)
    nxt = astage + 1
    nxtc = jnp.clip(nxt, 0, S - 1)
    a_req = jnp.where(nxt < a_ns, link_t[apid, nxtc], -1)
    a_cls = vcls_t[apid, nxtc]
    a_key = (enqueue[apid] * P + apid) * F
    a_fin = astage == a_ns - 1
    a_nf = flits_t[apid]  # (L,) — the arriving worm's length
    freq = jnp.where(hdr1h, a_req[:, None], freq)
    fkey = jnp.where(hdr1h, a_key[:, None], fkey)
    fcls = jnp.where(hdr1h, a_cls.astype(jnp.int8)[:, None], fcls)
    ffin = jnp.where(hdr1h, a_fin[:, None], ffin)
    fnf = jnp.where(hdr1h, a_nf.astype(jnp.int8)[:, None], fnf)

    # ---- 5. ejection (per node, post-move state) --------------------------
    ecand_f = (fowner >= 0) & (fcount > 0) & ffin
    ekey_f = fkey + fhead.astype(jnp.int32)
    ecand = pad1(
        jnp.concatenate([ecand_f.reshape(LW), jnp.zeros_like(req_l, bool)]),
        False,
    )
    ekey = pad1(
        jnp.concatenate([ekey_f.reshape(LW), jnp.zeros_like(req_l)]),
        NOC_INF,
    )
    ek_np = jnp.where(ecand[node_ports], ekey[node_ports], INF)
    eport = jnp.argmin(ek_np, axis=1).astype(jnp.int32)  # (NN,)
    ewin_n = jnp.min(ek_np, axis=1) < INF
    ewon = ecand & ewin_n[cand_node] & (eport[cand_node] == cand_port)
    ewon_f = ewon[:LW].reshape(L, W)
    etail = ewon_f & (fhead == fnf - 1)
    fhead = fhead + ewon_f.astype(jnp.int8)
    fcount = fcount - ewon_f.astype(jnp.int8)
    fowner = jnp.where(etail, -1, fowner)

    # ---- 6. DPM child release: watch the parent header's arrival ----------
    wlc = jnp.clip(tb["watch_link"], 0, L - 1)
    hit = (
        aval[wlc] & (apid[wlc] == tb["child_parent"])
        & (astage[wlc] == tb["child_rs"]) & (afid[wlc] == 0)
    )
    crtime = jnp.where(
        (crtime < 0) & hit, jnp.maximum(t + 1, tb["child_enq"]), crtime
    )

    # ---- 7. counters (same events the host sim counts) --------------------
    n_moves = jnp.sum(aval, dtype=jnp.int32)
    n_inj = jnp.sum(from_lane, dtype=jnp.int32)
    n_ej = jnp.sum(ewon_f, dtype=jnp.int32)
    finished = jnp.sum(etail, dtype=jnp.int32)
    inflight = inflight - finished
    zero = jnp.zeros((), jnp.int32)
    ctr = ctr + jnp.stack([
        n_moves, n_moves, n_moves - n_inj + n_ej, n_moves,
        jnp.sum(req >= 0, dtype=jnp.int32), n_inj + n_ej, finished, zero,
    ])

    # ---- 8. telemetry planes (epoch-bucketed, DESIGN.md §10) --------------
    # one-hot epoch accumulate (no dynamic scatter — Mosaic-safe and
    # bit-identical across backends). lutil decomposes flit_link_traversals
    # per directed link; rconf counts losing requests per router — the same
    # candidate sets the arbitrations counter tallies, minus the winners.
    E = lutil.shape[0]
    eh = (
        jnp.arange(E, dtype=jnp.int32) == jnp.minimum(t // EPL, E - 1)
    ).astype(jnp.int32)  # (E,)
    lutil = lutil + eh[:, None] * aval.astype(jnp.int32)[None, :]
    nreq = jnp.sum(
        (req_np[:, None, :] == out_link[:, :, None]).astype(jnp.int32),
        axis=2,
    )  # (NN, D) requests per output link, admissible or not (host parity)
    conf_n = jnp.sum(jnp.maximum(nreq - 1, 0), axis=1)  # (NN,)
    rconf = rconf + eh[:, None] * conf_n[None, :]

    state = CycleState(fowner, fstage, fhead, fcount, fdvc, freq, fkey,
                       fcls, ffin, fnf, lpid, lsent, lptr, ldvc, crtime,
                       ctaken, lutil, rconf, inflight, ctr)
    return state, (aval, apid, astage, afid)
