"""Fused wormhole-cycle kernel: the whole xsim step as one Pallas launch.

Three-file pattern (as ``kernels.noc_step``): ``ref.py`` is the bit-exact
jnp cycle over packed router-centric planes (also the CPU fast path),
``noc_cycle.py`` the Pallas chunk kernel running the same ``cycle_core``
with state resident across an inner ``fori_loop``, ``ops.py`` the backend
dispatch (``ref`` / ``pallas`` / ``pallas_interpret``).
"""
from .noc_cycle import make_chunk_runner
from .ops import CTR, CycleState, init_planes, resolve_backend, run_cycles
from .ref import TABLE_FIELDS, cycle_core

__all__ = [
    "CTR", "CycleState", "TABLE_FIELDS", "cycle_core", "init_planes",
    "make_chunk_runner", "resolve_backend", "run_cycles",
]
