"""Pallas TPU kernel: batched DPM partition-cost tables (Definitions 1-3).

This is the paper's planner compute, vectorized over many multicast requests
(the situation a TPU-side planner faces: one plan per expert-dispatch group
per step). For a tile of packets the kernel evaluates all 24 candidate
partitions (8 basic + 8 pairs + 8 triples of consecutive partitions):

    rep[c]  = argmin_{d in cand} (dist(S, d), label(d))        (Definition 1)
    cost[c] = sum_{d in cand} dist(rep, d) [+ |S->rep|]        (C_t of Def. 2)

where dist is Manhattan on the mesh and toroidal Manhattan under ``wrap=True``
(the Torus geometry — partitions become signed shortest-displacement wedges,
matching repro.core.partition.basic_partitions on a Torus exactly).

The dual-path cost C_p needs a sequential path walk and stays host-side
(repro.core); MU-cost planning is exact for partitions where MU wins (the
common case on a torus — see DESIGN.md §3). Greedy merging over the table is
vectorized jnp in ops.py.

Block layout: a tile of TP packets x all NN mesh nodes in VMEM; integer/VPU
work only (no MXU), grid = n_tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# candidate index sets: 8 singles, 8 consecutive pairs, 8 consecutive triples
CANDS: list[tuple[int, ...]] = (
    [(i,) for i in range(8)]
    + [(i, (i + 1) % 8) for i in range(8)]
    + [(i, (i + 1) % 8, (i + 2) % 8) for i in range(8)]
)
BIG = 1 << 20


def _ring_delta(d, size: int, wrap: bool):
    """Signed shortest displacement per ring dimension, vectorized.

    ``wrap=False`` is the identity (mesh). ``wrap=True`` maps into
    [-size//2, (size-1)//2] with half-way ties negative. The expression must
    stay bit-identical to core.topology.ring_delta (jnp ``%`` is floor-mod,
    like Python's) or host and kernel partitions diverge; parity is pinned by
    tests/test_topology.py.
    """
    if not wrap or size <= 1:
        return d
    return (d + size // 2) % size - size // 2


def _kernel(
    mask_ref, sxy_ref, cost_ref, rep_ref, *, n: int, m: int, leg: bool, wrap: bool
):
    NN = n * m
    node = jax.lax.iota(jnp.int32, NN)
    xs = node % n  # row-major node index
    ys = node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))

    dm = mask_ref[...]  # (TP, NN) int32 0/1
    sx = sxy_ref[:, 0:1]  # (TP, 1)
    sy = sxy_ref[:, 1:2]

    # signed shortest displacement source -> node (plain difference on the
    # mesh, shortest way around each ring on the torus)
    dxs = _ring_delta(xs[None, :] - sx, n, wrap)  # (TP, NN)
    dys = _ring_delta(ys[None, :] - sy, m, wrap)
    gx, lx, ex = dxs > 0, dxs < 0, dxs == 0
    gy, ly, ey = dys > 0, dys < 0, dys == 0
    # P0..P7 counter-clockwise from the upper-right quadrant (Fig. 2a)
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]

    dsrc = jnp.abs(dxs) + jnp.abs(dys)  # (TP, NN) (toroidal) Manhattan

    for ci, ids in enumerate(CANDS):
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm  # (TP, NN) destinations in this candidate
        any_sel = sel.any(axis=1)
        # representative: argmin (dist, label)
        key = jnp.where(sel, dsrc * BIG + blabel[None, :], jnp.int32(2**30))
        rep = jnp.argmin(key, axis=1).astype(jnp.int32)  # (TP,)
        rx = rep % n
        ry = rep // n
        drep = jnp.abs(_ring_delta(xs[None, :] - rx[:, None], n, wrap)) + jnp.abs(
            _ring_delta(ys[None, :] - ry[:, None], m, wrap)
        )
        ct = jnp.sum(jnp.where(sel, drep, 0), axis=1).astype(jnp.int32)
        if leg:
            sleg = jnp.abs(_ring_delta(rx - sx[:, 0], n, wrap)) + jnp.abs(
                _ring_delta(ry - sy[:, 0], m, wrap)
            )
            ct = ct + sleg
        cost_ref[:, ci] = jnp.where(any_sel, ct, 0)
        rep_ref[:, ci] = jnp.where(any_sel, rep, -1)


def _weighted_kernel(
    mask_ref, sxy_ref, dist_ref, weight_ref, cost_ref, rep_ref,
    *, n: int, m: int, leg: bool, wrap: bool, overhead: float,
):
    """Weighted variant: distances and per-destination prices come from
    dense (NN, NN) route tensors instead of coordinate arithmetic.

    ``dist[u, v]`` is the provider-route hop count (detours included on a
    degraded topology) and drives Definition 1 representative selection;
    ``weight[u, v]`` is the route price under an arbitrary cost model and
    drives Definition 2's C_t plus the S->R leg; ``overhead`` is the
    model's per-worm injection price (charged per re-injected MU child,
    i.e. per destination beyond the representative). Partition membership
    stays geometric (base-topology wedges). Row gathers are one-hot MXU
    matmuls — float32 sums of 0/1-selected rows, exact for integer-valued
    weights below 2^24.
    """
    NN = n * m
    node = jax.lax.iota(jnp.int32, NN)
    xs = node % n
    ys = node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))

    dm = mask_ref[...]  # (TP, NN) int32 0/1
    sx = sxy_ref[:, 0:1]
    sy = sxy_ref[:, 1:2]
    dist = dist_ref[...]  # (NN, NN) f32
    weight = weight_ref[...]  # (NN, NN) f32

    dxs = _ring_delta(xs[None, :] - sx, n, wrap)
    dys = _ring_delta(ys[None, :] - sy, m, wrap)
    gx, lx, ex = dxs > 0, dxs < 0, dxs == 0
    gy, ly, ey = dys > 0, dys < 0, dys == 0
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]

    src_idx = sy[:, 0] * n + sx[:, 0]  # (TP,) row-major
    oh_src = (node[None, :] == src_idx[:, None]).astype(jnp.float32)
    dsrc = jnp.dot(
        oh_src, dist, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # (TP, NN) provider hop counts src -> node
    w_src = jnp.dot(oh_src, weight, preferred_element_type=jnp.float32)

    for ci, ids in enumerate(CANDS):
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm
        any_sel = sel.any(axis=1)
        key = jnp.where(sel, dsrc * BIG + blabel[None, :], jnp.int32(2**30))
        rep = jnp.argmin(key, axis=1).astype(jnp.int32)
        oh_rep = (node[None, :] == rep[:, None]).astype(jnp.float32)
        w_rep = jnp.dot(oh_rep, weight, preferred_element_type=jnp.float32)
        cnt = jnp.sum(sel.astype(jnp.float32), axis=1)
        ct = jnp.sum(jnp.where(sel, w_rep, 0.0), axis=1)
        ct = ct + jnp.maximum(cnt - 1.0, 0.0) * overhead
        if leg:
            ct = ct + jnp.sum(oh_rep * w_src, axis=1)
        cost_ref[:, ci] = jnp.where(any_sel, ct, 0.0)
        rep_ref[:, ci] = jnp.where(any_sel, rep, -1)


def dpm_cost_table(
    dest_mask: jax.Array,  # (P, NN) int32 0/1 (row-major nodes)
    src_xy: jax.Array,  # (P, 2) int32
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    include_source_leg: bool = True,
    tile: int = 128,
    interpret: bool = False,
):
    """Batched candidate cost tables; ``wrap=True`` computes toroidal
    Manhattan distances and wedge partitions (the Torus geometry)."""
    m = m or n
    P, NN = dest_mask.shape
    assert NN == n * m
    pad = (-P) % tile
    if pad:
        dest_mask = jnp.pad(dest_mask, [(0, pad), (0, 0)])
        src_xy = jnp.pad(src_xy, [(0, pad), (0, 0)])
    Pp = P + pad
    kernel = functools.partial(
        _kernel, n=n, m=m, leg=include_source_leg, wrap=wrap
    )
    costs, reps = pl.pallas_call(
        kernel,
        grid=(Pp // tile,),
        in_specs=[
            pl.BlockSpec((tile, NN), lambda i: (i, 0)),
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, 24), jnp.int32),
            jax.ShapeDtypeStruct((Pp, 24), jnp.int32),
        ],
        interpret=interpret,
    )(dest_mask.astype(jnp.int32), src_xy.astype(jnp.int32))
    return costs[:P], reps[:P]


def dpm_cost_table_weighted(
    dest_mask: jax.Array,  # (P, NN) int32 0/1 (row-major nodes)
    src_xy: jax.Array,  # (P, 2) int32
    dist: jax.Array,  # (NN, NN) provider-route hop counts (int-valued)
    weight: jax.Array,  # (NN, NN) provider-route prices under a cost model
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    overhead: float = 0.0,
    include_source_leg: bool = True,
    tile: int = 128,
    interpret: bool = False,
):
    """Batched candidate cost tables over arbitrary route tensors.

    The generalization of ``dpm_cost_table`` the route-provider layer
    feeds: ``(dist, weight, overhead)`` come from
    ``repro.core.routefn.route_cost_matrices(topo, cost_model)``, so
    energy-, contention-, and fault-priced DPM (detoured hop counts on a
    ``FaultyTopology``) all batch on device through one kernel. Returns
    ``(costs (P, 24) float32, reps (P, 24) int32)``; candidate cost is C_t
    from the representative plus, when ``include_source_leg``, the priced
    S->R leg — matching ``repro.core.partition.candidate_cost``'s ``cost_mu
    + source_leg`` under the same model (exactly for integer-valued
    weights, to float32 rounding otherwise).
    """
    m = m or n
    P, NN = dest_mask.shape
    assert NN == n * m and dist.shape == weight.shape == (NN, NN)
    pad = (-P) % tile
    if pad:
        dest_mask = jnp.pad(dest_mask, [(0, pad), (0, 0)])
        src_xy = jnp.pad(src_xy, [(0, pad), (0, 0)])
    Pp = P + pad
    kernel = functools.partial(
        _weighted_kernel,
        n=n, m=m, leg=include_source_leg, wrap=wrap, overhead=float(overhead),
    )
    costs, reps = pl.pallas_call(
        kernel,
        grid=(Pp // tile,),
        in_specs=[
            pl.BlockSpec((tile, NN), lambda i: (i, 0)),
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
            pl.BlockSpec((NN, NN), lambda i: (0, 0)),
            pl.BlockSpec((NN, NN), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, 24), jnp.float32),
            jax.ShapeDtypeStruct((Pp, 24), jnp.int32),
        ],
        interpret=interpret,
    )(
        dest_mask.astype(jnp.int32),
        src_xy.astype(jnp.int32),
        dist.astype(jnp.float32),
        weight.astype(jnp.float32),
    )
    return costs[:P], reps[:P]
