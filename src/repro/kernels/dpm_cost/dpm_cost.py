"""Pallas TPU kernel: batched DPM partition-cost tables (Definitions 1-3).

This is the paper's planner compute, vectorized over many multicast requests
(the situation a TPU-side planner faces: one plan per expert-dispatch group
per step). For a tile of packets the kernel evaluates all 24 candidate
partitions (8 basic + 8 pairs + 8 triples of consecutive partitions):

    rep[c]  = argmin_{d in cand} (dist(S, d), label(d))        (Definition 1)
    cost[c] = sum_{d in cand} dist(rep, d) [+ |S->rep|]        (C_t of Def. 2)

where dist is Manhattan on the mesh and toroidal Manhattan under ``wrap=True``
(the Torus geometry — partitions become signed shortest-displacement wedges,
matching repro.core.partition.basic_partitions on a Torus exactly).

The dual-path cost C_p needs a sequential path walk and stays host-side
(repro.core); MU-cost planning is exact for partitions where MU wins (the
common case on a torus — see DESIGN.md §3). Greedy merging over the table is
vectorized jnp in ops.py.

Block layout: a tile of TP packets x all NN mesh nodes in VMEM; integer/VPU
work only (no MXU), grid = n_tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# candidate index sets: 8 singles, 8 consecutive pairs, 8 consecutive triples
CANDS: list[tuple[int, ...]] = (
    [(i,) for i in range(8)]
    + [(i, (i + 1) % 8) for i in range(8)]
    + [(i, (i + 1) % 8, (i + 2) % 8) for i in range(8)]
)
BIG = 1 << 20


def _ring_delta(d, size: int, wrap: bool):
    """Signed shortest displacement per ring dimension, vectorized.

    ``wrap=False`` is the identity (mesh). ``wrap=True`` maps into
    [-size//2, (size-1)//2] with half-way ties negative. The expression must
    stay bit-identical to core.topology.ring_delta (jnp ``%`` is floor-mod,
    like Python's) or host and kernel partitions diverge; parity is pinned by
    tests/test_topology.py.
    """
    if not wrap or size <= 1:
        return d
    return (d + size // 2) % size - size // 2


def _kernel(
    mask_ref, sxy_ref, cost_ref, rep_ref, *, n: int, m: int, leg: bool, wrap: bool
):
    NN = n * m
    node = jax.lax.iota(jnp.int32, NN)
    xs = node % n  # row-major node index
    ys = node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))

    dm = mask_ref[...]  # (TP, NN) int32 0/1
    sx = sxy_ref[:, 0:1]  # (TP, 1)
    sy = sxy_ref[:, 1:2]

    # signed shortest displacement source -> node (plain difference on the
    # mesh, shortest way around each ring on the torus)
    dxs = _ring_delta(xs[None, :] - sx, n, wrap)  # (TP, NN)
    dys = _ring_delta(ys[None, :] - sy, m, wrap)
    gx, lx, ex = dxs > 0, dxs < 0, dxs == 0
    gy, ly, ey = dys > 0, dys < 0, dys == 0
    # P0..P7 counter-clockwise from the upper-right quadrant (Fig. 2a)
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]

    dsrc = jnp.abs(dxs) + jnp.abs(dys)  # (TP, NN) (toroidal) Manhattan

    for ci, ids in enumerate(CANDS):
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm  # (TP, NN) destinations in this candidate
        any_sel = sel.any(axis=1)
        # representative: argmin (dist, label)
        key = jnp.where(sel, dsrc * BIG + blabel[None, :], jnp.int32(2**30))
        rep = jnp.argmin(key, axis=1).astype(jnp.int32)  # (TP,)
        rx = rep % n
        ry = rep // n
        drep = jnp.abs(_ring_delta(xs[None, :] - rx[:, None], n, wrap)) + jnp.abs(
            _ring_delta(ys[None, :] - ry[:, None], m, wrap)
        )
        ct = jnp.sum(jnp.where(sel, drep, 0), axis=1).astype(jnp.int32)
        if leg:
            sleg = jnp.abs(_ring_delta(rx - sx[:, 0], n, wrap)) + jnp.abs(
                _ring_delta(ry - sy[:, 0], m, wrap)
            )
            ct = ct + sleg
        cost_ref[:, ci] = jnp.where(any_sel, ct, 0)
        rep_ref[:, ci] = jnp.where(any_sel, rep, -1)


def dpm_cost_table(
    dest_mask: jax.Array,  # (P, NN) int32 0/1 (row-major nodes)
    src_xy: jax.Array,  # (P, 2) int32
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    include_source_leg: bool = True,
    tile: int = 128,
    interpret: bool = False,
):
    """Batched candidate cost tables; ``wrap=True`` computes toroidal
    Manhattan distances and wedge partitions (the Torus geometry)."""
    m = m or n
    P, NN = dest_mask.shape
    assert NN == n * m
    pad = (-P) % tile
    if pad:
        dest_mask = jnp.pad(dest_mask, [(0, pad), (0, 0)])
        src_xy = jnp.pad(src_xy, [(0, pad), (0, 0)])
    Pp = P + pad
    kernel = functools.partial(
        _kernel, n=n, m=m, leg=include_source_leg, wrap=wrap
    )
    costs, reps = pl.pallas_call(
        kernel,
        grid=(Pp // tile,),
        in_specs=[
            pl.BlockSpec((tile, NN), lambda i: (i, 0)),
            pl.BlockSpec((tile, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
            pl.BlockSpec((tile, 24), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, 24), jnp.int32),
            jax.ShapeDtypeStruct((Pp, 24), jnp.int32),
        ],
        interpret=interpret,
    )(dest_mask.astype(jnp.int32), src_xy.astype(jnp.int32))
    return costs[:P], reps[:P]
