"""Pure-jnp oracle for the dpm_cost kernel (same math, no pallas)."""
from __future__ import annotations

import jax.numpy as jnp

from .dpm_cost import BIG, CANDS, _ring_delta


def dpm_cost_table_ref(
    dest_mask, src_xy, *, n, m=None, wrap=False, include_source_leg=True
):
    m = m or n
    P, NN = dest_mask.shape
    node = jnp.arange(NN, dtype=jnp.int32)
    xs, ys = node % n, node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))
    dm = dest_mask.astype(jnp.int32)
    sx, sy = src_xy[:, 0:1], src_xy[:, 1:2]
    dxs = _ring_delta(xs[None] - sx, n, wrap)
    dys = _ring_delta(ys[None] - sy, m, wrap)
    gx, lx, ex = dxs > 0, dxs < 0, dxs == 0
    gy, ly, ey = dys > 0, dys < 0, dys == 0
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]
    dsrc = jnp.abs(dxs) + jnp.abs(dys)
    costs, reps = [], []
    for ids in CANDS:
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm
        any_sel = sel.any(1)
        key = jnp.where(sel, dsrc * BIG + blabel[None], jnp.int32(2**30))
        rep = jnp.argmin(key, 1).astype(jnp.int32)
        rx, ry = rep % n, rep // n
        drep = jnp.abs(_ring_delta(xs[None] - rx[:, None], n, wrap)) + jnp.abs(
            _ring_delta(ys[None] - ry[:, None], m, wrap)
        )
        ct = jnp.sum(jnp.where(sel, drep, 0), 1).astype(jnp.int32)
        if include_source_leg:
            ct = ct + jnp.abs(_ring_delta(rx - sx[:, 0], n, wrap)) + jnp.abs(
                _ring_delta(ry - sy[:, 0], m, wrap)
            )
        costs.append(jnp.where(any_sel, ct, 0))
        reps.append(jnp.where(any_sel, rep, -1))
    return jnp.stack(costs, 1), jnp.stack(reps, 1)


def dpm_cost_table_weighted_ref(
    dest_mask, src_xy, dist, weight, *, n, m=None, wrap=False,
    overhead=0.0, include_source_leg=True,
):
    """Pure-jnp oracle of the weighted kernel (same math, jnp.take gathers)."""
    m = m or n
    P, NN = dest_mask.shape
    node = jnp.arange(NN, dtype=jnp.int32)
    xs, ys = node % n, node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))
    dm = dest_mask.astype(jnp.int32)
    sx, sy = src_xy[:, 0:1], src_xy[:, 1:2]
    dist = dist.astype(jnp.float32)
    weight = weight.astype(jnp.float32)
    dxs = _ring_delta(xs[None] - sx, n, wrap)
    dys = _ring_delta(ys[None] - sy, m, wrap)
    gx, lx, ex = dxs > 0, dxs < 0, dxs == 0
    gy, ly, ey = dys > 0, dys < 0, dys == 0
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]
    src_idx = sy[:, 0] * n + sx[:, 0]
    dsrc = jnp.take(dist, src_idx, axis=0).astype(jnp.int32)
    w_src = jnp.take(weight, src_idx, axis=0)
    costs, reps = [], []
    for ids in CANDS:
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm
        any_sel = sel.any(1)
        key = jnp.where(sel, dsrc * BIG + blabel[None], jnp.int32(2**30))
        rep = jnp.argmin(key, 1).astype(jnp.int32)
        w_rep = jnp.take(weight, rep, axis=0)
        cnt = jnp.sum(sel.astype(jnp.float32), 1)
        ct = jnp.sum(jnp.where(sel, w_rep, 0.0), 1)
        ct = ct + jnp.maximum(cnt - 1.0, 0.0) * float(overhead)
        if include_source_leg:
            ct = ct + jnp.take_along_axis(w_src, rep[:, None], 1)[:, 0]
        costs.append(jnp.where(any_sel, ct, 0.0))
        reps.append(jnp.where(any_sel, rep, -1))
    return jnp.stack(costs, 1), jnp.stack(reps, 1)
