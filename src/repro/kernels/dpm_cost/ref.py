"""Pure-jnp oracle for the dpm_cost kernel (same math, no pallas)."""
from __future__ import annotations

import jax.numpy as jnp

from .dpm_cost import BIG, CANDS


def dpm_cost_table_ref(dest_mask, src_xy, *, n, m=None, include_source_leg=True):
    m = m or n
    P, NN = dest_mask.shape
    node = jnp.arange(NN, dtype=jnp.int32)
    xs, ys = node % n, node // n
    blabel = jnp.where(ys % 2 == 0, ys * n + xs, ys * n + (n - 1 - xs))
    dm = dest_mask.astype(jnp.int32)
    sx, sy = src_xy[:, 0:1], src_xy[:, 1:2]
    gx, lx, ex = xs[None] > sx, xs[None] < sx, xs[None] == sx
    gy, ly, ey = ys[None] > sy, ys[None] < sy, ys[None] == sy
    parts = [
        gx & gy, ex & gy, lx & gy, lx & ey,
        lx & ly, ex & ly, gx & ly, gx & ey,
    ]
    dsrc = jnp.abs(xs[None] - sx) + jnp.abs(ys[None] - sy)
    costs, reps = [], []
    for ids in CANDS:
        cm = parts[ids[0]]
        for i in ids[1:]:
            cm = cm | parts[i]
        sel = (dm > 0) & cm
        any_sel = sel.any(1)
        key = jnp.where(sel, dsrc * BIG + blabel[None], jnp.int32(2**30))
        rep = jnp.argmin(key, 1).astype(jnp.int32)
        rx, ry = rep % n, rep // n
        drep = jnp.abs(xs[None] - rx[:, None]) + jnp.abs(ys[None] - ry[:, None])
        ct = jnp.sum(jnp.where(sel, drep, 0), 1).astype(jnp.int32)
        if include_source_leg:
            ct = ct + jnp.abs(rx - sx[:, 0]) + jnp.abs(ry - sy[:, 0])
        costs.append(jnp.where(any_sel, ct, 0))
        reps.append(jnp.where(any_sel, rep, -1))
    return jnp.stack(costs, 1), jnp.stack(reps, 1)
