"""Jit'd DPM planner fast path: kernel cost table + vectorized greedy merge.

``dpm_plan(dest_mask, src_xy)`` returns, fully on device and batched over
packets, the final partition selection of Algorithm 1 under the MU cost
model: a (P, 24) bool matrix of chosen candidates. Used by the TPU multicast
scheduler for batched plan evaluation, and validated against the host
planner (repro.core) in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.partition import candidate_ids_for, wedge_patterns
from .dpm_cost import BIG, CANDS, dpm_cost_table, dpm_cost_table_weighted

_SINGLES = jnp.arange(8)


@functools.lru_cache(maxsize=None)
def _cand_bits(np_: int) -> np.ndarray:
    """candidate -> bitmask over the ``np_`` basic partitions (np_ <= 30).

    numpy (not jnp) so the cached constant never captures a jit tracer.
    """
    return np.array(
        [sum(1 << i for i in ids) for ids in candidate_ids_for(np_)],
        dtype=np.int32,
    )


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("n", "m", "wrap", "include_source_leg", "interpret")
)
def dpm_plan(
    dest_mask: jax.Array,  # (P, NN)
    src_xy: jax.Array,  # (P, 2)
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    include_source_leg: bool = True,
    interpret: bool | None = None,
):
    """Algorithm 1 (greedy partition merging), batched. Returns
    (chosen (P,24) bool, costs (P,24) int32, reps (P,24) int32).
    ``wrap=True`` plans on torus geometry (toroidal distances/partitions)."""
    if interpret is None:
        interpret = _on_cpu()
    costs, reps = dpm_cost_table(
        dest_mask,
        src_xy,
        n=n,
        m=m,
        wrap=wrap,
        include_source_leg=include_source_leg,
        interpret=interpret,
    )
    # greedy merge (Definition 3 savings + tie-breaks) shared with the
    # weighted path — int32 costs keep the original integer arithmetic
    return _greedy_merge(costs, reps), costs, reps


def total_plan_cost(chosen, costs):
    return jnp.sum(jnp.where(chosen, costs, 0), axis=1)


# order sentinel: "never picked by the merge loop" (leftover singles sort
# after every real pick round; see _greedy_merge_ordered)
NO_ORDER = jnp.int32(2**30)


def _greedy_merge(costs, reps, np_: int = 8):
    """Algorithm 1's greedy merge over an already-computed candidate table.

    Shared by the hop-count, weighted, and generic-topology paths; ``costs``
    may be int32 (hop counting) or float32 (weighted objectives) — savings
    stay in the input dtype and the host tie-break is reproduced exactly in
    either. ``np_`` is the basic-partition count (8 wedges in 2-D, 26 in
    3-D); the candidate axis is ``3 * np_`` (singles + consecutive pairs +
    triples, ``core.partition.candidate_ids_for`` order).
    """
    return _greedy_merge_ordered(costs, reps, np_)[0]


def _greedy_merge_ordered(costs, reps, np_: int = 8):
    """Greedy merge that also reports *pick order*: ``(chosen, order)``.

    ``order[p, ci]`` is the merge round (0-based) at which candidate ``ci``
    won, or ``NO_ORDER`` for unpicked candidates and leftover singles. The
    host planner emits partitions in greedy pick order followed by leftover
    singles in ascending index — an ordering that determines path/parent
    indices inside the final ``MulticastPlan`` — so the batched decoder
    (``core.batch_planner``) needs the rounds, not just the winning set,
    to reproduce host plans bit-identically.
    """
    cands = candidate_ids_for(np_)
    NC = len(cands)
    cand_bits = jnp.asarray(_cand_bits(np_))
    P = costs.shape[0]
    nonempty = reps >= 0  # (P, NC)

    split_cost = jnp.zeros_like(costs)
    for ci, ids in enumerate(cands):
        if len(ids) == 1:
            continue
        sc = sum(costs[:, i] for i in ids)
        split_cost = split_cost.at[:, ci].set(sc)
    saving0 = jnp.where(
        (jnp.arange(NC) >= np_)[None, :] & nonempty,
        jnp.maximum(0, split_cost - costs),
        0,
    )

    # host tie-break (dpm_partition): max saving, then fewer merged
    # partitions, then smaller candidate index — resolved as a two-step
    # argmax/argmin so exact-tie semantics survive float32 savings (a
    # scalar "saving * K - adj" encoding would mis-rank near-ties under
    # the energy/contention objectives)
    prio_adj = (
        jnp.array([len(ids) for ids in cands], jnp.int32) * 128
        + jnp.arange(NC, dtype=jnp.int32)
    )

    def step(state, rnd):
        saving, covered, chosen, order = state
        overlap = (cand_bits[None, :] & covered[:, None]) != 0
        s = jnp.where(overlap, 0, saving)
        smax = jnp.max(s, axis=1, keepdims=True)
        is_best = (s == smax) & (s > 0)
        best = jnp.argmin(
            jnp.where(is_best, prio_adj[None, :], jnp.int32(2**30)), axis=1
        )
        has = smax[:, 0] > 0
        bbits = cand_bits[best]
        covered = jnp.where(has, covered | bbits, covered)
        rows = jnp.arange(P)
        chosen = chosen.at[rows, best].set(chosen[rows, best] | has)
        order = order.at[rows, best].set(
            jnp.where(has, jnp.minimum(order[rows, best], rnd), order[rows, best])
        )
        return (s, covered, chosen, order), None

    chosen0 = jnp.zeros((P, NC), bool)
    covered0 = jnp.zeros((P,), jnp.int32)
    order0 = jnp.full((P, NC), NO_ORDER, jnp.int32)
    # every winning merge covers >= 2 uncovered partitions, so np_ // 2
    # rounds always reach the fixed point
    (saving, covered, chosen, order), _ = jax.lax.scan(
        step, (saving0, covered0, chosen0, order0),
        jnp.arange(np_ // 2, dtype=jnp.int32),
    )
    single_bit = 1 << jnp.arange(np_, dtype=jnp.int32)
    leftover = nonempty[:, :np_] & (
        (covered[:, None] & single_bit[None, :]) == 0
    )
    chosen = chosen.at[:, :np_].set(chosen[:, :np_] | leftover)
    return chosen, order


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "wrap", "overhead", "include_source_leg",
                     "interpret"),
)
def dpm_plan_weighted(
    dest_mask: jax.Array,  # (P, NN)
    src_xy: jax.Array,  # (P, 2)
    dist: jax.Array,  # (NN, NN) provider-route hop counts
    weight: jax.Array,  # (NN, NN) provider-route prices
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    overhead: float = 0.0,
    include_source_leg: bool = True,
    interpret: bool | None = None,
):
    """Algorithm 1 batched under an arbitrary route-cost tensor.

    The device twin of ``dpm_partition(..., cost_model=...)`` restricted to
    MU-mode candidate pricing: ``(dist, weight, overhead)`` come from
    ``repro.core.routefn.route_cost_matrices``, so energy / contention /
    fault-penalty DPM (including detoured routes on a ``FaultyTopology``)
    batch on device. Returns (chosen (P,24) bool, costs (P,24) f32,
    reps (P,24) i32).
    """
    if interpret is None:
        interpret = _on_cpu()
    costs, reps = dpm_cost_table_weighted(
        dest_mask, src_xy, dist, weight,
        n=n, m=m, wrap=wrap, overhead=overhead,
        include_source_leg=include_source_leg, interpret=interpret,
    )
    return _greedy_merge(costs, reps), costs, reps


# ---------------------------------------------------------------------------
# Generic-topology path: 3-D meshes/tori (26 wedges) and chiplet packages
# route their geometry through host-built lookup tables instead of the
# closed-form 2-D coordinate math baked into the Pallas kernels above.
# ---------------------------------------------------------------------------
def partition_membership(g, srcs) -> np.ndarray:
    """(len(srcs), NN) int32 wedge id of every node w.r.t. each source.

    Entry ``[p, v]`` is the basic-partition index of node ``v`` under
    packet ``p``'s source (``core.partition.wedge_patterns`` order over
    sign patterns of ``Topology.delta``), or -1 at the source itself —
    the membership table ``dpm_plan_topo`` selects candidates from.
    """
    nodes = g.nodes()
    ndim = len(nodes[0])
    index = {p: i for i, p in enumerate(wedge_patterns(ndim))}
    out = np.full((len(srcs), g.num_nodes), -1, np.int32)
    for pi, src in enumerate(srcs):
        for v in nodes:
            dv = g.delta(src, v)
            sign = tuple((x > 0) - (x < 0) for x in dv)
            out[pi, g.idx(v)] = index.get(sign, -1)
    return out


def snake_labels(g) -> np.ndarray:
    """(NN,) int32 boustrophedon label per node, ``Topology.idx`` order."""
    return np.array([g.label(*c) for c in g.nodes()], np.int32)


@functools.partial(
    jax.jit, static_argnames=("np_", "overhead", "include_source_leg")
)
def dpm_plan_topo(
    part_of: jax.Array,  # (P, NN) int32 membership (partition_membership)
    src_idx: jax.Array,  # (P,) int32 Topology.idx of each source
    labels: jax.Array,  # (NN,) int32 snake labels (snake_labels)
    dist: jax.Array,  # (NN, NN) provider-route hop counts
    weight: jax.Array,  # (NN, NN) provider-route prices
    *,
    np_: int,
    overhead: float = 0.0,
    include_source_leg: bool = True,
):
    """Algorithm 1 batched on *any* registered topology.

    The geometry enters as data: wedge membership (masking non-destinations
    with -1), snake labels, and the ``(dist, weight, overhead)`` route-cost
    tensors of ``repro.core.routefn.route_cost_matrices`` — so 3-D meshes,
    tori, and chiplet packages (including degraded/weighted fabrics) batch
    on device with no kernel-side coordinate math. ``np_`` is
    ``len(core.partition.wedge_patterns(ndim))``: 8 in 2-D, 26 in 3-D.
    Returns (chosen (P, 3*np_) bool, costs (P, 3*np_) f32,
    reps (P, 3*np_) i32), candidate axis in ``candidate_ids_for`` order.
    """
    cands = candidate_ids_for(np_)
    dist = dist.astype(jnp.int32)
    weight = weight.astype(jnp.float32)
    dsrc = jnp.take(dist, src_idx, axis=0)  # (P, NN)
    w_src = jnp.take(weight, src_idx, axis=0)
    costs, reps = [], []
    for ids in cands:
        sel = part_of == ids[0]
        for i in ids[1:]:
            sel = sel | (part_of == i)
        any_sel = sel.any(1)
        # Definition 1 representative: min (dist-to-src, label)
        key = jnp.where(sel, dsrc * BIG + labels[None], jnp.int32(2**30))
        rep = jnp.argmin(key, 1).astype(jnp.int32)
        w_rep = jnp.take(weight, rep, axis=0)  # (P, NN) prices from rep
        cnt = jnp.sum(sel.astype(jnp.float32), 1)
        ct = jnp.sum(jnp.where(sel, w_rep, 0.0), 1)
        ct = ct + jnp.maximum(cnt - 1.0, 0.0) * float(overhead)
        if include_source_leg:
            ct = ct + jnp.take_along_axis(w_src, rep[:, None], 1)[:, 0]
        costs.append(jnp.where(any_sel, ct, 0.0))
        reps.append(jnp.where(any_sel, rep, -1))
    costs = jnp.stack(costs, 1)
    reps = jnp.stack(reps, 1)
    return _greedy_merge(costs, reps, np_), costs, reps


def _chain_cost(sel_l, bound, ascending, label_order, w_flat, rep, NN):
    """Price one dual-path chain side for every (packet, position).

    ``sel_l`` is the selection reordered to label rank; the side's members
    are the selected ranks strictly beyond ``bound`` (the representative's
    label) in the walk direction. A label-ordered chain decomposes into
    pairwise label routes between consecutive members — the label rule only
    ever moves through labels at or below (above, descending) the current
    target, so no pending member is passed early — which turns C_p into a
    prefix-scan over label rank: each member's predecessor is the running
    max (min) of selected ranks before it, or the representative when none.
    Returns (side cost (B,), side nonempty (B,)).
    """
    pos = jnp.arange(NN, dtype=jnp.int32)
    if ascending:
        active = sel_l & (pos[None, :] > bound[:, None])
        walk = active
        order_nodes = label_order
    else:
        active = sel_l & (pos[None, :] < bound[:, None])
        walk = jnp.flip(active, axis=1)
        order_nodes = jnp.flip(label_order)
    idx_seq = jnp.where(walk, pos[None, :], -1)
    run = jax.lax.cummax(idx_seq, axis=1)
    prev = jnp.concatenate(
        [jnp.full((run.shape[0], 1), -1, run.dtype), run[:, :-1]], axis=1
    )
    prev_node = jnp.where(
        prev >= 0, jnp.take(order_nodes, jnp.clip(prev, 0)), rep[:, None]
    )
    cur_node = order_nodes[None, :]
    contrib = jnp.take(w_flat, prev_node * NN + cur_node)
    return (
        jnp.sum(jnp.where(walk, contrib, 0.0), axis=1),
        active.any(axis=1),
    )


@functools.partial(
    jax.jit, static_argnames=("np_", "overhead", "include_source_leg")
)
def dpm_plan_exact(
    dest_mask: jax.Array,  # (B, NN) bool destination sets
    src_idx: jax.Array,  # (B,) int32 Topology.idx of each source
    part_of: jax.Array,  # (B, NN) int32 wedge membership (all nodes)
    labels: jax.Array,  # (NN,) int32 snake labels
    label_order: jax.Array,  # (NN,) int32 node index at each label rank
    dist: jax.Array,  # (NN, NN) provider-route hop counts
    w_uni: jax.Array,  # (NN, NN) unicast-route prices (C_t terms)
    w_high: jax.Array,  # (NN, NN) HIGH-subnetwork label-route prices
    w_low: jax.Array,  # (NN, NN) LOW-subnetwork label-route prices
    *,
    np_: int,
    overhead: float = 0.0,
    include_source_leg: bool = True,
):
    """Algorithm 1 batched with the *full* Definition 2 objective.

    Unlike ``dpm_plan_topo`` (which prices candidates by C_t only), this
    evaluates both C_t and C_p per candidate — C_p via the label-chain
    prefix scan of ``_chain_cost`` over the dense pairwise label-route
    price matrices — and records the MU/DP mode choice and the greedy
    pick order, everything the host decode needs to rebuild each
    ``MulticastPlan`` bit-identically (``core.batch_planner``; exactness
    conditions in ``batch_support`` there). Returns
    ``(chosen, order, reps, mode_mu, costs)``, all ``(B, 3 * np_)`` over
    the ``candidate_ids_for`` axis.
    """
    import numpy as _np

    cands = candidate_ids_for(np_)
    NC = len(cands)
    B, NN = dest_mask.shape
    dist = dist.astype(jnp.int32)
    w_uni = w_uni.astype(jnp.float32)
    wh_flat = w_high.astype(jnp.float32).reshape(-1)
    wl_flat = w_low.astype(jnp.float32).reshape(-1)
    dsrc = jnp.take(dist, src_idx, axis=0)  # (B, NN)
    w_src = jnp.take(w_uni, src_idx, axis=0)
    # All candidates evaluated as one stacked (NC * B, NN) problem — a
    # static candidate->wedge incidence table turns the per-candidate
    # membership test into a single gather, and everything downstream is
    # one tensor op per step instead of NC of them.
    inc = _np.zeros((NC, np_), bool)
    for ci, ids in enumerate(cands):
        inc[ci, list(ids)] = True
    member = jnp.take(jnp.asarray(inc), part_of, axis=1)  # (NC, B, NN)
    sel = (dest_mask[None] & member).reshape(NC * B, NN)
    any_sel = sel.any(1)
    # Definition 1 representative: min (dist-to-src, label)
    dsrc_t = jnp.broadcast_to(dsrc[None], (NC, B, NN)).reshape(NC * B, NN)
    key = jnp.where(sel, dsrc_t * BIG + labels[None], jnp.int32(2**30))
    rep = jnp.argmin(key, 1).astype(jnp.int32)
    # C_t: one unicast worm per non-representative destination
    w_rep = jnp.take(w_uni, rep, axis=0)  # (NC * B, NN) prices from rep
    cnt = jnp.sum(sel.astype(jnp.float32), 1)
    cost_mu = jnp.sum(jnp.where(sel, w_rep, 0.0), 1)
    cost_mu = cost_mu + jnp.maximum(cnt - 1.0, 0.0) * float(overhead)
    # C_p: label-ordered chains from the representative, one per side
    rep_lab = jnp.take(labels, rep)
    sel_l = jnp.take_along_axis(
        sel, jnp.broadcast_to(label_order[None, :], sel.shape), axis=1
    )
    hi, any_h = _chain_cost(sel_l, rep_lab, True, label_order, wh_flat, rep, NN)
    lo, any_l = _chain_cost(sel_l, rep_lab, False, label_order, wl_flat, rep, NN)
    cost_dp = (
        hi + lo
        + (any_h.astype(jnp.float32) + any_l.astype(jnp.float32))
        * float(overhead)
    )
    # ties prefer MU (the paper: D_H/D_L computation is then skipped)
    mode_mu = cost_mu <= cost_dp
    cost = jnp.minimum(cost_mu, cost_dp)
    if include_source_leg:
        w_src_t = jnp.broadcast_to(
            w_src[None], (NC, B, NN)
        ).reshape(NC * B, NN)
        cost = cost + jnp.take_along_axis(w_src_t, rep[:, None], 1)[:, 0]
    costs = jnp.where(any_sel, cost, 0.0).reshape(NC, B).T
    reps = jnp.where(any_sel, rep, -1).reshape(NC, B).T
    modes = (mode_mu | ~any_sel).reshape(NC, B).T
    chosen, order = _greedy_merge_ordered(costs, reps, np_)
    return chosen, order, reps, modes, costs
