"""Jit'd DPM planner fast path: kernel cost table + vectorized greedy merge.

``dpm_plan(dest_mask, src_xy)`` returns, fully on device and batched over
packets, the final partition selection of Algorithm 1 under the MU cost
model: a (P, 24) bool matrix of chosen candidates. Used by the TPU multicast
scheduler for batched plan evaluation, and validated against the host
planner (repro.core) in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dpm_cost import CANDS, dpm_cost_table, dpm_cost_table_weighted

_SINGLES = jnp.arange(8)
# candidate -> bitmask over the 8 basic partitions
_CAND_BITS = jnp.array(
    [sum(1 << i for i in ids) for ids in CANDS], dtype=jnp.int32
)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("n", "m", "wrap", "include_source_leg", "interpret")
)
def dpm_plan(
    dest_mask: jax.Array,  # (P, NN)
    src_xy: jax.Array,  # (P, 2)
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    include_source_leg: bool = True,
    interpret: bool | None = None,
):
    """Algorithm 1 (greedy partition merging), batched. Returns
    (chosen (P,24) bool, costs (P,24) int32, reps (P,24) int32).
    ``wrap=True`` plans on torus geometry (toroidal distances/partitions)."""
    if interpret is None:
        interpret = _on_cpu()
    costs, reps = dpm_cost_table(
        dest_mask,
        src_xy,
        n=n,
        m=m,
        wrap=wrap,
        include_source_leg=include_source_leg,
        interpret=interpret,
    )
    # greedy merge (Definition 3 savings + tie-breaks) shared with the
    # weighted path — int32 costs keep the original integer arithmetic
    return _greedy_merge(costs, reps), costs, reps


def total_plan_cost(chosen, costs):
    return jnp.sum(jnp.where(chosen, costs, 0), axis=1)


def _greedy_merge(costs, reps):
    """Algorithm 1's greedy merge over an already-computed candidate table.

    Shared by the hop-count and weighted paths; ``costs`` may be int32 (hop
    counting) or float32 (weighted objectives) — savings stay in the input
    dtype and the host tie-break is reproduced exactly in either.
    """
    P = costs.shape[0]
    nonempty = reps >= 0  # (P, 24)

    split_cost = jnp.zeros_like(costs)
    for ci, ids in enumerate(CANDS):
        if len(ids) == 1:
            continue
        sc = sum(costs[:, i] for i in ids)
        split_cost = split_cost.at[:, ci].set(sc)
    saving0 = jnp.where(
        (jnp.arange(24) >= 8)[None, :] & nonempty,
        jnp.maximum(0, split_cost - costs),
        0,
    )

    # host tie-break (dpm_partition): max saving, then fewer merged
    # partitions, then smaller candidate index — resolved as a two-step
    # argmax/argmin so exact-tie semantics survive float32 savings (a
    # scalar "saving * K - adj" encoding would mis-rank near-ties under
    # the energy/contention objectives)
    prio_adj = (
        jnp.array([len(ids) for ids in CANDS], jnp.int32) * 32
        + jnp.arange(24, dtype=jnp.int32)
    )

    def step(state, _):
        saving, covered, chosen = state
        overlap = (_CAND_BITS[None, :] & covered[:, None]) != 0
        s = jnp.where(overlap, 0, saving)
        smax = jnp.max(s, axis=1, keepdims=True)
        is_best = (s == smax) & (s > 0)
        best = jnp.argmin(
            jnp.where(is_best, prio_adj[None, :], jnp.int32(2**30)), axis=1
        )
        has = smax[:, 0] > 0
        bbits = _CAND_BITS[best]
        covered = jnp.where(has, covered | bbits, covered)
        chosen = chosen.at[jnp.arange(P), best].set(
            chosen[jnp.arange(P), best] | has
        )
        return (s, covered, chosen), None

    chosen0 = jnp.zeros((P, 24), bool)
    covered0 = jnp.zeros((P,), jnp.int32)
    (saving, covered, chosen), _ = jax.lax.scan(
        step, (saving0, covered0, chosen0), None, length=4
    )
    single_bit = 1 << jnp.arange(8, dtype=jnp.int32)
    leftover = nonempty[:, :8] & ((covered[:, None] & single_bit[None, :]) == 0)
    chosen = chosen.at[:, :8].set(chosen[:, :8] | leftover)
    return chosen


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "wrap", "overhead", "include_source_leg",
                     "interpret"),
)
def dpm_plan_weighted(
    dest_mask: jax.Array,  # (P, NN)
    src_xy: jax.Array,  # (P, 2)
    dist: jax.Array,  # (NN, NN) provider-route hop counts
    weight: jax.Array,  # (NN, NN) provider-route prices
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    overhead: float = 0.0,
    include_source_leg: bool = True,
    interpret: bool | None = None,
):
    """Algorithm 1 batched under an arbitrary route-cost tensor.

    The device twin of ``dpm_partition(..., cost_model=...)`` restricted to
    MU-mode candidate pricing: ``(dist, weight, overhead)`` come from
    ``repro.core.routefn.route_cost_matrices``, so energy / contention /
    fault-penalty DPM (including detoured routes on a ``FaultyTopology``)
    batch on device. Returns (chosen (P,24) bool, costs (P,24) f32,
    reps (P,24) i32).
    """
    if interpret is None:
        interpret = _on_cpu()
    costs, reps = dpm_cost_table_weighted(
        dest_mask, src_xy, dist, weight,
        n=n, m=m, wrap=wrap, overhead=overhead,
        include_source_leg=include_source_leg, interpret=interpret,
    )
    return _greedy_merge(costs, reps), costs, reps
