"""Jit'd DPM planner fast path: kernel cost table + vectorized greedy merge.

``dpm_plan(dest_mask, src_xy)`` returns, fully on device and batched over
packets, the final partition selection of Algorithm 1 under the MU cost
model: a (P, 24) bool matrix of chosen candidates. Used by the TPU multicast
scheduler for batched plan evaluation, and validated against the host
planner (repro.core) in tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dpm_cost import CANDS, dpm_cost_table

_SINGLES = jnp.arange(8)
# candidate -> bitmask over the 8 basic partitions
_CAND_BITS = jnp.array(
    [sum(1 << i for i in ids) for ids in CANDS], dtype=jnp.int32
)


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("n", "m", "wrap", "include_source_leg", "interpret")
)
def dpm_plan(
    dest_mask: jax.Array,  # (P, NN)
    src_xy: jax.Array,  # (P, 2)
    *,
    n: int,
    m: int | None = None,
    wrap: bool = False,
    include_source_leg: bool = True,
    interpret: bool | None = None,
):
    """Algorithm 1 (greedy partition merging), batched. Returns
    (chosen (P,24) bool, costs (P,24) int32, reps (P,24) int32).
    ``wrap=True`` plans on torus geometry (toroidal distances/partitions)."""
    if interpret is None:
        interpret = _on_cpu()
    costs, reps = dpm_cost_table(
        dest_mask,
        src_xy,
        n=n,
        m=m,
        wrap=wrap,
        include_source_leg=include_source_leg,
        interpret=interpret,
    )
    P = costs.shape[0]
    nonempty = reps >= 0  # (P, 24)

    # saving of each merged candidate vs its singles (Definition 3)
    split_cost = jnp.zeros((P, 24), jnp.int32)
    for ci, ids in enumerate(CANDS):
        if len(ids) == 1:
            continue
        sc = sum(costs[:, i] for i in ids)
        split_cost = split_cost.at[:, ci].set(sc)
    saving0 = jnp.where(
        (jnp.arange(24) >= 8)[None, :] & nonempty,
        jnp.maximum(0, split_cost - costs),
        0,
    )

    # tie-break: fewer partitions first, then smaller index -> encode
    # priority = saving * 64 - (len(ids) * 8 + ci_mod) so larger is better
    sizes = jnp.array([len(ids) for ids in CANDS], jnp.int32)
    prio_adj = sizes * 32 + jnp.arange(24, dtype=jnp.int32)

    def step(state, _):
        saving, covered, chosen = state  # covered: (P,) int32 bitmask
        # zero savings of candidates overlapping covered partitions
        overlap = (_CAND_BITS[None, :] & covered[:, None]) != 0
        s = jnp.where(overlap, 0, saving)
        prio = s * 1024 - prio_adj[None, :]
        best = jnp.argmax(jnp.where(s > 0, prio, -(2**30)), axis=1)
        has = jnp.take_along_axis(s, best[:, None], 1)[:, 0] > 0
        bbits = _CAND_BITS[best]
        covered = jnp.where(has, covered | bbits, covered)
        chosen = chosen.at[jnp.arange(P), best].set(
            chosen[jnp.arange(P), best] | has
        )
        return (s, covered, chosen), None

    chosen0 = jnp.zeros((P, 24), bool)
    covered0 = jnp.zeros((P,), jnp.int32)
    (saving, covered, chosen), _ = jax.lax.scan(
        step, (saving0, covered0, chosen0), None, length=4
    )
    # leftover non-empty singles not covered by a chosen merge
    single_bit = 1 << jnp.arange(8, dtype=jnp.int32)
    leftover = nonempty[:, :8] & ((covered[:, None] & single_bit[None, :]) == 0)
    chosen = chosen.at[:, :8].set(chosen[:, :8] | leftover)
    return chosen, costs, reps


def total_plan_cost(chosen, costs):
    return jnp.sum(jnp.where(chosen, costs, 0), axis=1)
